//! RL algorithm utilities on the coordinator side: GRPO group-
//! normalized advantages (paper Eq. 2), reward normalization, TOPR
//! trajectory partitioning, and minibatch assembly into the AOT
//! `train_step` layout.

use crate::runtime::TrainBatch;

/// A completed, scored rollout sample (the SampleBuffer element).
#[derive(Clone, Debug)]
pub struct Trajectory {
    /// fixed-length prompt tokens (the first observation)
    pub prompt: Vec<i32>,
    /// everything after the prompt: generated action tokens, and (for
    /// multi-turn envs) interleaved observation tokens
    pub response: Vec<i32>,
    /// 1.0 for trainable (policy-generated) response tokens, 0.0 for
    /// environment-observation tokens
    pub response_mask: Vec<f32>,
    /// behavior-policy logprob of each response token, recorded at
    /// decode time (pi_old for importance sampling); 0.0 at obs tokens
    pub behavior_logps: Vec<f32>,
    pub reward: f32,
    /// prompt/group id (GRPO normalizes within a group)
    pub group: u64,
    /// policy version that initiated generation (Section 4.3)
    pub init_version: u64,
    /// some generation in this trajectory straddled a weight update
    /// (a prefix salvaged by partial migration resumed under newer
    /// weights): the behavior policy is piecewise across versions.
    /// Surfaced as `cross_version_samples` in buffer/step stats.
    pub cross_version: bool,
}

impl Trajectory {
    /// Single-turn helper: every response token is trainable.
    pub fn single_turn(
        prompt: Vec<i32>,
        response: Vec<i32>,
        behavior_logps: Vec<f32>,
        reward: f32,
        group: u64,
        init_version: u64,
    ) -> Self {
        let response_mask = vec![1.0; response.len()];
        Trajectory {
            prompt,
            response,
            response_mask,
            behavior_logps,
            reward,
            group,
            init_version,
            cross_version: false,
        }
    }

    pub fn total_len(&self) -> usize {
        self.prompt.len() + self.response.len()
    }
}

/// GRPO advantage (Eq. 2): standardize rewards within each group.
/// `samples` must contain complete groups. Returns one advantage per
/// sample, broadcast over its response tokens at batch assembly.
pub fn grpo_advantages(samples: &[Trajectory]) -> Vec<f32> {
    use std::collections::BTreeMap;
    let mut groups: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, s) in samples.iter().enumerate() {
        groups.entry(s.group).or_default().push(i);
    }
    let mut adv = vec![0f32; samples.len()];
    for idx in groups.values() {
        let rewards: Vec<f64> = idx.iter().map(|&i| samples[i].reward as f64).collect();
        let mean = crate::util::mean(&rewards);
        let std = crate::util::std_dev(&rewards);
        for &i in idx {
            adv[i] = if std > 1e-8 {
                ((samples[i].reward as f64 - mean) / std) as f32
            } else {
                0.0 // zero intra-group variance: no learning signal
            };
        }
    }
    adv
}

/// A group is degenerate (filterable) when all rewards coincide — the
/// dynamic-filtering criterion of Section 5.1.1.
pub fn group_has_zero_variance(rewards: &[f32]) -> bool {
    rewards.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-8)
}

/// TOPR trajectory sign: T^+ (>= group mean) vs T^- (below).
pub fn topr_signs(samples: &[Trajectory], advantages: &[f32]) -> Vec<f32> {
    samples
        .iter()
        .zip(advantages)
        .map(|(_, &a)| if a >= 0.0 { 1.0 } else { -1.0 })
        .collect()
}

/// Assemble `rows` trajectories into the fixed [B, S] train_step layout.
///
/// Token position p is predicted at slot p-1, so a response spanning
/// positions [P, P+L) sets mask slots [P-1, P+L-1) and places the k-th
/// behavior logprob at slot P+k-1.
pub fn assemble_batch(
    rows: &[Trajectory],
    advantages: &[f32],
    signs: &[f32],
    batch: usize,
    max_seq: usize,
) -> TrainBatch {
    assert_eq!(rows.len(), batch, "must pass exactly train_batch rows");
    let mut out = TrainBatch {
        tokens: vec![0; batch * max_seq],
        mask: vec![0.0; batch * max_seq],
        adv: vec![0.0; batch * max_seq],
        logp_old: vec![0.0; batch * max_seq],
        logp_prox: vec![0.0; batch * max_seq],
        sign: signs.to_vec(),
    };
    for (r, traj) in rows.iter().enumerate() {
        let p = traj.prompt.len();
        let base = r * max_seq;
        for (i, &t) in traj.prompt.iter().enumerate() {
            out.tokens[base + i] = t;
        }
        let resp_len = traj.response.len().min(max_seq - p);
        for k in 0..resp_len {
            out.tokens[base + p + k] = traj.response[k];
            if traj.response_mask[k] > 0.0 {
                let slot = base + p + k - 1;
                out.mask[slot] = 1.0;
                out.adv[slot] = advantages[r];
                out.logp_old[slot] = traj.behavior_logps[k];
                out.logp_prox[slot] = traj.behavior_logps[k]; // overwritten when needed
            }
        }
    }
    out
}

/// Fill `logp_prox` from a proximal-policy forward pass laid out
/// [B, S] (Decoupled PPO; Section 2.2).
pub fn fill_prox(batch: &mut TrainBatch, prox: &[f32]) {
    assert_eq!(batch.logp_prox.len(), prox.len());
    for (dst, (&src, &m)) in batch.logp_prox.iter_mut().zip(prox.iter().zip(&batch.mask)) {
        if m > 0.0 {
            *dst = src;
        }
    }
}

/// Mean reward / pass-rate metrics for logging.
pub fn pass_rate(samples: &[Trajectory]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.iter().filter(|s| s.reward > 0.5).count() as f64 / samples.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn traj(group: u64, reward: f32) -> Trajectory {
        Trajectory::single_turn(vec![1, 2, 3], vec![4, 5, 2], vec![-0.5, -0.7, -0.1], reward, group, 0)
    }

    #[test]
    fn grpo_normalizes_within_group() {
        let samples = vec![traj(0, 1.0), traj(0, 0.0), traj(1, 1.0), traj(1, 1.0)];
        let adv = grpo_advantages(&samples);
        // group 0: mean 0.5 std 0.5 -> +-1
        assert!((adv[0] - 1.0).abs() < 1e-6);
        assert!((adv[1] + 1.0).abs() < 1e-6);
        // group 1: zero variance -> 0
        assert_eq!(adv[2], 0.0);
        assert_eq!(adv[3], 0.0);
    }

    #[test]
    fn zero_variance_detection() {
        assert!(group_has_zero_variance(&[1.0, 1.0, 1.0]));
        assert!(!group_has_zero_variance(&[1.0, 0.0]));
        assert!(group_has_zero_variance(&[]));
    }

    #[test]
    fn topr_signs_follow_advantage() {
        let samples = vec![traj(0, 1.0), traj(0, 0.0)];
        let adv = grpo_advantages(&samples);
        let signs = topr_signs(&samples, &adv);
        assert_eq!(signs, vec![1.0, -1.0]);
    }

    #[test]
    fn assemble_layout() {
        let t = traj(0, 1.0);
        let b = assemble_batch(&[t.clone()], &[2.0], &[1.0], 1, 16);
        // prompt 3 tokens at 0..3, response at 3..6
        assert_eq!(&b.tokens[0..6], &[1, 2, 3, 4, 5, 2]);
        // mask slots 2..5 (predicting positions 3..6)
        assert_eq!(&b.mask[0..6], &[0.0, 0.0, 1.0, 1.0, 1.0, 0.0]);
        assert_eq!(b.adv[2], 2.0);
        assert_eq!(b.logp_old[2], -0.5);
        assert_eq!(b.logp_old[4], -0.1);
        assert_eq!(b.sign, vec![1.0]);
        // masked token count equals response length
        let masked: f32 = b.mask.iter().sum();
        assert_eq!(masked, 3.0);
    }

    #[test]
    fn assemble_truncates_overlong_response() {
        let mut t = traj(0, 1.0);
        t.response = (0..40).map(|i| (i % 10) as i32).collect();
        t.response_mask = vec![1.0; 40];
        t.behavior_logps = vec![-0.1; 40];
        let b = assemble_batch(&[t], &[1.0], &[1.0], 1, 16);
        let masked: f32 = b.mask.iter().sum();
        assert_eq!(masked, 13.0); // 16 - 3 prompt slots
    }

    #[test]
    fn assemble_skips_observation_tokens() {
        let mut t = traj(0, 1.0);
        // response: act obs obs act — only act tokens trainable
        t.response = vec![5, 6, 7, 8];
        t.response_mask = vec![1.0, 0.0, 0.0, 1.0];
        t.behavior_logps = vec![-0.3, 0.0, 0.0, -0.4];
        let b = assemble_batch(&[t], &[1.0], &[1.0], 1, 16);
        let masked: f32 = b.mask.iter().sum();
        assert_eq!(masked, 2.0);
        assert_eq!(b.logp_old[2], -0.3); // slot for position 3
        assert_eq!(b.logp_old[5], -0.4); // slot for position 6
        assert_eq!(b.mask[3], 0.0);
    }

    #[test]
    fn pass_rate_counts() {
        let samples = vec![traj(0, 1.0), traj(0, 0.0), traj(1, 1.0)];
        assert!((pass_rate(&samples) - 2.0 / 3.0).abs() < 1e-9);
    }
}
