//! Virtual-time simulator of the RLVR post-training pipeline.
//!
//! Reproduces the scheduling phenomena of the paper's RLVR experiments
//! (Figs 1b, 3a, 3b, 7, 8; Table 1): batch rollout vs queue scheduling,
//! prompt replication, dynamic filtering with redundant prompts, and
//! the asynchronous rollout-train decoupled architecture with the
//! per-sample asynchronous-ratio bound (Section 4.3).
//!
//! The coordination policies here mirror `coordinator/` exactly; only
//! the execution substrate is virtual (DESIGN.md §3).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::coordinator::async_governor::{AsyncGovernor, AsyncMode, GovernorCfg};
use crate::metrics::telemetry::{TelemetryCfg, TelemetryPlane, TelemetrySignals};
use crate::sim::queue::{GpuPool, ServicePool, T};
use crate::util::rng::Rng;
use crate::workload::{DecodeCost, LengthProfile, RewardCost, TrainCost};

/// Rollout scheduling mode (Section 5.1.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheduling {
    /// One batch, barrier before rewards (Sync-Naive).
    BatchRollout,
    /// Per-sample tasks, immediate reward dispatch, early stop.
    QueueSched,
}

/// Dynamic-filtering configuration (Fig 7).
#[derive(Clone, Copy, Debug)]
pub struct FilterCfg {
    /// probability a prompt group has zero intra-group reward variance
    pub p_degenerate: f64,
    /// redundant prompts allowed in flight beyond the quota
    pub max_additional_running_prompts: usize,
}

#[derive(Clone, Debug)]
pub struct RlvrSimConfig {
    pub infer_gpus: usize,
    pub train_gpus: usize,
    /// full-speed co-resident sequences per GPU
    pub knee: usize,
    /// admission cap per GPU
    pub max_active: usize,
    pub n_prompts: usize,
    pub group_size: usize,
    pub scheduling: Scheduling,
    /// prompt replication (Section 5.1.2): candidates spread across GPUs
    pub replicate: bool,
    /// asynchronous ratio alpha; 0.0 => synchronous
    pub async_ratio: f64,
    pub lengths: LengthProfile,
    pub decode: DecodeCost,
    pub train: TrainCost,
    pub reward: RewardCost,
    pub reward_workers: usize,
    pub weight_sync_time: f64,
    pub filter: Option<FilterCfg>,
    /// adaptive asynchrony governor: when enabled, the sim runs the
    /// decoupled pipeline with a real `TelemetryPlane` on virtual
    /// time and lets the governor dial the mode ladder instead of a
    /// fixed `async_ratio`
    pub governor: Option<GovernorCfg>,
    pub steps: usize,
    pub seed: u64,
}

impl RlvrSimConfig {
    /// Paper-calibrated defaults (Qwen3-8B, DAPO-Math; Appendix A).
    pub fn paper_default(infer_gpus: usize, train_gpus: usize) -> Self {
        RlvrSimConfig {
            infer_gpus,
            train_gpus,
            knee: 32,
            max_active: 96,
            n_prompts: 256,
            group_size: 16,
            scheduling: Scheduling::QueueSched,
            replicate: true,
            async_ratio: 0.0,
            lengths: LengthProfile::qwen3_think(),
            decode: DecodeCost::qwen3_8b(),
            train: TrainCost::qwen3_8b(),
            reward: RewardCost::verifier(),
            reward_workers: 64,
            weight_sync_time: 10.0,
            filter: None,
            governor: None,
            steps: 4,
            seed: 17,
        }
    }

    pub fn sequences_per_step(&self) -> usize {
        self.n_prompts * self.group_size
    }
}

#[derive(Clone, Debug, Default)]
pub struct RlvrReport {
    pub total_time: f64,
    pub step_times: Vec<f64>,
    pub samples_consumed: usize,
    pub tokens_generated: f64,
    pub gen_utilization: f64,
    /// trainer seconds spent waiting for samples
    pub trainer_idle: f64,
    /// per-sample policy-version gap at consumption (async)
    pub mean_version_gap: f64,
    pub max_version_gap: usize,
    /// generation work discarded by aborts / filtering
    pub wasted_tokens: f64,
    pub filtered_groups: usize,
    /// governor mode timeline: (virtual time, mode label), seeded
    /// with the starting mode at t=0 (adaptive arm only)
    pub mode_timeline: Vec<(f64, String)>,
    pub mode_transitions: usize,
    /// largest per-window version-gap signal the telemetry plane
    /// measured (the quantity the staleness budget bounds)
    pub max_window_gap: f64,
}

impl RlvrReport {
    pub fn mean_step_time(&self) -> f64 {
        crate::util::mean(&self.step_times)
    }

    pub fn samples_per_hour(&self) -> f64 {
        if self.total_time <= 0.0 {
            return 0.0;
        }
        self.samples_consumed as f64 / self.total_time * 3600.0
    }
}

struct GroupState {
    done: usize,
    rewards_done: usize,
    degenerate: bool,
    aborted: bool,
}

/// Effective decode work including prefill and the context-length
/// attention term, in short-context token units.
fn task_tokens(cfg: &RlvrSimConfig, len: usize) -> f64 {
    cfg.decode.effective_tokens(len) + cfg.decode.prefill_time / cfg.decode.token_time
}

pub fn run(cfg: &RlvrSimConfig) -> RlvrReport {
    match () {
        _ if cfg.governor.map(|g| g.enabled).unwrap_or(false) => run_adaptive(cfg),
        _ if cfg.async_ratio > 0.0 => run_async(cfg),
        _ => run_sync(cfg),
    }
}

// ---------------------------------------------------------------------------
// Synchronous pipeline: rollout barrier -> reward -> train -> sync.
// ---------------------------------------------------------------------------

fn run_sync(cfg: &RlvrSimConfig) -> RlvrReport {
    let mut rng = Rng::new(cfg.seed);
    let mut report = RlvrReport::default();
    let mut now = 0.0f64;
    // In sync mode rollout and training share the full GPU budget.
    let gen_gpus = cfg.infer_gpus + cfg.train_gpus;

    for _ in 0..cfg.steps {
        let step_start = now;
        let (rollout_end, tokens, waste, filtered) = match cfg.scheduling {
            Scheduling::BatchRollout => sync_batch_rollout(cfg, gen_gpus, &mut rng, now),
            Scheduling::QueueSched => sync_queue_rollout(cfg, gen_gpus, &mut rng, now),
        };
        report.tokens_generated += tokens;
        report.wasted_tokens += waste;
        report.filtered_groups += filtered;
        now = rollout_end;
        // training on the full budget; then weight broadcast
        now += cfg.train.step_time(cfg.sequences_per_step(), gen_gpus);
        now += cfg.weight_sync_time;
        report.samples_consumed += cfg.sequences_per_step();
        report.step_times.push(now - step_start);
    }
    report.total_time = now;
    let cap = GpuPool::new(gen_gpus, cfg.decode.token_time, cfg.knee, cfg.max_active)
        .capacity_rate();
    report.gen_utilization = report.tokens_generated / (cap * now.max(1e-9));
    report
}

/// Batch rollout: static group placement, reward barrier, filtering
/// deficits trigger whole extra rounds (the "wasted generations" of
/// Fig 6). Returns (end_time, useful_tokens, wasted_tokens, filtered).
fn sync_batch_rollout(
    cfg: &RlvrSimConfig,
    gen_gpus: usize,
    rng: &mut Rng,
    start: f64,
) -> (f64, f64, f64, usize) {
    let g = cfg.group_size;
    let mut now = start;
    let mut qualified = 0usize;
    let mut useful = 0.0f64;
    let mut waste = 0.0f64;
    let mut filtered = 0usize;

    while qualified < cfg.n_prompts {
        let deficit = cfg.n_prompts - qualified;
        // one full synchronous round of `deficit` groups
        let max_active = cfg.max_active.max(g);
        let mut pool = GpuPool::new(gen_gpus, cfg.decode.token_time, cfg.knee, max_active);
        let mut gpu_queues: Vec<VecDeque<Vec<f64>>> = vec![VecDeque::new(); gen_gpus];
        let mut next_id = 0u64;
        let mut round_tokens = 0.0f64;
        for grp in 0..deficit {
            // no replication: the group's g candidates are one request
            // pinned to one worker, decoded in lockstep until the
            // longest finishes (num_return_sequences semantics) — the
            // short candidates pad along, wasting decode slots.
            let drawn: Vec<f64> = (0..g).map(|_| task_tokens(cfg, cfg.lengths.sample(rng))).collect();
            let lens: Vec<f64> = if cfg.replicate {
                drawn
            } else {
                let lmax = drawn.iter().cloned().fold(0.0, f64::max);
                vec![lmax; g]
            };
            round_tokens += lens.iter().sum::<f64>();
            gpu_queues[grp % gen_gpus].push_back(lens);
        }
        // admit groups while slots are available
        let mut active: Vec<usize> = vec![0; gen_gpus];
        for gi in 0..gen_gpus {
            while let Some(lens) = gpu_queues[gi].front() {
                if active[gi] + lens.len() > max_active {
                    break;
                }
                for &l in gpu_queues[gi].pop_front().unwrap().iter() {
                    pool.submit_to(gi, next_id, l, now);
                    next_id += 1;
                }
                active[gi] += g;
            }
        }
        // drain: on completion, admit more queued groups on that gpu
        let mut done_on: Vec<usize> = vec![0; gen_gpus];
        while let Some(t) = pool.peek_completion() {
            pool.pop_completion(t);
            now = t;
            // find gpu with freed slot: loads() recount
            let loads = pool.loads();
            for gi in 0..gen_gpus {
                done_on[gi] = 0; // unused; loads drives admission
                while let Some(lens) = gpu_queues[gi].front() {
                    if loads[gi] + lens.len() > max_active {
                        break;
                    }
                    for &l in gpu_queues[gi].pop_front().unwrap().iter() {
                        pool.submit_to(gi, next_id, l, now);
                        next_id += 1;
                    }
                }
            }
        }
        // reward barrier: all samples scored after generation completes
        let mut rewards = ServicePool::new(cfg.reward_workers);
        let mut reward_end = now;
        for _ in 0..deficit * g {
            reward_end = reward_end.max(rewards.submit(now, cfg.reward.sample(rng)));
        }
        now = reward_end;
        // filtering verdicts
        let mut ok = 0usize;
        for _ in 0..deficit {
            let degenerate = cfg
                .filter
                .map(|f| rng.chance(f.p_degenerate))
                .unwrap_or(false);
            if degenerate {
                filtered += 1;
            } else {
                ok += 1;
            }
        }
        if cfg.filter.is_some() {
            let frac_ok = ok as f64 / deficit as f64;
            useful += round_tokens * frac_ok;
            waste += round_tokens * (1.0 - frac_ok);
        } else {
            useful += round_tokens;
        }
        qualified += ok;
        if cfg.filter.is_none() {
            break; // no filtering: a single round always suffices
        }
    }
    (now, useful, waste, filtered)
}

/// Queue scheduling: per-sample tasks, immediate rewards, replacement
/// prompts under filtering, early termination at quota (Fig 6 right).
fn sync_queue_rollout(
    cfg: &RlvrSimConfig,
    gen_gpus: usize,
    rng: &mut Rng,
    start: f64,
) -> (f64, f64, f64, usize) {
    let g = cfg.group_size;
    let max_active = if cfg.replicate { cfg.max_active } else { cfg.max_active.max(g) };
    let mut pool = GpuPool::new(gen_gpus, cfg.decode.token_time, cfg.knee, max_active);
    let mut rewards = ServicePool::new(cfg.reward_workers);
    let mut reward_events: BinaryHeap<Reverse<(T, usize)>> = BinaryHeap::new();

    let mut groups: Vec<GroupState> = Vec::new();
    let mut task_group: HashMap<u64, usize> = HashMap::new();
    let mut task_tokens_left: HashMap<u64, f64> = HashMap::new();
    let mut pending: VecDeque<(u64, usize, f64)> = VecDeque::new(); // (id, group, tokens)
    let mut next_id = 0u64;
    let mut now = start;
    let mut useful = 0.0f64;
    let mut waste = 0.0f64;
    let mut qualified = 0usize;
    let mut filtered = 0usize;
    #[allow(unused_assignments)]
    let mut submitted_groups = 0usize;
    let extra = cfg.filter.map(|f| f.max_additional_running_prompts).unwrap_or(0);
    let max_running_groups = cfg.n_prompts + extra;

    let spawn_group = |groups: &mut Vec<GroupState>,
                           pending: &mut VecDeque<(u64, usize, f64)>,
                           next_id: &mut u64,
                           rng: &mut Rng| {
        let gi = groups.len();
        let degenerate = cfg.filter.map(|f| rng.chance(f.p_degenerate)).unwrap_or(false);
        groups.push(GroupState { done: 0, rewards_done: 0, degenerate, aborted: false });
        let drawn: Vec<f64> = (0..g).map(|_| task_tokens(cfg, cfg.lengths.sample(rng))).collect();
        // pinned multi-candidate decoding advances all g candidates in
        // lockstep until the longest completes (Section 5.1.2)
        let lmax = drawn.iter().cloned().fold(0.0, f64::max);
        for tok in drawn {
            let eff = if cfg.replicate { tok } else { lmax };
            pending.push_back((*next_id, gi, eff));
            *next_id += 1;
        }
    };

    for _ in 0..max_running_groups.min(cfg.n_prompts + extra) {
        if submitted_groups >= cfg.n_prompts + extra && cfg.filter.is_some() {
            break;
        }
        spawn_group(&mut groups, &mut pending, &mut next_id, rng);
        submitted_groups += 1;
    }

    // dispatch helper: queue scheduling = least-loaded GPU; without
    // replication a group's candidates co-reside (submitted as a unit).
    let dispatch = |pool: &mut GpuPool,
                    pending: &mut VecDeque<(u64, usize, f64)>,
                    task_group: &mut HashMap<u64, usize>,
                    task_tokens_left: &mut HashMap<u64, f64>,
                    now: f64| {
        if cfg.replicate {
            while let Some(&(id, grp, tok)) = pending.front() {
                if !pool.submit(id, tok, now) {
                    break;
                }
                pending.pop_front();
                task_group.insert(id, grp);
                task_tokens_left.insert(id, tok);
            }
        } else {
            // whole-group placement on one GPU
            while pending.len() >= 1 {
                let grp = pending.front().unwrap().1;
                let unit: Vec<(u64, usize, f64)> =
                    pending.iter().take_while(|t| t.1 == grp).cloned().collect();
                let gi = match pool
                    .loads()
                    .iter()
                    .enumerate()
                    .filter(|(_, &l)| l + unit.len() <= pool.max_active)
                    .min_by_key(|(_, &l)| l)
                {
                    Some((gi, _)) => gi,
                    None => break,
                };
                for (id, grp, tok) in unit {
                    pool.submit_to(gi, id, tok, now);
                    pending.pop_front();
                    task_group.insert(id, grp);
                    task_tokens_left.insert(id, tok);
                }
            }
        }
    };

    dispatch(&mut pool, &mut pending, &mut task_group, &mut task_tokens_left, now);

    loop {
        if qualified >= cfg.n_prompts {
            break;
        }
        let tg = pool.peek_completion();
        let tr = reward_events.peek().map(|Reverse((t, _))| t.0);
        let (t, is_gen) = match (tg, tr) {
            (Some(a), Some(b)) if a <= b => (a, true),
            (Some(a), None) => (a, true),
            (None, Some(b)) | (Some(_), Some(b)) => (b, false),
            (None, None) => break, // starved (shouldn't happen)
        };
        now = t;
        if is_gen {
            let id = pool.pop_completion(t);
            let grp = task_group[&id];
            let tok = task_tokens_left[&id];
            useful += tok;
            groups[grp].done += 1;
            // immediate reward dispatch (overlaps generation)
            let done_at = rewards.submit(now, cfg.reward.sample(rng));
            reward_events.push(Reverse((T(done_at), grp)));
            dispatch(&mut pool, &mut pending, &mut task_group, &mut task_tokens_left, now);
        } else {
            let Reverse((_, grp)) = reward_events.pop().unwrap();
            groups[grp].rewards_done += 1;
            if groups[grp].rewards_done == g {
                if groups[grp].degenerate {
                    filtered += 1;
                    // replacement prompt keeps the pipeline full
                    if cfg.filter.is_some() {
                        spawn_group(&mut groups, &mut pending, &mut next_id, rng);
                        submitted_groups += 1;
                        dispatch(&mut pool, &mut pending, &mut task_group, &mut task_tokens_left, now);
                    }
                } else {
                    qualified += 1;
                }
            }
        }
    }

    // early termination: abort surplus in-flight work (counted as waste)
    let in_flight: Vec<u64> = task_group
        .keys()
        .copied()
        .filter(|id| task_tokens_left.contains_key(id))
        .collect();
    for id in in_flight {
        if let Some(rem) = pool.abort(id, now) {
            let total = task_tokens_left[&id];
            waste += total - rem; // decoded-then-discarded work
        }
    }
    // mark degenerate groups' tokens as waste
    for grp in &groups {
        if grp.degenerate && grp.rewards_done == g {
            // their work was already counted useful on completion; move it
            // (approximate: average task length) — handled via filtered count
            let _ = grp.aborted;
        }
    }
    (now, useful, waste, filtered)
}

// ---------------------------------------------------------------------------
// Asynchronous pipeline: decoupled pools + SampleBuffer admission.
// ---------------------------------------------------------------------------

fn run_async(cfg: &RlvrSimConfig) -> RlvrReport {
    assert!(cfg.infer_gpus > 0 && cfg.train_gpus > 0, "async needs both pools");
    let mut rng = Rng::new(cfg.seed);
    let mut report = RlvrReport::default();
    let q = cfg.sequences_per_step();
    let outstanding_cap = ((1.0 + cfg.async_ratio) * q as f64).ceil() as usize;

    let mut pool = GpuPool::new(cfg.infer_gpus, cfg.decode.token_time, cfg.knee, cfg.max_active);
    let mut rewards = ServicePool::new(cfg.reward_workers);
    let mut reward_events: BinaryHeap<Reverse<(T, u64)>> = BinaryHeap::new();

    let mut now = 0.0f64;
    let mut version = 0usize;
    let mut init_version: HashMap<u64, usize> = HashMap::new();
    let mut tokens_of: HashMap<u64, f64> = HashMap::new();
    let mut buffered: VecDeque<(f64, usize)> = VecDeque::new(); // (ready, init_version)
    let mut next_id = 0u64;
    let mut outstanding = 0usize; // in flight (gen or reward) + buffered
    let mut trainer_busy_until: Option<f64> = None;
    let mut resume_at: Option<f64> = None;
    let mut last_step_end = 0.0f64;
    let mut gaps: Vec<f64> = Vec::new();
    let mut trainer_ready_since = 0.0f64;

    while report.step_times.len() < cfg.steps {
        // keep the rollout stage saturated (producer side)
        if resume_at.is_none() {
            while outstanding < outstanding_cap && pool.has_capacity() {
                let tok = task_tokens(cfg, cfg.lengths.sample(&mut rng));
                pool.submit(next_id, tok, now);
                init_version.insert(next_id, version);
                tokens_of.insert(next_id, tok);
                outstanding += 1;
                next_id += 1;
            }
        }
        // consume when a full minibatch is buffered (blocking get_batch)
        if trainer_busy_until.is_none() && buffered.len() >= q {
            for _ in 0..q {
                let (_ready, iv) = buffered.pop_front().unwrap();
                let gap = version.saturating_sub(iv);
                gaps.push(gap as f64);
                report.max_version_gap = report.max_version_gap.max(gap);
                outstanding -= 1;
            }
            report.trainer_idle += now - trainer_ready_since;
            trainer_busy_until = Some(now + cfg.train.step_time(q, cfg.train_gpus));
        }

        // next event: gen completion | reward done | trainer done | resume
        let mut best: Option<(f64, u8)> = None;
        let consider = |t: Option<f64>, kind: u8, best: &mut Option<(f64, u8)>| {
            if let Some(t) = t {
                if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                    *best = Some((t, kind));
                }
            }
        };
        consider(pool.peek_completion(), 0, &mut best);
        consider(reward_events.peek().map(|Reverse((t, _))| t.0), 1, &mut best);
        consider(trainer_busy_until, 2, &mut best);
        consider(resume_at, 3, &mut best);
        let Some((t, kind)) = best else {
            panic!("async sim deadlock: no events (cap {outstanding_cap}, outstanding {outstanding})");
        };
        now = t;
        match kind {
            0 => {
                let id = pool.pop_completion(t);
                report.tokens_generated += tokens_of[&id];
                let done_at = rewards.submit(now, cfg.reward.sample(&mut rng));
                reward_events.push(Reverse((T(done_at), id)));
            }
            1 => {
                let Reverse((_, id)) = reward_events.pop().unwrap();
                buffered.push_back((now, init_version[&id]));
            }
            2 => {
                // train step done: advance version, broadcast weights
                trainer_busy_until = None;
                trainer_ready_since = now;
                version += 1;
                report.samples_consumed += q;
                report.step_times.push(now - last_step_end);
                last_step_end = now;
                pool.set_paused(true, now);
                resume_at = Some(now + cfg.weight_sync_time);
            }
            3 => {
                pool.set_paused(false, now);
                resume_at = None;
            }
            _ => unreachable!(),
        }
    }

    report.total_time = now;
    report.mean_version_gap = crate::util::mean(&gaps);
    report.gen_utilization =
        report.tokens_generated / (pool.capacity_rate() * now.max(1e-9));
    report
}

// ---------------------------------------------------------------------------
// Adaptive pipeline: the async event loop with the governor in it.
// ---------------------------------------------------------------------------

/// `run_async`'s decoupled pools with the asynchrony governor closing
/// the staleness loop. A real [`TelemetryPlane`] runs on virtual time
/// (`window_secs` = the governor's decision interval, `gap_budget` =
/// the governor's budget) and is fed the *measured* per-window max
/// consumed version gap; each closed window may move the mode, which
/// dials the admission cap and the per-step sync barrier exactly as
/// the real `AsyncController` does.
fn run_adaptive(cfg: &RlvrSimConfig) -> RlvrReport {
    assert!(cfg.infer_gpus > 0 && cfg.train_gpus > 0, "adaptive needs both pools");
    let mut rng = Rng::new(cfg.seed);
    let mut report = RlvrReport::default();
    let q = cfg.sequences_per_step();
    let mut gcfg = cfg.governor.expect("run_adaptive requires cfg.governor");
    if gcfg.step_quota == 0 {
        gcfg.step_quota = q;
    }
    let mut gov = AsyncGovernor::new(gcfg);
    // the plane's windows ARE the governor's decision cadence, and
    // its gap watchdog threshold mirrors the governor's budget
    let mut plane = TelemetryPlane::new(TelemetryCfg {
        window_secs: gcfg.interval,
        gap_budget: gcfg.gap_budget,
        ..TelemetryCfg::on()
    });
    plane.tick(&TelemetrySignals::default()); // seed the baseline at t=0

    let cap_for = |m: AsyncMode| ((1.0 + gcfg.admission_alpha(m)) * q as f64).ceil() as usize;
    let mut outstanding_cap = cap_for(gov.mode());
    report.mode_timeline.push((0.0, gov.mode().label()));

    let mut pool = GpuPool::new(cfg.infer_gpus, cfg.decode.token_time, cfg.knee, cfg.max_active);
    let mut rewards = ServicePool::new(cfg.reward_workers);
    let mut reward_events: BinaryHeap<Reverse<(T, u64)>> = BinaryHeap::new();

    let mut now = 0.0f64;
    let mut version = 0usize;
    let mut init_version: HashMap<u64, usize> = HashMap::new();
    let mut tokens_of: HashMap<u64, f64> = HashMap::new();
    let mut buffered: VecDeque<(f64, usize)> = VecDeque::new(); // (ready, init_version)
    let mut next_id = 0u64;
    let mut outstanding = 0usize; // in flight (gen or reward) + buffered
    let mut trainer_busy_until: Option<f64> = None;
    let mut resume_at: Option<f64> = None;
    // the current training step runs the paper's suspend->train->
    // resume recipe (Sync mode, or a PeriodicBarrier boundary step)
    let mut barrier_step = false;
    let mut last_step_end = 0.0f64;
    let mut gaps: Vec<f64> = Vec::new();
    // measured staleness signal: max consumed gap since the last
    // window close — what `TelemetrySignals::version_gap` carries
    let mut window_gap_max = 0.0f64;
    let mut completed = 0u64;
    let mut trainer_ready_since = 0.0f64;

    while report.step_times.len() < cfg.steps {
        // producer side: admit while under the governed cap; a sync
        // barrier holds admission for the whole step, the weight-sync
        // pause holds it between steps
        if resume_at.is_none() && !(barrier_step && trainer_busy_until.is_some()) {
            while outstanding < outstanding_cap && pool.has_capacity() {
                let tok = task_tokens(cfg, cfg.lengths.sample(&mut rng));
                pool.submit(next_id, tok, now);
                init_version.insert(next_id, version);
                tokens_of.insert(next_id, tok);
                outstanding += 1;
                next_id += 1;
            }
        }
        // consume when a full minibatch is buffered (blocking get_batch)
        if trainer_busy_until.is_none() && buffered.len() >= q {
            for _ in 0..q {
                let (_ready, iv) = buffered.pop_front().unwrap();
                let gap = version.saturating_sub(iv);
                gaps.push(gap as f64);
                window_gap_max = window_gap_max.max(gap as f64);
                report.max_version_gap = report.max_version_gap.max(gap);
                outstanding -= 1;
            }
            report.trainer_idle += now - trainer_ready_since;
            barrier_step = gov.mode().sync_step(report.step_times.len());
            if barrier_step {
                // suspend immediately after get_batch (Section 4.3)
                pool.set_paused(true, now);
            }
            trainer_busy_until = Some(now + cfg.train.step_time(q, cfg.train_gpus));
        }

        // next event: gen completion | reward done | trainer done | resume
        let mut best: Option<(f64, u8)> = None;
        let consider = |t: Option<f64>, kind: u8, best: &mut Option<(f64, u8)>| {
            if let Some(t) = t {
                if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                    *best = Some((t, kind));
                }
            }
        };
        consider(pool.peek_completion(), 0, &mut best);
        consider(reward_events.peek().map(|Reverse((t, _))| t.0), 1, &mut best);
        consider(trainer_busy_until, 2, &mut best);
        consider(resume_at, 3, &mut best);
        let Some((t, kind)) = best else {
            panic!(
                "adaptive sim deadlock: no events (mode {}, cap {outstanding_cap}, outstanding {outstanding})",
                gov.mode().label()
            );
        };
        now = t;
        match kind {
            0 => {
                let id = pool.pop_completion(t);
                report.tokens_generated += tokens_of[&id];
                completed += 1;
                let done_at = rewards.submit(now, cfg.reward.sample(&mut rng));
                reward_events.push(Reverse((T(done_at), id)));
            }
            1 => {
                let Reverse((_, id)) = reward_events.pop().unwrap();
                buffered.push_back((now, init_version[&id]));
            }
            2 => {
                // train step done: advance version, broadcast weights
                trainer_busy_until = None;
                trainer_ready_since = now;
                version += 1;
                report.samples_consumed += q;
                report.step_times.push(now - last_step_end);
                last_step_end = now;
                pool.set_paused(true, now); // no-op if the barrier already paused
                resume_at = Some(now + cfg.weight_sync_time);
            }
            3 => {
                pool.set_paused(false, now);
                resume_at = None;
                barrier_step = false;
            }
            _ => unreachable!(),
        }

        // governor: tick the plane on the virtual clock; a closed
        // window may move the mode (and with it the admission cap)
        if plane.due(now) {
            let sig = TelemetrySignals {
                now,
                completed,
                version_gap: window_gap_max,
                ..Default::default()
            };
            if let Some(w) = plane.tick(&sig) {
                report.max_window_gap = report.max_window_gap.max(w.version_gap);
                window_gap_max = 0.0;
                if let Some(m) = gov.decide_at(w.t1, &w) {
                    report.mode_transitions += 1;
                    report.mode_timeline.push((w.t1, m.label()));
                }
                // same-rank refreshes retune the cap without counting
                // as a transition, exactly like the controller
                outstanding_cap = cap_for(gov.mode());
            }
        }
    }

    // flush the trailing partial window so the last measured gap
    // reaches the report even when the run ends mid-window
    let sig =
        TelemetrySignals { now, completed, version_gap: window_gap_max, ..Default::default() };
    if let Some(w) = plane.flush(&sig) {
        report.max_window_gap = report.max_window_gap.max(w.version_gap);
    }

    report.total_time = now;
    report.mean_version_gap = crate::util::mean(&gaps);
    report.gen_utilization =
        report.tokens_generated / (pool.capacity_rate() * now.max(1e-9));
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> RlvrSimConfig {
        let mut c = RlvrSimConfig::paper_default(4, 4);
        c.n_prompts = 16;
        c.group_size = 4;
        c.steps = 3;
        c.lengths = LengthProfile::new(500.0, 1.0, 4096);
        c.train = crate::workload::TrainCost::for_mean_len(500.0);
        c.weight_sync_time = 2.0;
        c
    }

    #[test]
    fn queue_beats_batch_rollout() {
        let mut batch = small_cfg();
        batch.scheduling = Scheduling::BatchRollout;
        batch.replicate = false;
        let mut queue = small_cfg();
        queue.scheduling = Scheduling::QueueSched;
        queue.replicate = true;
        let rb = run(&batch);
        let rq = run(&queue);
        assert!(
            rq.total_time < rb.total_time,
            "queue {} vs batch {}",
            rq.total_time,
            rb.total_time
        );
    }

    #[test]
    fn async_beats_sync() {
        let mut sync = small_cfg();
        sync.async_ratio = 0.0;
        // async splits the same total budget
        let mut asy = small_cfg();
        asy.infer_gpus = 5;
        asy.train_gpus = 3;
        asy.async_ratio = 2.0;
        let rs = run(&sync);
        let ra = run(&asy);
        assert!(
            ra.total_time < rs.total_time,
            "async {} vs sync {}",
            ra.total_time,
            rs.total_time
        );
        assert!(ra.max_version_gap as f64 <= asy.async_ratio + 1.0);
    }

    #[test]
    fn sync_consumes_exact_quota() {
        let cfg = small_cfg();
        let r = run(&cfg);
        assert_eq!(r.samples_consumed, cfg.sequences_per_step() * cfg.steps);
        assert_eq!(r.step_times.len(), cfg.steps);
        assert!(r.gen_utilization > 0.0 && r.gen_utilization <= 1.0);
    }

    #[test]
    fn filtering_discards_and_replaces() {
        let mut cfg = small_cfg();
        cfg.filter = Some(FilterCfg { p_degenerate: 0.5, max_additional_running_prompts: 8 });
        cfg.steps = 1;
        let r = run(&cfg);
        assert!(r.filtered_groups > 0, "expected some degenerate groups");
        assert_eq!(r.samples_consumed, cfg.sequences_per_step());
    }

    #[test]
    fn filtering_hurts_batch_more_than_queue() {
        let mut batch = small_cfg();
        batch.scheduling = Scheduling::BatchRollout;
        batch.replicate = false;
        batch.filter = Some(FilterCfg { p_degenerate: 0.4, max_additional_running_prompts: 16 });
        batch.steps = 2;
        let mut queue = batch.clone();
        queue.scheduling = Scheduling::QueueSched;
        queue.replicate = true;
        let rb = run(&batch);
        let rq = run(&queue);
        assert!(rq.total_time < rb.total_time * 0.8, "queue {} batch {}", rq.total_time, rb.total_time);
    }

    #[test]
    fn replication_helps_grouped_decoding() {
        let mut no_rep = small_cfg();
        no_rep.group_size = 16;
        no_rep.n_prompts = 8;
        no_rep.replicate = false;
        let mut rep = no_rep.clone();
        rep.replicate = true;
        let a = run(&no_rep);
        let b = run(&rep);
        assert!(b.total_time <= a.total_time, "rep {} vs none {}", b.total_time, a.total_time);
    }

    #[test]
    fn determinism() {
        let cfg = small_cfg();
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.step_times, b.step_times);
    }

    /// Split matching `async_beats_sync` so the adaptive arm is
    /// compared against fixed arms on the same hardware.
    fn adaptive_base() -> RlvrSimConfig {
        let mut c = small_cfg();
        c.infer_gpus = 5;
        c.train_gpus = 3;
        c
    }

    #[test]
    fn adaptive_matches_best_fixed_arm_within_budget() {
        // acceptance: with a budget the loosest arm already respects,
        // the governor must stay fully async and match the best fixed
        // async_ratio's throughput — the adaptive arm costs nothing
        // when the budget is not binding
        let budget = 6.0;
        let mut fixed_best = 0.0f64;
        for alpha in [0.0, 1.0, 2.0] {
            let mut c = adaptive_base();
            c.async_ratio = alpha;
            let r = run(&c);
            if r.max_version_gap as f64 <= budget {
                fixed_best = fixed_best.max(r.samples_per_hour());
            }
        }
        assert!(fixed_best > 0.0, "at least one fixed arm must fit the budget");
        let mut ad = adaptive_base();
        ad.governor = Some(GovernorCfg {
            gap_budget: budget,
            alpha_max: 2.0,
            interval: 5.0,
            cooldown: 10.0,
            ..GovernorCfg::on()
        });
        let ra = run(&ad);
        assert!(
            ra.max_window_gap <= budget,
            "measured window gap {} must stay inside budget {budget}",
            ra.max_window_gap
        );
        assert!(ra.max_version_gap as f64 <= budget);
        assert_eq!(ra.mode_timeline[0].1, "async(192)", "starts optimistic: (1+2)*64");
        assert!(
            ra.samples_per_hour() >= 0.98 * fixed_best,
            "adaptive {} must match best budget-respecting fixed arm {}",
            ra.samples_per_hour(),
            fixed_best
        );
    }

    #[test]
    fn tight_budget_forces_transitions_and_bounds_gap() {
        // budget 2 with alpha_max 4: the theory clamp caps effective
        // alpha at 1, and the measured gap hitting the budget must
        // drive at least one mode transition (the emergency Sync path)
        let mut c = adaptive_base();
        c.steps = 8;
        c.governor = Some(GovernorCfg {
            gap_budget: 2.0,
            alpha_max: 4.0,
            interval: 2.0,
            cooldown: 4.0,
            ..GovernorCfg::on()
        });
        let r = run(&c);
        assert_eq!(r.samples_consumed, c.sequences_per_step() * c.steps);
        assert!(
            r.mode_transitions >= 1,
            "a binding budget must move the mode at least once: {:?}",
            r.mode_timeline
        );
        assert!(
            r.max_version_gap as f64 <= 2.0 + 1.0,
            "gap {} may exceed the budget by at most one-window detection lag",
            r.max_version_gap
        );
        assert!(r.max_window_gap <= 2.0 + 1.0);
    }

    #[test]
    fn adaptive_determinism() {
        let mut c = adaptive_base();
        c.steps = 6;
        c.governor = Some(GovernorCfg {
            gap_budget: 2.0,
            alpha_max: 4.0,
            interval: 2.0,
            cooldown: 4.0,
            ..GovernorCfg::on()
        });
        let a = run(&c);
        let b = run(&c);
        assert_eq!(a.total_time, b.total_time);
        assert_eq!(a.step_times, b.step_times);
        assert_eq!(a.mode_timeline, b.mode_timeline);
        assert_eq!(a.mode_transitions, b.mode_transitions);
        assert_eq!(a.max_version_gap, b.max_version_gap);
    }
}
