//! Virtual-time discrete-event cluster simulator (DESIGN.md §3).
//!
//! The paper's scale experiments ran on 16-128 GPUs; this substrate
//! reproduces the *timing* phenomena (long-tail stragglers, bandwidth-
//! bound decode, rollout/train overlap, queueing) deterministically on
//! one CPU. The coordination policies are shared with `coordinator/`,
//! which drives the real PJRT engine.

pub mod agentic;
pub mod fleet;
pub mod queue;
pub mod rlvr;
