//! Virtual-time simulator of the agentic RL pipeline (Section 5.2):
//! multi-turn trajectories against latency-heavy, failure-prone
//! environments; environment-level asynchronous rollout (5.2.1) and
//! redundant environment rollout (5.2.2). Drives Figs 9, 10, 11.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::sim::queue::{GpuPool, T};
use crate::util::rng::Rng;
use crate::workload::{DecodeCost, EnvLatency, FailureModel, TrainCost};

#[derive(Clone, Debug)]
pub struct AgenticSimConfig {
    pub gen_gpus: usize,
    pub knee: usize,
    pub max_active: usize,
    /// env fleet: may exceed the quota (redundant rollout)
    pub num_env_groups: usize,
    pub group_size: usize,
    /// base quota: first `quota_groups` groups each reaching
    /// `quota_group_size` finished trajectories complete the rollout
    pub quota_groups: usize,
    pub quota_group_size: usize,
    pub turns: usize,
    pub tokens_per_action: usize,
    pub decode: DecodeCost,
    pub env_latency: EnvLatency,
    pub failures: FailureModel,
    /// environment-level asynchronous rollout vs lockstep barriers
    pub env_async: bool,
    /// fail-stop detection + restart delay
    pub retry_timeout: f64,
    /// probability an entire env group's backend dies mid-rollout
    /// (groups share a container/service; spare *groups* cover this,
    /// spare members do not — Section 5.2.2)
    pub group_fail_stop_prob: f64,
    pub seed: u64,
}

impl AgenticSimConfig {
    /// ALFWorld-like defaults (paper Appendix A: 30 steps).
    pub fn alfworld(gen_gpus: usize) -> Self {
        AgenticSimConfig {
            gen_gpus,
            knee: 16,
            max_active: 64,
            num_env_groups: 8,
            group_size: 16,
            quota_groups: 8,
            quota_group_size: 16,
            turns: 30,
            tokens_per_action: 150,
            decode: DecodeCost::qwen3_8b(),
            env_latency: EnvLatency::gaussian(3.0, 2.0),
            failures: FailureModel::alfworld_like(),
            env_async: true,
            retry_timeout: 60.0,
            group_fail_stop_prob: 0.0,
            seed: 11,
        }
    }

    /// SWE-like defaults (50 steps, long env latencies, frequent fails).
    pub fn swe(gen_gpus: usize) -> Self {
        AgenticSimConfig {
            gen_gpus,
            knee: 16,
            max_active: 64,
            num_env_groups: 8,
            group_size: 16,
            quota_groups: 8,
            quota_group_size: 16,
            turns: 50,
            tokens_per_action: 700,
            decode: DecodeCost::qwen3_8b(),
            env_latency: EnvLatency::gaussian(12.0, 8.0),
            failures: FailureModel::swe_like(),
            env_async: true,
            retry_timeout: 120.0,
            group_fail_stop_prob: 0.02,
            seed: 13,
        }
    }

    pub fn total_envs(&self) -> usize {
        self.num_env_groups * self.group_size
    }

    pub fn quota(&self) -> usize {
        self.quota_groups * self.quota_group_size
    }
}

#[derive(Clone, Debug, Default)]
pub struct AgenticReport {
    /// rollout makespan for one collection step
    pub rollout_time: f64,
    pub trajectories_done: usize,
    pub restarts: usize,
    pub gen_utilization: f64,
    pub tokens_generated: f64,
    /// decode tokens burned by fail-stop restarts: every turn a
    /// trajectory had decoded before its env died is re-decoded from
    /// scratch (the abort-and-resubmit bill the resumable-task
    /// coordinator surface avoids for migrations)
    pub wasted_tokens: f64,
}

/// One rollout-collection step.
pub fn run_rollout(cfg: &AgenticSimConfig) -> AgenticReport {
    assert!(cfg.num_env_groups >= cfg.quota_groups, "fleet smaller than quota");
    assert!(cfg.group_size >= cfg.quota_group_size, "groups smaller than quota");
    if cfg.env_async {
        run_env_async(cfg)
    } else {
        run_lockstep(cfg)
    }
}

struct Traj {
    group: usize,
    turn: usize,
    /// turn at which this trajectory fail-stops (usize::MAX = healthy)
    dead_at: usize,
    done: bool,
}

fn draw_dead_at(cfg: &AgenticSimConfig, rng: &mut Rng) -> usize {
    if rng.chance(cfg.failures.fail_stop_prob) {
        rng.below(cfg.turns.max(1))
    } else {
        usize::MAX
    }
}

/// Per-group backend death turns (usize::MAX = healthy group).
fn draw_group_dead(cfg: &AgenticSimConfig, rng: &mut Rng) -> Vec<usize> {
    (0..cfg.num_env_groups)
        .map(|_| {
            if rng.chance(cfg.group_fail_stop_prob) {
                rng.below(cfg.turns.max(1))
            } else {
                usize::MAX
            }
        })
        .collect()
}

fn env_step_latency(cfg: &AgenticSimConfig, rng: &mut Rng) -> f64 {
    let mut lat = cfg.env_latency.sample(rng);
    if rng.chance(cfg.failures.fail_slow_prob) {
        lat *= cfg.failures.fail_slow_factor;
    }
    lat
}

fn quota_met(group_done: &[usize], cfg: &AgenticSimConfig) -> bool {
    group_done.iter().filter(|&&d| d >= cfg.quota_group_size).count() >= cfg.quota_groups
}

// ---------------------------------------------------------------------------
// Lockstep baseline: per-turn barriers across the whole fleet.
// ---------------------------------------------------------------------------

fn run_lockstep(cfg: &AgenticSimConfig) -> AgenticReport {
    let mut rng = Rng::new(cfg.seed);
    let mut report = AgenticReport::default();
    let group_dead = draw_group_dead(cfg, &mut rng);
    let mut trajs: Vec<Traj> = (0..cfg.total_envs())
        .map(|i| Traj {
            group: i / cfg.group_size,
            turn: 0,
            dead_at: draw_dead_at(cfg, &mut rng).min(group_dead[i / cfg.group_size]),
            done: false,
        })
        .collect();
    let mut group_done = vec![0usize; cfg.num_env_groups];
    let mut now = 0.0f64;
    let cap_rate = cfg.gen_gpus as f64 * cfg.knee as f64 / cfg.decode.token_time;

    while !quota_met(&group_done, cfg) {
        // gen barrier: all unfinished trajectories decode one action as
        // one batch over the pool; barrier time = last completion.
        let alive: Vec<usize> = trajs.iter().enumerate().filter(|(_, t)| !t.done).map(|(i, _)| i).collect();
        if alive.is_empty() {
            break;
        }
        let mut pool = GpuPool::new(cfg.gen_gpus, cfg.decode.token_time, cfg.knee, cfg.max_active);
        let tokens = cfg.tokens_per_action as f64 + cfg.decode.prefill_time / cfg.decode.token_time;
        let mut queue: Vec<u64> = Vec::new();
        for (j, &ti) in alive.iter().enumerate() {
            let _ = ti;
            if !pool.submit(j as u64, tokens, 0.0) {
                queue.push(j as u64);
            }
        }
        let mut gen_end = 0.0f64;
        while let Some(t) = pool.peek_completion() {
            pool.pop_completion(t);
            gen_end = t;
            if let Some(id) = queue.pop() {
                pool.submit(id, tokens, t);
            }
        }
        now += gen_end;
        report.tokens_generated += alive.len() as f64 * tokens;

        // env barrier: wait for the slowest env step; fail-stopped
        // trajectories hold the barrier for retry_timeout, then restart.
        let mut barrier = 0.0f64;
        for &ti in &alive {
            let t = &mut trajs[ti];
            if t.turn >= t.dead_at {
                barrier = barrier.max(cfg.retry_timeout);
                // every action decoded for this trajectory (its turns
                // so far plus this round's) restarts from scratch
                report.wasted_tokens += (t.turn as f64 + 1.0) * tokens;
                t.turn = 0;
                t.dead_at = draw_dead_at(cfg, &mut rng);
                report.restarts += 1;
                continue;
            }
            barrier = barrier.max(env_step_latency(cfg, &mut rng));
            t.turn += 1;
            if t.turn >= cfg.turns {
                t.done = true;
                group_done[t.group] += 1;
                report.trajectories_done += 1;
            }
        }
        now += barrier;
    }
    report.rollout_time = now;
    report.gen_utilization = report.tokens_generated / (cap_rate * now.max(1e-9));
    report
}

// ---------------------------------------------------------------------------
// Environment-level asynchronous rollout: per-trajectory progression.
// ---------------------------------------------------------------------------

fn run_env_async(cfg: &AgenticSimConfig) -> AgenticReport {
    let mut rng = Rng::new(cfg.seed);
    let mut report = AgenticReport::default();
    let mut pool = GpuPool::new(cfg.gen_gpus, cfg.decode.token_time, cfg.knee, cfg.max_active);
    let tokens = cfg.tokens_per_action as f64 + cfg.decode.prefill_time / cfg.decode.token_time;

    let group_dead = draw_group_dead(cfg, &mut rng);
    let mut trajs: Vec<Traj> = (0..cfg.total_envs())
        .map(|i| Traj {
            group: i / cfg.group_size,
            turn: 0,
            dead_at: draw_dead_at(cfg, &mut rng).min(group_dead[i / cfg.group_size]),
            done: false,
        })
        .collect();
    let mut group_done = vec![0usize; cfg.num_env_groups];
    // events: (time, traj, kind) kind 0 = env step done / restart ready
    let mut env_events: BinaryHeap<Reverse<(T, usize)>> = BinaryHeap::new();
    let mut gen_queue: std::collections::VecDeque<usize> = (0..trajs.len()).collect();
    let mut now = 0.0f64;

    loop {
        // dispatch pending generation requests (queue scheduling)
        while let Some(&ti) = gen_queue.front() {
            if !pool.submit(ti as u64, tokens, now) {
                break;
            }
            gen_queue.pop_front();
        }
        if quota_met(&group_done, cfg) {
            break;
        }
        let tg = pool.peek_completion();
        let te = env_events.peek().map(|Reverse((t, _))| t.0);
        let (t, is_gen) = match (tg, te) {
            (Some(a), Some(b)) if a <= b => (a, true),
            (Some(a), None) => (a, true),
            (None, Some(b)) | (Some(_), Some(b)) => (b, false),
            (None, None) => break,
        };
        now = t;
        if is_gen {
            let ti = pool.pop_completion(t) as usize;
            report.tokens_generated += tokens;
            let tr = &mut trajs[ti];
            if tr.turn >= tr.dead_at {
                // env is dead: action times out, restart after detection
                env_events.push(Reverse((T(now + cfg.retry_timeout), ti)));
                report.wasted_tokens += (tr.turn as f64 + 1.0) * tokens;
                tr.turn = usize::MAX - 1; // marker: restarting
                report.restarts += 1;
            } else {
                env_events.push(Reverse((T(now + env_step_latency(cfg, &mut rng)), ti)));
            }
        } else {
            let Reverse((_, ti)) = env_events.pop().unwrap();
            let tr = &mut trajs[ti];
            if tr.turn == usize::MAX - 1 {
                // restart fresh trajectory in the same env slot
                tr.turn = 0;
                tr.dead_at = draw_dead_at(cfg, &mut rng);
                gen_queue.push_back(ti);
                continue;
            }
            tr.turn += 1;
            if tr.turn >= cfg.turns {
                tr.done = true;
                if group_done[tr.group] < cfg.group_size {
                    group_done[tr.group] += 1;
                }
                report.trajectories_done += 1;
            } else {
                gen_queue.push_back(ti);
            }
        }
    }
    report.rollout_time = now;
    report.gen_utilization =
        report.tokens_generated / (pool.capacity_rate() * now.max(1e-9));
    report
}

// ---------------------------------------------------------------------------
// End-to-end training-time model (Fig 11): rollout + train per step,
// overlapped under the async architecture.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug)]
pub struct EndToEnd {
    pub steps: usize,
    pub train: TrainCost,
    pub train_gpus: usize,
    pub weight_sync_time: f64,
    /// rollout-train decoupling on? (async_generation_ratio > 0)
    pub decoupled: bool,
}

impl EndToEnd {
    /// Total training hours for `steps` iterations given a per-step
    /// rollout makespan distribution (re-sampled per step via seeds).
    pub fn total_time(&self, cfg: &AgenticSimConfig) -> f64 {
        let quota = cfg.quota();
        let t_train = self.train.step_time(quota, self.train_gpus) + self.weight_sync_time;
        let mut total = 0.0f64;
        let mut first_rollout = 0.0f64;
        for s in 0..self.steps {
            let mut c = cfg.clone();
            c.seed = cfg.seed.wrapping_add(s as u64 * 7919);
            let r = run_rollout(&c);
            if s == 0 {
                first_rollout = r.rollout_time;
            }
            if self.decoupled {
                // producer-consumer overlap: step is gated by the slower
                // of continuous collection and training (Prop 2)
                total += r.rollout_time.max(t_train);
            } else {
                total += r.rollout_time + t_train;
            }
        }
        if self.decoupled {
            total += first_rollout.min(t_train); // pipeline fill
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small(env_async: bool) -> AgenticSimConfig {
        let mut c = AgenticSimConfig::alfworld(4);
        c.num_env_groups = 4;
        c.group_size = 8;
        c.quota_groups = 4;
        c.quota_group_size = 8;
        c.turns = 6;
        c.env_async = env_async;
        c.failures = FailureModel::none();
        c
    }

    #[test]
    fn env_async_beats_lockstep() {
        let a = run_rollout(&small(true));
        let b = run_rollout(&small(false));
        assert!(a.rollout_time < b.rollout_time, "async {} lockstep {}", a.rollout_time, b.rollout_time);
        assert_eq!(a.trajectories_done, 32);
    }

    #[test]
    fn speedup_grows_with_latency_variance() {
        let speedup = |std: f64| {
            let mut c = small(true);
            c.env_latency = EnvLatency::gaussian(10.0, std);
            let a = run_rollout(&c);
            c.env_async = false;
            let b = run_rollout(&c);
            b.rollout_time / a.rollout_time
        };
        let lo = speedup(1.0);
        let hi = speedup(8.0);
        assert!(hi > lo, "variance should amplify async benefit: {lo} vs {hi}");
    }

    #[test]
    fn redundancy_mitigates_failstop() {
        let mut base = small(true);
        base.failures = FailureModel { fail_slow_prob: 0.1, fail_slow_factor: 6.0, fail_stop_prob: 0.08 };
        base.retry_timeout = 120.0;
        let exact = run_rollout(&base);
        let mut red = base.clone();
        red.num_env_groups = 6; // fleet > quota
        red.group_size = 10;
        let r = run_rollout(&red);
        assert!(
            r.rollout_time < exact.rollout_time,
            "redundant {} vs exact {}",
            r.rollout_time,
            exact.rollout_time
        );
    }

    #[test]
    fn decoupling_shortens_end_to_end() {
        let cfg = small(true);
        let e2e_sync = EndToEnd {
            steps: 3,
            train: TrainCost::qwen3_8b(),
            train_gpus: 4,
            weight_sync_time: 2.0,
            decoupled: false,
        };
        let mut e2e_async = e2e_sync;
        e2e_async.decoupled = true;
        let ts = e2e_sync.total_time(&cfg);
        let ta = e2e_async.total_time(&cfg);
        assert!(ta < ts, "async {ta} sync {ts}");
    }

    #[test]
    fn determinism() {
        let cfg = small(true);
        assert_eq!(run_rollout(&cfg).rollout_time, run_rollout(&cfg).rollout_time);
    }
}
