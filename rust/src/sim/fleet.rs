//! Virtual-time mirror of the coordinator's inference fleet
//! (`coordinator/fleet.rs`): N single-GPU decode replicas behind the
//! *same* `Router` the real pool uses, driven by closed-loop clients
//! (stand-ins for EnvManagers) — or an open-loop [`BurstTrace`] — over
//! the paper's long-tail response lengths.
//!
//! This is where the fleet-level phenomena are reproduced at scale
//! without hardware (DESIGN.md §3):
//!
//!   * round-robin placement stacks short requests behind 30k-token
//!     stragglers, while least-outstanding routing redirects inflow to
//!     the replicas that are actually draining — lower makespan and
//!     tail latency under skewed lengths;
//!   * queue scheduling (pool-side backpressure at the decode-slot
//!     cap) bounds per-replica co-residency, avoiding the
//!     processor-sharing slowdown beyond the bandwidth knee;
//!   * EWMA latency-aware routing measures each replica's delivered
//!     token rate (the same `Router::on_completion` feed the real
//!     pool's collectors use) and starves fail-slow / heterogeneous
//!     replicas (`slow_replica`) that least-outstanding keeps feeding;
//!   * staggered (rolling) weight sync keeps N-1 replicas decoding
//!     through a model update; broadcast sync stalls all of them;
//!   * *prefix-salvaging migration* (`hang_timeout` > 0): a request
//!     that runs past the watchdog deadline is aborted off its replica
//!     and resubmitted elsewhere through the same saturation probe +
//!     exclusion-routing the real `LlmProxyPool::migrate` uses — move
//!     when a peer has a free decode window, *ReclaimInPlace* when the
//!     pool is saturated (`reclaim_in_place`: salvage + re-enter pool
//!     admission, so the paused request escapes to whichever window
//!     frees first), re-arm when there is no peer at all. With
//!     `partial_migration` only the *remaining* tokens are re-decoded,
//!     plus the cost of replaying the salvaged prefix through prefill
//!     (`prefill_time_per_token`, the KV rebuild a real engine pays on
//!     resume); the from-scratch arm re-decodes everything and burns
//!     the progress into `wasted_tokens`;
//!   * *fleet-wide KV-prefix reuse* (`kv_cache.enabled`): each replica
//!     caches conversation KV under a byte budget; routing prefers the
//!     replica holding the longest cached prefix (the same cache-aware
//!     override the real `Router` applies), so multi-turn follow-ups
//!     (`multi_turn` > 1) and in-place salvage resume where the KV
//!     lives and replay only the *uncached* context through prefill;
//!   * *elastic autoscaling* (`autoscale: Some(cfg)`): the *same*
//!     `coordinator::autoscaler::decide` function that drives the real
//!     pool runs on the virtual clock, growing the fleet into bursts
//!     and salvage-draining it back through troughs. Replica-seconds
//!     are integrated per serving interval — the currency
//!     `benches/fig_autoscale.rs` compares against static fleets;
//!   * *length-aware tail scheduling* (`route_policy: TailAware`): the
//!     *same* `LengthPredictor` the real pool shares across its hot
//!     paths runs on virtual completions, feeding tail-aware routing
//!     hints, predicted-remaining-token load scores, and the two-class
//!     (shortest-predicted-first within a long-work reservation, with
//!     an aging bound) admission order mirrored from the proxy's
//!     decode loop. Any other policy keeps the exact pre-predictor
//!     FIFO event sequence, so `benches/fig_tail_latency.rs` can read
//!     fifo-vs-tail-aware arms off identical workloads.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};
use std::sync::Arc;

use crate::coordinator::async_governor::{AsyncGovernor, GovernorCfg};
use crate::coordinator::autoscaler::{AutoscaleCfg, Autoscaler, PoolSignals, ScaleDecision};
use crate::coordinator::kv_index::KvCacheCfg;
use crate::coordinator::length_predictor::{LengthPredictor, PredictorCfg};
use crate::coordinator::routing::{ReplicaLoad, RouteHint, RoutePolicy, Router};
use crate::metrics::telemetry::{
    TelemetryAlert, TelemetryCfg, TelemetryPlane, TelemetrySignals, TelemetryWindow,
};
use crate::metrics::trace::{AttrSnapshot, EventPhase, FlightRecorder};
use crate::sim::queue::{GpuPool, T};
use crate::util::rng::Rng;
use crate::workload::{BurstTrace, DecodeCost, LengthProfile};

/// Give up migrating a request after this many moves (mirrors the
/// engine's MAX_GEN_MIGRATIONS): a genuinely long generation must be
/// allowed to finish somewhere.
const MAX_SIM_MIGRATIONS: u32 = 3;

/// Starvation-proof aging bound for the two-class admission order
/// (mirrors the proxy's AGING_LIMIT): an entry passed over this many
/// dispatch rounds is admitted next regardless of class.
const SIM_AGING_LIMIT: u32 = 32;

#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    pub num_replicas: usize,
    pub route_policy: RoutePolicy,
    /// staggered weight sync (one replica paused at a time) vs
    /// broadcast (all paused together)
    pub rolling_update: bool,
    /// closed-loop clients (EnvManager stand-ins), each with one
    /// request in flight; ignored when `arrivals` is set
    pub clients: usize,
    /// total requests to complete (the sweep's fixed work budget)
    pub total_requests: usize,
    /// full-speed co-resident sequences per replica
    pub knee: usize,
    /// decode-slot admission cap (what queue scheduling routes against)
    pub max_active: usize,
    pub lengths: LengthProfile,
    pub decode: DecodeCost,
    /// virtual seconds between weight-sync waves (0 = never sync)
    pub sync_interval: f64,
    /// pause duration per replica per wave
    pub sync_time: f64,
    /// heterogeneous fleet: replica `index` decodes `factor`x slower
    /// (fail-slow hardware, thermal throttling, a noisy neighbor)
    pub slow_replica: Option<(usize, f64)>,
    /// migration watchdog: a request still running this many virtual
    /// seconds after dispatch is moved to another replica (0 = never)
    pub hang_timeout: f64,
    /// carry the decoded prefix across migration (resume) vs re-decode
    /// from scratch
    pub partial_migration: bool,
    /// saturated watchdog fires salvage + requeue in place (the real
    /// pool's ReclaimInPlace arm); false = re-arm and wait
    pub reclaim_in_place: bool,
    /// shortest decoded prefix (token units) worth salvaging
    pub min_salvage_tokens: f64,
    /// seconds per salvaged token replayed through prefill when a
    /// resumed request re-dispatches (the KV rebuild bill; 0 = free)
    pub prefill_time_per_token: f64,
    /// fleet-wide KV-prefix reuse (mirrors `PoolCfg::kv_cache`): each
    /// replica caches conversation KV up to `budget_tokens()`, routing
    /// prefers the replica holding the longest cached prefix, and only
    /// the uncached portion of a request's context is replayed through
    /// prefill at placement. Disabled by default — the legacy event
    /// sequence is untouched.
    pub kv_cache: KvCacheCfg,
    /// turns per conversation for closed-loop clients: each completion
    /// chains a follow-up request whose context is the conversation so
    /// far (multi-turn agentic episodes). 1 = the legacy single-turn
    /// workload; open-loop arrivals always start fresh conversations.
    pub multi_turn: usize,
    /// open-loop bursty arrivals; `None` = closed-loop clients
    pub arrivals: Option<BurstTrace>,
    /// elastic fleet: run `coordinator::autoscaler::decide` on the
    /// virtual clock between `min_replicas` and `max_replicas`;
    /// `None` = static `num_replicas`
    pub autoscale: Option<AutoscaleCfg>,
    /// flight recorder fed with *virtual*-timestamp lifecycle events
    /// (`FlightRecorder::emit_at`): the same schema the real pool
    /// records, so a sim run exports the identical Chrome trace /
    /// JSONL shape. `None` = no tracing (zero overhead).
    pub trace: Option<Arc<FlightRecorder>>,
    /// live telemetry plane on the virtual clock: the *same*
    /// `TelemetryPlane` the real controller ticks, fed cumulative sim
    /// signals after every event and flushed at the end of the run so
    /// the window timeline tiles `[0, makespan]`. Windows land in
    /// [`FleetSimReport::telemetry`]. `None` = off; either way the
    /// plane is a pure observer — it never touches the event loop
    /// (asserted by `telemetry_is_a_pure_observer`).
    pub telemetry: Option<TelemetryCfg>,
    /// adaptive asynchrony governor on the virtual clock: requests
    /// carry the weights version they were dispatched under, completed
    /// requests feed the measured gap into the telemetry windows, and
    /// each closed window may move the mode. Tight modes (rank >= 2,
    /// i.e. `PeriodicBarrier`/`Sync`) force fleet-wide *broadcast*
    /// sync waves even when `rolling_update` is set — the barrier
    /// semantics. When enabled without a `telemetry` block, a plane is
    /// derived from the governor's cadence/budget.
    pub governor: Option<GovernorCfg>,
    /// generation-length predictor knobs; scheduling acts on its output
    /// only under `RoutePolicy::TailAware` (other policies keep the
    /// exact legacy FIFO event order)
    pub predictor: PredictorCfg,
    pub seed: u64,
}

impl FleetSimConfig {
    /// Paper-flavored defaults, scaled to the replica count so each
    /// replica sees the same offered load across a sweep.
    pub fn default_fleet(num_replicas: usize) -> Self {
        FleetSimConfig {
            num_replicas,
            route_policy: RoutePolicy::LeastOutstanding,
            rolling_update: true,
            clients: 24 * num_replicas,
            total_requests: 150 * num_replicas,
            knee: 16,
            max_active: 48,
            lengths: LengthProfile::qwen3_base(),
            decode: DecodeCost::qwen3_8b(),
            sync_interval: 120.0,
            sync_time: 10.0,
            slow_replica: None,
            hang_timeout: 0.0,
            partial_migration: true,
            reclaim_in_place: true,
            min_salvage_tokens: 1.0,
            // ~40x faster than the 8 ms/token decode: a realistic KV
            // rebuild rate, so salvage is cheap but not free
            prefill_time_per_token: 2e-4,
            kv_cache: KvCacheCfg::disabled(),
            multi_turn: 1,
            arrivals: None,
            autoscale: None,
            trace: None,
            telemetry: None,
            governor: None,
            predictor: PredictorCfg::default(),
            seed: 17,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct FleetSimReport {
    pub makespan: f64,
    pub completed: usize,
    /// decode work performed, in short-context token units
    pub tokens: f64,
    /// tokens per virtual second over the whole run
    pub throughput: f64,
    pub mean_latency: f64,
    /// episode-completion latency quantiles — the tail-latency bench's
    /// scoreboard (submit -> done on the virtual clock)
    pub p50_latency: f64,
    pub p90_latency: f64,
    pub p99_latency: f64,
    pub per_replica_util: Vec<f64>,
    /// fewest replicas decoding at any instant inside a sync window
    /// (rolling => N-1, broadcast => 0)
    pub min_decoding_during_sync: usize,
    pub sync_waves: usize,
    /// largest per-replica co-residency observed (queue scheduling
    /// keeps this <= max_active)
    pub max_inflight: usize,
    /// largest pool-side queue observed (backpressure depth)
    pub pool_queue_max: usize,
    /// requests placed on each replica (routing share)
    pub routed: Vec<usize>,
    /// watchdog migrations performed
    pub migrations: usize,
    /// watchdog firings resolved as ReclaimInPlace (salvage + requeue,
    /// no target replica reserved — the saturated-pool arm)
    pub reclaims_in_place: usize,
    /// virtual seconds the autoscale shrink's salvaged requests spent
    /// between being RECLAIMed off the retiring replica and being
    /// re-dispatched onto a survivor (summed over drained requests,
    /// measured at the actual re-placement). With the non-blocking
    /// drain and spare survivor capacity this is exactly 0.0 — every
    /// victim re-dispatches at the same virtual instant the shrink
    /// fires. A reintroduced synchronous SALVAGE_WAIT (a deferred
    /// handoff event, a drain that parks victims behind a delay)
    /// shows up here as positive time; asserted == 0 by
    /// `autoscale_shrink_blocks_zero_virtual_time`.
    pub drain_virtual_secs: f64,
    /// decoded tokens carried across migrations/drains (partial arm)
    pub salvaged_tokens: f64,
    /// decoded tokens re-decoded from scratch (the from-scratch bill)
    pub wasted_tokens: f64,
    /// salvaged tokens replayed through prefill on re-dispatch (each
    /// costs `prefill_time_per_token` of extra decode-equivalent work)
    pub prefill_replay_tokens: f64,
    /// placements that found cached conversation KV on the chosen
    /// replica (kv_cache arm only)
    pub kv_hits: u64,
    /// context-bearing placements that found no cached prefix
    pub kv_misses: u64,
    /// context tokens served from a replica's KV cache instead of
    /// being replayed through prefill
    pub kv_hit_tokens: f64,
    /// cached conversations dropped to stay under the KV byte budget
    pub kv_evictions: u64,
    /// autoscaler grow actions (replicas added)
    pub scale_ups: usize,
    /// autoscaler shrink actions (replicas drained)
    pub scale_downs: usize,
    /// most replicas serving at once
    pub peak_replicas: usize,
    /// replicas serving when the run ended
    pub final_replicas: usize,
    /// integral of serving replicas over time — the provisioning bill
    /// an elastic fleet holds below a static peak-sized one
    pub replica_seconds: f64,
    /// where every serving replica-second went, mirrored from the real
    /// pool's time attribution: `weight_sync` is the exact pause
    /// integral, `prefill`/`prefill_replay` are priced at full speed
    /// (`prefill_time` per completion, `prefill_time_per_token` per
    /// replayed token — processor-sharing slowdown above the knee is
    /// absorbed by `decode_busy`), `draining` is 0 (sim drains are
    /// instantaneous), and `idle_bubble` is the residual. By
    /// construction `attr.total() == replica_seconds` on a static
    /// fleet (no sync wave can touch a drained slot).
    pub attr: AttrSnapshot,
    /// closed telemetry windows — the windowed verdict timeline, in
    /// virtual-time order (empty unless `telemetry` was configured)
    pub telemetry: Vec<TelemetryWindow>,
    /// every watchdog alert transition across the run, in order
    pub telemetry_alerts: Vec<TelemetryAlert>,
    /// governor mode timeline: `(virtual_time, mode_label)` — seeded
    /// with the starting mode at t=0, one entry per transition after
    /// (empty unless `governor` was configured)
    pub mode_timeline: Vec<(f64, String)>,
    /// governor transitions across the run (`mode_timeline.len() - 1`
    /// when governed)
    pub mode_transitions: usize,
}

#[derive(Clone, Copy)]
enum SyncPhase {
    Idle { next: f64 },
    Broadcast { until: f64 },
    Rolling { replica: usize, until: f64 },
}

/// Event tags for tie-breaking at equal virtual times: lower fires
/// first. Watchdog before completions (matching the pre-elastic event
/// order), arrivals before completions at the same instant, scale
/// decisions after the work that triggered them, sync last.
const EV_DOG: u8 = 0;
const EV_ARRIVE: u8 = 1;
const EV_GEN: u8 = 2;
const EV_SCALE: u8 = 3;
const EV_SYNC: u8 = 4;

/// A pool-queued request (the sim's `Pending` mirror). `avoid` mirrors
/// the real pool's salvage preference; `group` is the prompt-group key
/// fed to the length predictor; `passes` counts dispatch rounds the
/// two-class admission passed this entry over (aging bound input).
#[derive(Clone, Copy)]
struct PendReq {
    id: u64,
    tokens: f64,
    avoid: Option<usize>,
    group: u64,
    passes: u32,
    /// conversation identity — the KV-reuse key (fresh requests open
    /// their own conversation: `conv == id`)
    conv: u64,
    /// context tokens decoded in earlier turns / before a salvage:
    /// served from a replica's KV cache when routed there, replayed
    /// through prefill otherwise
    ctx: f64,
}

pub fn run(cfg: &FleetSimConfig) -> FleetSimReport {
    assert!(cfg.num_replicas > 0, "empty fleet");
    let scale_cfg = cfg.autoscale.filter(|a| a.enabled);
    let max_slots = scale_cfg.map(|a| a.max_replicas).unwrap_or(cfg.num_replicas);
    let init_n = scale_cfg
        .map(|a| cfg.num_replicas.clamp(a.min_replicas, a.max_replicas))
        .unwrap_or(cfg.num_replicas);
    let mut scaler = scale_cfg.map(Autoscaler::new);
    let mut rng = Rng::new(cfg.seed);
    // virtual-time flight recorder: same event names as the real pool,
    // timestamps are the sim clock (emit_at), ring = replica slot
    let rec: Option<&FlightRecorder> = cfg.trace.as_deref();
    // replaying a salvaged token through prefill costs this many
    // decode-equivalent work units
    let prefill_ratio = cfg.prefill_time_per_token / cfg.decode.token_time;
    if cfg.kv_cache.enabled {
        cfg.kv_cache.validate().expect("invalid kv cache cfg");
    }
    let kv_on = cfg.kv_cache.enabled;
    // per-replica conversation KV cache: conv -> (cached context
    // tokens, LRU tick). The budget is token-denominated, mirroring
    // the real index's kv_bytes_budget / bytes_per_token.
    let kv_budget = cfg.kv_cache.budget_tokens() as f64;
    let mut kv_store: Vec<HashMap<u64, (f64, u64)>> = vec![HashMap::new(); max_slots];
    let mut kv_held: Vec<f64> = vec![0.0; max_slots];
    let mut kv_tick: u64 = 0;

    let slow_factor = |r: usize| match cfg.slow_replica {
        Some((slow, f)) if slow == r => f.max(1e-9),
        _ => 1.0,
    };
    let make_pool = |r: usize| {
        GpuPool::new(1, cfg.decode.token_time * slow_factor(r), cfg.knee, cfg.max_active)
    };
    let mut replicas: Vec<GpuPool> = (0..init_n).map(make_pool).collect();
    let mut paused = vec![false; init_n];
    let mut serving = vec![true; init_n];
    // virtual time each serving replica's current interval started
    let mut activated = vec![0.0f64; init_n];
    let mut router = Router::new(cfg.route_policy);
    // the same predictor the real pool shares across routing, admission
    // and autoscaling, fed on every virtual completion. Prompt groups
    // are the log2 bucket of a request's total decode work — the sim's
    // stand-in for "prompts of one group share a length distribution".
    cfg.predictor.validate().expect("invalid predictor cfg");
    let predictor = LengthPredictor::new(cfg.predictor);
    let tail_aware = cfg.route_policy == RoutePolicy::TailAware;
    // id -> predicted tokens at dispatch (TailAware only): the
    // predicted-remaining-token load score, floored at live outstanding
    let mut pred_of: HashMap<u64, f64> = HashMap::new();
    // ids currently placed whose prediction classified them long
    let mut long_ids: HashSet<u64> = HashSet::new();

    // The avoid entry mirrors the real pool's Pending::avoid: a
    // salvaged request prefers any replica but the one it was reclaimed
    // from, relaxed only when nothing else is routable.
    let mut pending: VecDeque<PendReq> = VecDeque::new();
    // id -> (submit time, total tokens, prompt group)
    let mut submit_time: HashMap<u64, (f64, f64, u64)> = HashMap::new();
    // id -> (conversation, turn number, context tokens at dispatch)
    let mut conv_of: HashMap<u64, (u64, u32, f64)> = HashMap::new();
    // id -> placement time: the router's EWMA feed measures dispatch->
    // completion, matching the real pool (InFlight::dispatched), not
    // pool-queue wait
    let mut dispatch_time: HashMap<u64, f64> = HashMap::new();
    // id -> current replica (the pool's InFlight::replica)
    let mut placed: HashMap<u64, usize> = HashMap::new();
    // id -> tokens assigned at the current dispatch (salvage baseline)
    let mut work_left: HashMap<u64, f64> = HashMap::new();
    // id -> watchdog strikes (mirrors InFlight::migrations)
    let mut strikes: HashMap<u64, u32> = HashMap::new();
    // ids salvaged off a retiring replica and not yet re-placed ->
    // the virtual time the drain reclaimed them (feeds the
    // drain_virtual_secs handoff-latency tripwire)
    let mut drain_pending: HashMap<u64, f64> = HashMap::new();
    // (deadline, id, replica) — stale entries skipped on pop
    let mut watchdogs: BinaryHeap<Reverse<(T, u64, usize)>> = BinaryHeap::new();
    let mut next_id = 0u64;
    let mut now = 0.0f64;
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.total_requests);
    // the virtual-time telemetry plane (a pure observer: it reads the
    // sim state, never schedules events). `window_lats` holds episode
    // latencies since the last closed window — the plane's windowed
    // tail signal, reset on every close.
    let mut plane = cfg
        .telemetry
        .as_ref()
        .filter(|t| t.enabled)
        .cloned()
        .or_else(|| {
            // the governor can only act on closed windows: when no
            // telemetry block was configured, derive a plane from the
            // governor's own cadence and budget
            cfg.governor.filter(|g| g.enabled).map(|g| TelemetryCfg {
                window_secs: g.interval,
                gap_budget: g.gap_budget,
                ..TelemetryCfg::on()
            })
        })
        .map(|t| {
            t.validate().expect("invalid telemetry cfg");
            TelemetryPlane::new(t)
        });
    let mut window_lats: Vec<f64> = Vec::new();
    let mut report = FleetSimReport {
        routed: vec![0; max_slots],
        peak_replicas: init_n,
        ..Default::default()
    };
    // adaptive asynchrony governor on the virtual clock. Requests carry
    // the weights version they were dispatched under (`dispatch_version`,
    // original dispatch wins across migrations — a salvaged prefix was
    // decoded under the old weights); completions fold their gap into
    // `window_gap_max`, the plane's per-window version-gap signal.
    let mut gov = cfg.governor.filter(|g| g.enabled).map(AsyncGovernor::new);
    let mut weights_version = 0usize;
    let mut dispatch_version: HashMap<u64, usize> = HashMap::new();
    let mut window_gap_max = 0.0f64;
    if let Some(g) = gov.as_ref() {
        report.mode_timeline.push((0.0, g.mode().label()));
    }
    let mut max_paused = 0usize;
    let mut phase = SyncPhase::Idle {
        next: if cfg.sync_interval > 0.0 { cfg.sync_interval } else { f64::INFINITY },
    };
    let mut next_arrival = match &cfg.arrivals {
        Some(trace) => trace.next_arrival(0.0, &mut rng),
        None => f64::INFINITY,
    };
    let scale_interval = scale_cfg.map(|a| a.interval).unwrap_or(f64::INFINITY);
    let mut next_scale = scale_interval;

    // `chain` continues an existing conversation: (conv, turn, ctx) of
    // the follow-up; `None` opens a fresh single-context conversation
    let new_request = |pending: &mut VecDeque<PendReq>,
                           submit_time: &mut HashMap<u64, (f64, f64, u64)>,
                           conv_of: &mut HashMap<u64, (u64, u32, f64)>,
                           next_id: &mut u64,
                           rng: &mut Rng,
                           now: f64,
                           chain: Option<(u64, u32, f64)>| {
        let len = cfg.lengths.sample(rng);
        let tokens =
            cfg.decode.effective_tokens(len) + cfg.decode.prefill_time / cfg.decode.token_time;
        let group = tokens.max(1.0).log2() as u64;
        let (conv, turn, ctx) = chain.unwrap_or((*next_id, 1, 0.0));
        pending.push_back(PendReq { id: *next_id, tokens, avoid: None, group, passes: 0, conv, ctx });
        submit_time.insert(*next_id, (now, tokens, group));
        conv_of.insert(*next_id, (conv, turn, ctx));
        if let Some(r) = rec {
            r.emit_at(
                "submit",
                EventPhase::Instant,
                *next_id,
                None,
                0,
                0,
                now,
                format!("tokens={tokens:.0}"),
            );
        }
        *next_id += 1;
    };

    // place a request on a specific replica (shared by pool-queue
    // dispatch and migration), arming its watchdog
    macro_rules! place {
        ($r:expr, $id:expr, $tokens:expr, $now:expr) => {{
            replicas[$r].submit_to(0, $id, $tokens, $now);
            if let Some(t0) = drain_pending.remove(&$id) {
                // handoff latency of a scale-down salvage: stays 0.0
                // while the drain re-dispatches at the shrink instant
                report.drain_virtual_secs += $now - t0;
            }
            dispatch_time.insert($id, $now);
            dispatch_version.entry($id).or_insert(weights_version);
            placed.insert($id, $r);
            work_left.insert($id, $tokens);
            if let Some(rec) = rec {
                rec.emit_at(
                    "route",
                    EventPhase::Instant,
                    $id,
                    Some($r),
                    0,
                    0,
                    $now,
                    format!("tokens={:.0}", $tokens),
                );
            }
            report.routed[$r] += 1;
            report.max_inflight = report.max_inflight.max(replicas[$r].in_flight());
            if cfg.hang_timeout > 0.0 {
                watchdogs.push(Reverse((T($now + cfg.hang_timeout), $id, $r)));
            }
        }};
    }

    // conversation-KV bookkeeping (all no-ops while kv_cache is off)
    macro_rules! kv_lookup {
        ($r:expr, $conv:expr) => {
            kv_store[$r].get(&$conv).map(|&(t, _)| t).unwrap_or(0.0)
        };
    }
    macro_rules! kv_insert {
        ($r:expr, $conv:expr, $ctx:expr) => {{
            if kv_on && serving[$r] && $ctx > 0.0 {
                kv_tick += 1;
                let prev =
                    kv_store[$r].insert($conv, ($ctx, kv_tick)).map(|(t, _)| t).unwrap_or(0.0);
                kv_held[$r] += $ctx - prev;
                // LRU-evict whole conversations until back under
                // budget (deterministic: full min over (tick, conv))
                while kv_held[$r] > kv_budget && !kv_store[$r].is_empty() {
                    let victim = kv_store[$r]
                        .iter()
                        .map(|(&c, &(_, tick))| (tick, c))
                        .min()
                        .map(|(_, c)| c)
                        .unwrap();
                    let (t, _) = kv_store[$r].remove(&victim).unwrap();
                    kv_held[$r] -= t;
                    report.kv_evictions += 1;
                }
            }
        }};
    }
    macro_rules! kv_invalidate {
        ($r:expr) => {{
            if kv_on {
                kv_store[$r].clear();
                kv_held[$r] = 0.0;
            }
        }};
    }
    // place a request, serving its conversation context from the
    // chosen replica's KV cache where possible and replaying the rest
    // through prefill — the charge the real proxy skips on a prefix hit
    macro_rules! kv_place {
        ($r:expr, $e:expr, $now:expr) => {{
            let e: PendReq = $e;
            let mut service = e.tokens;
            if e.ctx > 0.0 {
                let cached = if kv_on { kv_lookup!($r, e.conv).min(e.ctx) } else { 0.0 };
                let replay = (e.ctx - cached).max(0.0);
                if cached > 0.0 {
                    report.kv_hits += 1;
                    report.kv_hit_tokens += cached;
                    kv_tick += 1;
                    if let Some(entry) = kv_store[$r].get_mut(&e.conv) {
                        entry.1 = kv_tick;
                    }
                    if let Some(rec) = rec {
                        rec.emit_at(
                            "kv_hit",
                            EventPhase::Instant,
                            e.id,
                            Some($r),
                            0,
                            0,
                            $now,
                            format!("cached={cached:.0}"),
                        );
                    }
                } else if kv_on {
                    report.kv_misses += 1;
                    if let Some(rec) = rec {
                        rec.emit_at(
                            "kv_miss",
                            EventPhase::Instant,
                            e.id,
                            Some($r),
                            0,
                            0,
                            $now,
                            String::new(),
                        );
                    }
                }
                report.prefill_replay_tokens += replay;
                service += replay * prefill_ratio;
            }
            place!($r, e.id, service, $now);
        }};
    }

    macro_rules! loads {
        () => {
            (0..replicas.len())
                .map(|r| ReplicaLoad {
                    outstanding: replicas[r].in_flight(),
                    slots: cfg.max_active,
                    suspended: paused[r] || !serving[r],
                    // predicted-remaining-token load score (TailAware):
                    // sum of at-dispatch predictions for the replica's
                    // in-flight set, floored at its live outstanding —
                    // the same floor Shared::predicted_remaining applies
                    predicted_remaining: {
                        let inflight = replicas[r].in_flight() as f64;
                        if tail_aware {
                            placed
                                .iter()
                                .filter(|&(_, &rr)| rr == r)
                                .map(|(id, _)| pred_of.get(id).copied().unwrap_or(1.0))
                                .sum::<f64>()
                                .max(inflight)
                        } else {
                            inflight
                        }
                    },
                })
                .collect::<Vec<ReplicaLoad>>()
        };
    }

    // dispatch pool-queued requests while the router allows. Legacy
    // policies keep strict FIFO (the front's avoid preference is tried
    // first and relaxed only when nothing else is routable, mirroring
    // Shared::drain). TailAware admits in the proxy's two-class order:
    // aged entries first (starvation bound), then the long-work
    // reservation's oldest long entry, then shortest-predicted-first —
    // exact FIFO while the predictor is cold (all predictions equal,
    // stable min picks the oldest).
    macro_rules! dispatch {
        ($now:expr) => {{
            while !pending.is_empty() {
                let loads: Vec<ReplicaLoad> = loads!();
                let idx = if !tail_aware {
                    0
                } else {
                    let live_n = serving.iter().filter(|&&s| s).count().max(1);
                    // fleet-scope long reservation: the proxy reserves
                    // (decode batch / 4) long slots per replica; the
                    // sim's decode-batch analog is the knee
                    let reserve = live_n * (cfg.knee / 4).max(1);
                    let mut aged = None;
                    let mut oldest_long = None;
                    let mut shortest = 0usize;
                    let mut best = f64::INFINITY;
                    for (i, e) in pending.iter().enumerate() {
                        let pred = predictor.predict(e.group);
                        if aged.is_none() && e.passes >= SIM_AGING_LIMIT {
                            aged = Some(i);
                        }
                        if oldest_long.is_none() && predictor.classify(pred) {
                            oldest_long = Some(i);
                        }
                        if pred < best {
                            best = pred;
                            shortest = i;
                        }
                    }
                    match (aged, oldest_long) {
                        (Some(i), _) => i,
                        (None, Some(i)) if long_ids.len() < reserve => i,
                        _ => shortest,
                    }
                };
                let e = pending[idx];
                // cache-aware routing: per-replica cached-context view
                // for this conversation. All-zero collapses to an empty
                // vec — policies keep their legacy pick byte-identically
                let cached_per: Vec<usize> = if kv_on && e.ctx > 0.0 {
                    let per: Vec<usize> = (0..replicas.len())
                        .map(|r| {
                            if serving[r] && !paused[r] {
                                kv_lookup!(r, e.conv).min(e.ctx) as usize
                            } else {
                                0
                            }
                        })
                        .collect();
                    if per.iter().all(|&c| c == 0) {
                        Vec::new()
                    } else {
                        per
                    }
                } else {
                    Vec::new()
                };
                let hint = if tail_aware {
                    let pred = predictor.predict(e.group);
                    Some(RouteHint {
                        predicted_len: pred,
                        long: predictor.classify(pred),
                        cached: cached_per,
                    })
                } else if !cached_per.is_empty() {
                    Some(RouteHint { cached: cached_per, ..RouteHint::default() })
                } else {
                    None
                };
                // a salvaged request's avoid preference is dropped when
                // the avoided replica holds the longest cached prefix
                // (mirrors Shared::drain): resuming where the KV lives
                // beats avoiding the reclaim source
                let mut avoid = e.avoid;
                if let (Some(a), Some(h)) = (avoid, hint.as_ref()) {
                    if !h.cached.is_empty() {
                        let at_avoid = h.cached.get(a).copied().unwrap_or(0);
                        if at_avoid > 0 && h.cached.iter().all(|&c| c <= at_avoid) {
                            avoid = None;
                        }
                    }
                }
                let picked = match router.route_excluding_hinted(&loads, avoid, hint.clone()) {
                    Some(r) => Some(r),
                    None if avoid.is_some() => router.route_hinted(&loads, hint.clone()),
                    None => None,
                };
                let Some(r) = picked else { break };
                let _ = pending.remove(idx);
                // everything older than the admitted entry was passed
                // over this round (feeds the aging bound)
                for p in pending.iter_mut().take(idx) {
                    p.passes += 1;
                }
                if tail_aware {
                    let h = hint.unwrap();
                    pred_of.insert(e.id, h.predicted_len.max(1.0));
                    if h.long {
                        long_ids.insert(e.id);
                    }
                }
                kv_place!(r, e, $now);
            }
            report.pool_queue_max = report.pool_queue_max.max(pending.len());
        }};
    }

    // fold an aborted request's progress into its resubmission:
    // salvage keeps the remaining work plus the prefill replay of the
    // decoded prefix; from-scratch re-decodes everything. Evaluates to
    // (resubmit tokens, new context). With the KV index on, the
    // decoded prefix stays resident in the source replica's cache
    // (unless the source is retiring) and joins the request's context
    // instead of being charged here — the replay bill is paid at
    // re-placement against whatever cache the router finds, which is
    // what makes salvage (near) free when the request resumes in place.
    macro_rules! salvage_resubmit {
        ($assigned:expr, $remaining:expr, $conv:expr, $ctx:expr, $src:expr, $keep_src:expr) => {{
            let decoded = ($assigned - $remaining).max(0.0);
            if cfg.partial_migration && decoded >= cfg.min_salvage_tokens {
                report.salvaged_tokens += decoded;
                if kv_on {
                    if $keep_src {
                        kv_insert!($src, $conv, $ctx + decoded);
                    }
                    ($remaining.max(1e-9), $ctx + decoded)
                } else {
                    report.prefill_replay_tokens += decoded;
                    ($remaining.max(1e-9) + decoded * prefill_ratio, $ctx)
                }
            } else {
                report.wasted_tokens += decoded;
                ($assigned, $ctx)
            }
        }};
    }

    // cumulative telemetry reading at `$now` — the sim-side analog of
    // the pool's `telemetry_signals()`. The attribution mirrors the
    // final report's categories so per-window deltas telescope back to
    // the serving replica-second integral; latency percentiles are
    // window-scoped (reset at every close); the version gap is the
    // weight-sync staleness of completed requests (sync waves passed
    // since dispatch, max over the window); trainer-side signals
    // (buffer, train wait) have no sim counterpart and stay zero.
    macro_rules! tele_signals {
        ($now:expr) => {{
            let rs: f64 = report.replica_seconds
                + (0..replicas.len())
                    .filter(|&i| serving[i])
                    .map(|i| $now - activated[i])
                    .sum::<f64>();
            let busy: f64 = replicas.iter().map(|p| p.total_busy_secs($now)).sum();
            let synced: f64 = replicas.iter().map(|p| p.paused_secs($now)).sum();
            let prefill = (completed as f64 * cfg.decode.prefill_time).min(busy);
            let prefill_replay = (report.prefill_replay_tokens * cfg.prefill_time_per_token)
                .min((busy - prefill).max(0.0));
            let oldest = dispatch_time.values().fold(f64::INFINITY, |m, &t| m.min(t));
            TelemetrySignals {
                now: $now,
                completed: completed as u64,
                queue_depth: pending.len() as f64,
                serving: serving.iter().filter(|&&s| s).count(),
                attr: AttrSnapshot {
                    decode_busy: (busy - prefill - prefill_replay).max(0.0),
                    prefill,
                    prefill_replay,
                    weight_sync: synced,
                    draining: 0.0,
                    idle_bubble: (rs - busy - synced).max(0.0),
                },
                wasted_tokens: report.wasted_tokens.round() as u64,
                salvaged_tokens: report.salvaged_tokens.round() as u64,
                prefix_hit_tokens: report.kv_hit_tokens.round() as u64,
                produced_tokens: replicas
                    .iter()
                    .map(|p| p.total_work_done($now))
                    .sum::<f64>()
                    .round() as u64,
                version_gap: window_gap_max,
                buffer_ready: 0.0,
                train_wait_secs: 0.0,
                lat_p50: crate::util::percentile(&window_lats, 50.0),
                lat_p99: crate::util::percentile(&window_lats, 99.0),
                oldest_open_decode_secs: if oldest.is_finite() {
                    ($now - oldest).max(0.0)
                } else {
                    0.0
                },
            }
        }};
    }
    // advance the plane after an event (`false`) or force-close the
    // final partial window at the end of the run (`true`). Closing a
    // window resets the window latency buffer and stamps a
    // `telemetry_verdict` instant into the trace when one is wired.
    macro_rules! tele_tick {
        ($now:expr, $flush:expr) => {{
            if let Some(p) = plane.as_mut() {
                if $flush || p.due($now) {
                    let sig = tele_signals!($now);
                    let closed = if $flush { p.flush(&sig) } else { p.tick(&sig) };
                    if let Some(w) = closed {
                        window_lats.clear();
                        window_gap_max = 0.0;
                        if let Some(g) = gov.as_mut() {
                            if let Some(m) = g.decide_at(w.t1, &w) {
                                report.mode_transitions += 1;
                                report.mode_timeline.push((w.t1, m.label()));
                                if let Some(rec) = rec {
                                    rec.emit_at(
                                        "governor_transition",
                                        EventPhase::Instant,
                                        0,
                                        None,
                                        0,
                                        0,
                                        w.t1,
                                        format!("mode={} gap={:.2}", m.as_str(), w.version_gap),
                                    );
                                }
                            }
                        }
                        if let Some(rec) = rec {
                            rec.emit_at(
                                "telemetry_verdict",
                                EventPhase::Instant,
                                0,
                                None,
                                0,
                                0,
                                w.t1,
                                format!(
                                    "verdict={} waste={:.3}",
                                    w.verdict.as_str(),
                                    w.waste_rate
                                ),
                            );
                        }
                    }
                }
            }
        }};
    }

    if cfg.arrivals.is_none() {
        for _ in 0..cfg.clients.min(cfg.total_requests) {
            new_request(&mut pending, &mut submit_time, &mut conv_of, &mut next_id, &mut rng, now, None);
            submitted += 1;
        }
        dispatch!(now);
    }
    // baseline-seed the plane at virtual zero so windows tile the run
    tele_tick!(0.0, false);

    while completed < cfg.total_requests {
        // earliest generation completion across the fleet
        let mut gen: Option<(f64, usize)> = None;
        for r in 0..replicas.len() {
            if let Some(t) = replicas[r].peek_completion() {
                if gen.map(|(bt, _)| t < bt).unwrap_or(true) {
                    gen = Some((t, r));
                }
            }
        }
        let sync_t = match phase {
            SyncPhase::Idle { next } => next,
            SyncPhase::Broadcast { until } => until,
            SyncPhase::Rolling { until, .. } => until,
        };
        let dog_t = watchdogs.peek().map(|Reverse((t, _, _))| t.0).unwrap_or(f64::INFINITY);
        let arr_t = if submitted < cfg.total_requests { next_arrival } else { f64::INFINITY };

        // earliest event wins; tags break exact-time ties deterministically
        let mut best: Option<(f64, u8)> = None;
        for cand in [
            (dog_t, EV_DOG),
            (arr_t, EV_ARRIVE),
            (gen.map(|(t, _)| t).unwrap_or(f64::INFINITY), EV_GEN),
            (next_scale, EV_SCALE),
            (sync_t, EV_SYNC),
        ] {
            if cand.0.is_finite() && best.map(|b| cand < b).unwrap_or(true) {
                best = Some(cand);
            }
        }
        let Some((_, tag)) = best else {
            panic!(
                "fleet sim starved: no completions, watchdogs, arrivals, scale, or sync \
                 events (completed {completed}/{})",
                cfg.total_requests
            );
        };

        match tag {
            EV_DOG => {
                // --- watchdog: reclaim a still-running request --------
                let Reverse((t, id, r)) = watchdogs.pop().unwrap();
                if placed.get(&id) != Some(&r) {
                    continue; // stale: completed or already migrated
                }
                now = t.0;
                if strikes.get(&id).copied().unwrap_or(0) >= MAX_SIM_MIGRATIONS {
                    continue; // let it finish where it is
                }
                let loads: Vec<ReplicaLoad> = loads!();
                // the same decision the real LlmProxyPool::migrate
                // makes: move when a peer has a free decode window;
                // ReclaimInPlace (salvage + re-enter admission) when
                // every peer is saturated; re-arm when no peer exists
                let movable = router.has_free_candidate(&loads, Some(r));
                let peers =
                    (0..replicas.len()).any(|i| i != r && !loads[i].suspended);
                if movable {
                    let Some(new_r) = router.route_excluding(&loads, Some(r)) else {
                        watchdogs.push(Reverse((T(now + cfg.hang_timeout), id, r)));
                        continue;
                    };
                    *strikes.entry(id).or_insert(0) += 1;
                    let remaining = replicas[r].abort(id, now).unwrap_or(0.0);
                    let assigned = work_left.get(&id).copied().unwrap_or(remaining);
                    report.migrations += 1;
                    let (conv, turn, ctx) = conv_of.get(&id).copied().unwrap_or((id, 1, 0.0));
                    let (resubmit, new_ctx) =
                        salvage_resubmit!(assigned, remaining, conv, ctx, r, true);
                    conv_of.insert(id, (conv, turn, new_ctx));
                    if let Some(rec) = rec {
                        rec.emit_at(
                            "salvage",
                            EventPhase::Instant,
                            id,
                            Some(r),
                            0,
                            0,
                            now,
                            format!("migrate to={new_r} decoded={:.0}", assigned - remaining),
                        );
                    }
                    kv_place!(
                        new_r,
                        PendReq {
                            id,
                            tokens: resubmit,
                            avoid: None,
                            group: 0,
                            passes: 0,
                            conv,
                            ctx: new_ctx,
                        },
                        now
                    );
                } else if peers && cfg.reclaim_in_place {
                    // pause/rebalance without moving: the salvaged
                    // request joins the pool queue and escapes to
                    // whichever window frees first
                    *strikes.entry(id).or_insert(0) += 1;
                    let remaining = replicas[r].abort(id, now).unwrap_or(0.0);
                    let assigned = work_left.get(&id).copied().unwrap_or(remaining);
                    report.reclaims_in_place += 1;
                    let (conv, turn, ctx) = conv_of.get(&id).copied().unwrap_or((id, 1, 0.0));
                    let (resubmit, new_ctx) =
                        salvage_resubmit!(assigned, remaining, conv, ctx, r, true);
                    conv_of.insert(id, (conv, turn, new_ctx));
                    if let Some(rec) = rec {
                        rec.emit_at(
                            "salvage",
                            EventPhase::Instant,
                            id,
                            Some(r),
                            0,
                            0,
                            now,
                            format!("reclaim_in_place decoded={:.0}", assigned - remaining),
                        );
                    }
                    placed.remove(&id);
                    work_left.remove(&id);
                    dispatch_time.remove(&id);
                    pred_of.remove(&id);
                    long_ids.remove(&id);
                    let group = submit_time.get(&id).map(|&(_, _, g)| g).unwrap_or(0);
                    pending.push_back(PendReq {
                        id,
                        tokens: resubmit,
                        avoid: Some(r),
                        group,
                        passes: 0,
                        conv,
                        ctx: new_ctx,
                    });
                    dispatch!(now);
                } else {
                    // single replica / every peer paused: re-arm and
                    // try again next period, like the real watchdog
                    // re-firing every hang_timeout
                    watchdogs.push(Reverse((T(now + cfg.hang_timeout), id, r)));
                }
            }
            EV_ARRIVE => {
                // --- open-loop arrival --------------------------------
                now = next_arrival;
                new_request(&mut pending, &mut submit_time, &mut conv_of, &mut next_id, &mut rng, now, None);
                submitted += 1;
                if let Some(trace) = &cfg.arrivals {
                    next_arrival = trace.next_arrival(now, &mut rng);
                }
                dispatch!(now);
            }
            EV_GEN => {
                let (t, r) = gen.unwrap();
                now = t;
                let id = replicas[r].pop_completion(t);
                placed.remove(&id);
                strikes.remove(&id);
                pred_of.remove(&id);
                long_ids.remove(&id);
                let (t_submit, tokens, group) = submit_time.remove(&id).unwrap_or((now, 0.0, 0));
                let (conv, turn, ctx) = conv_of.remove(&id).unwrap_or((id, 1, 0.0));
                let assigned = work_left.remove(&id).unwrap_or(tokens);
                // the finished turn's KV stays resident on its replica:
                // the conversation's next turn can resume here for free
                kv_insert!(r, conv, ctx + tokens);
                let t_dispatch = dispatch_time.remove(&id).unwrap_or(t_submit);
                // measured staleness: sync waves the fleet absorbed
                // since this request was (first) dispatched — the
                // plane's per-window version-gap signal, which in turn
                // drives every governor decision
                if let Some(v0) = dispatch_version.remove(&id) {
                    window_gap_max = window_gap_max.max((weights_version - v0) as f64);
                }
                // every virtual completion feeds the shared length
                // predictor, exactly like the real pool's collectors
                predictor.record(group, tokens.round() as usize);
                // the same observation feed the real pool's collectors
                // give the Router: dispatch-to-completion token rate,
                // counting only the tokens decoded on THIS replica
                // since its dispatch (a salvaged prefix must not
                // inflate the target's EWMA)
                router.on_completion(r, assigned, now - t_dispatch);
                if let Some(rec) = rec {
                    rec.emit_at(
                        "done",
                        EventPhase::Instant,
                        id,
                        Some(r),
                        0,
                        0,
                        now,
                        format!("latency={:.2}", now - t_submit),
                    );
                }
                latencies.push(now - t_submit);
                if plane.is_some() {
                    window_lats.push(now - t_submit);
                }
                completed += 1;
                // closed loop: the freed client submits its next task —
                // the conversation's follow-up turn while it has turns
                // left, a fresh conversation otherwise
                if cfg.arrivals.is_none() && submitted < cfg.total_requests {
                    let chain = if (turn as usize) < cfg.multi_turn.max(1) {
                        Some((conv, turn + 1, ctx + tokens))
                    } else {
                        None
                    };
                    new_request(&mut pending, &mut submit_time, &mut conv_of, &mut next_id, &mut rng, now, chain);
                    submitted += 1;
                }
                dispatch!(now);
            }
            EV_SCALE => {
                // --- autoscale decision on the virtual clock ----------
                now = next_scale;
                next_scale += scale_interval;
                let scaler = scaler.as_mut().expect("scale event without autoscaler");
                let profile = predictor.snapshot();
                let signals = PoolSignals {
                    serving: serving.iter().filter(|&&s| s).count(),
                    queue_depth: pending.len() as f64,
                    outstanding: placed.len(),
                    slots: cfg.max_active,
                    wasted_tokens: report.wasted_tokens as u64,
                    pred_mean_len: profile.mean,
                    pred_p90_len: profile.p90,
                };
                let decision = scaler.decide_at(now, &signals);
                if let Some(rec) = rec {
                    if decision != ScaleDecision::Hold {
                        rec.emit_at(
                            "scale",
                            EventPhase::Instant,
                            0,
                            None,
                            0,
                            0,
                            now,
                            format!(
                                "{decision:?} serving={} queue={}",
                                signals.serving, signals.queue_depth
                            ),
                        );
                    }
                }
                match decision {
                    ScaleDecision::Grow(k) => {
                        for _ in 0..k {
                            // reuse a drained slot (resetting its EWMA,
                            // like the real pool) or open a fresh one
                            if let Some(slot) = (0..replicas.len()).find(|&i| !serving[i]) {
                                serving[slot] = true;
                                activated[slot] = now;
                                router.reset_replica(slot);
                                // a revived slot comes up cold, like the
                                // real pool's add_replica slot reuse
                                kv_invalidate!(slot);
                            } else if replicas.len() < max_slots {
                                replicas.push(make_pool(replicas.len()));
                                paused.push(false);
                                serving.push(true);
                                activated.push(now);
                            } else {
                                break;
                            }
                            report.scale_ups += 1;
                        }
                        let live = serving.iter().filter(|&&s| s).count();
                        report.peak_replicas = report.peak_replicas.max(live);
                        dispatch!(now);
                    }
                    ScaleDecision::Shrink(k) => {
                        // the salvage drain below happens entirely at
                        // `now` and its victims re-place inside the
                        // same event (survivors have capacity when the
                        // scaler shrinks): any change that defers the
                        // handoff — a blocking SALVAGE_WAIT equivalent
                        // — accrues drain_virtual_secs at re-placement
                        for _ in 0..k {
                            let min_serving =
                                scale_cfg.map(|a| a.min_replicas).unwrap_or(1);
                            let live: Vec<usize> =
                                (0..replicas.len()).filter(|&i| serving[i]).collect();
                            if live.len() <= min_serving {
                                break;
                            }
                            // drain the cheapest replica: fewest in
                            // flight, then least predicted-remaining
                            // work (mirrors retire_idlest; identical to
                            // the legacy stable first-min for non-
                            // TailAware runs, where both keys collapse
                            // to in-flight)
                            let victim = *live
                                .iter()
                                .min_by_key(|&&i| {
                                    let pred = if tail_aware {
                                        placed
                                            .iter()
                                            .filter(|&(_, &rr)| rr == i)
                                            .map(|(id, _)| {
                                                pred_of.get(id).copied().unwrap_or(1.0)
                                            })
                                            .sum::<f64>()
                                    } else {
                                        replicas[i].in_flight() as f64
                                    };
                                    (replicas[i].in_flight(), pred.round() as u64, i)
                                })
                                .unwrap();
                            serving[victim] = false;
                            report.replica_seconds += now - activated[victim];
                            report.scale_downs += 1;
                            // a retiring replica's KV dies with it
                            kv_invalidate!(victim);
                            if let Some(rec) = rec {
                                rec.emit_at(
                                    "retire",
                                    EventPhase::Instant,
                                    0,
                                    Some(victim),
                                    0,
                                    0,
                                    now,
                                    format!("in_flight={}", replicas[victim].in_flight()),
                                );
                            }
                            // salvage-drain: every in-flight request is
                            // aborted with its decoded progress kept
                            // (plus prefill replay) and re-queued for
                            // the survivors — the same RECLAIM path
                            // retire_replica drives on the real pool
                            let ids: Vec<u64> = placed
                                .iter()
                                .filter(|(_, &rr)| rr == victim)
                                .map(|(&id, _)| id)
                                .collect();
                            for id in ids {
                                let remaining =
                                    replicas[victim].abort(id, now).unwrap_or(0.0);
                                let assigned =
                                    work_left.get(&id).copied().unwrap_or(remaining);
                                let (conv, turn, ctx) =
                                    conv_of.get(&id).copied().unwrap_or((id, 1, 0.0));
                                let (resubmit, new_ctx) = salvage_resubmit!(
                                    assigned, remaining, conv, ctx, victim, false
                                );
                                conv_of.insert(id, (conv, turn, new_ctx));
                                if let Some(rec) = rec {
                                    rec.emit_at(
                                        "salvage",
                                        EventPhase::Instant,
                                        id,
                                        Some(victim),
                                        0,
                                        0,
                                        now,
                                        format!("drain decoded={:.0}", assigned - remaining),
                                    );
                                }
                                placed.remove(&id);
                                pred_of.remove(&id);
                                long_ids.remove(&id);
                                drain_pending.insert(id, now);
                                let group =
                                    submit_time.get(&id).map(|&(_, _, g)| g).unwrap_or(0);
                                pending.push_back(PendReq {
                                    id,
                                    tokens: resubmit,
                                    avoid: Some(victim),
                                    group,
                                    passes: 0,
                                    conv,
                                    ctx: new_ctx,
                                });
                            }
                        }
                        dispatch!(now);
                    }
                    ScaleDecision::Hold => {}
                }
            }
            EV_SYNC => {
                now = sync_t;
                let live = replicas.len();
                phase = match phase {
                    SyncPhase::Idle { .. } => {
                        report.sync_waves += 1;
                        // the fleet absorbs a new weights version:
                        // everything still decoding was dispatched at
                        // least one version ago from here on
                        weights_version += 1;
                        // tight governor modes (PeriodicBarrier / Sync,
                        // rank >= 2) force a fleet-wide broadcast wave —
                        // the barrier semantics — even when the config
                        // asked for staggered rolling updates
                        let rolling = cfg.rolling_update
                            && gov.as_ref().map(|g| g.mode().rank() < 2).unwrap_or(true);
                        if let Some(rec) = rec {
                            let mode = if rolling { "rolling" } else { "broadcast" };
                            rec.emit_at(
                                "weight_sync",
                                EventPhase::Instant,
                                0,
                                None,
                                0,
                                0,
                                now,
                                format!("wave={} mode={mode}", report.sync_waves),
                            );
                        }
                        if rolling {
                            paused[0] = true;
                            replicas[0].set_paused(true, now);
                            // new weights invalidate a replica's cached
                            // KV (per cfg), exactly like sync_agent
                            if cfg.kv_cache.invalidate_on_weight_sync {
                                kv_invalidate!(0);
                            }
                            max_paused = max_paused.max(1);
                            SyncPhase::Rolling { replica: 0, until: now + cfg.sync_time }
                        } else {
                            for r in 0..live {
                                paused[r] = true;
                                replicas[r].set_paused(true, now);
                                if cfg.kv_cache.invalidate_on_weight_sync {
                                    kv_invalidate!(r);
                                }
                            }
                            max_paused = live;
                            SyncPhase::Broadcast { until: now + cfg.sync_time }
                        }
                    }
                    SyncPhase::Rolling { replica, .. } => {
                        paused[replica] = false;
                        replicas[replica].set_paused(false, now);
                        if replica + 1 < live {
                            paused[replica + 1] = true;
                            replicas[replica + 1].set_paused(true, now);
                            if cfg.kv_cache.invalidate_on_weight_sync {
                                kv_invalidate!(replica + 1);
                            }
                            SyncPhase::Rolling {
                                replica: replica + 1,
                                until: now + cfg.sync_time,
                            }
                        } else {
                            SyncPhase::Idle { next: now + cfg.sync_interval }
                        }
                    }
                    SyncPhase::Broadcast { .. } => {
                        for r in 0..live {
                            paused[r] = false;
                            replicas[r].set_paused(false, now);
                        }
                        SyncPhase::Idle { next: now + cfg.sync_interval }
                    }
                };
                dispatch!(now);
            }
            _ => unreachable!(),
        }
        tele_tick!(now, false);
    }
    // close the final partial window so the timeline tiles [0, makespan]
    tele_tick!(now, true);

    report.makespan = now;
    report.completed = completed;
    report.tokens = replicas.iter().map(|p| p.total_work_done(now)).sum();
    report.throughput = if now > 0.0 { report.tokens / now } else { 0.0 };
    report.mean_latency = crate::util::mean(&latencies);
    report.p50_latency = crate::util::percentile(&latencies, 50.0);
    report.p90_latency = crate::util::percentile(&latencies, 90.0);
    report.p99_latency = crate::util::percentile(&latencies, 99.0);
    report.per_replica_util = replicas
        .iter()
        .map(|p| p.total_work_done(now) / (p.capacity_rate() * now.max(1e-9)))
        .collect();
    let n = replicas.len();
    report.min_decoding_during_sync = if report.sync_waves > 0 { n - max_paused } else { n };
    report.final_replicas = serving.iter().filter(|&&s| s).count();
    for r in 0..n {
        if serving[r] {
            report.replica_seconds += now - activated[r];
        }
    }
    // time attribution, mirroring the real pool's categories. Busy and
    // paused are exact integrals from the GPU model; the prefill
    // buckets are priced at full speed (token-equivalents folded into
    // the decode budget), so any processor-sharing stretch lands in
    // decode_busy; idle is the residual of the serving integral.
    let busy: f64 = replicas.iter().map(|p| p.total_busy_secs(now)).sum();
    let synced: f64 = replicas.iter().map(|p| p.paused_secs(now)).sum();
    let prefill = completed as f64 * cfg.decode.prefill_time;
    let prefill_replay = report.prefill_replay_tokens * cfg.prefill_time_per_token;
    report.attr = AttrSnapshot {
        decode_busy: (busy - prefill - prefill_replay).max(0.0),
        prefill: prefill.min(busy),
        prefill_replay: prefill_replay.min((busy - prefill).max(0.0)),
        weight_sync: synced,
        draining: 0.0,
        idle_bubble: (report.replica_seconds - busy - synced).max(0.0),
    };
    if let Some(p) = plane.as_ref() {
        report.telemetry = p.windows().to_vec();
        report.telemetry_alerts = p.alerts();
    }
    report.routed.truncate(n);
    report
}

/// Mirrored replica-count sweep (the Fig 1b-style scaling axis for the
/// fleet layer): offered load scales with the replica count so the
/// per-replica pressure is constant.
pub fn sweep_replicas(base: &FleetSimConfig, counts: &[usize]) -> Vec<(usize, FleetSimReport)> {
    let per_clients = base.clients / base.num_replicas.max(1);
    let per_total = base.total_requests / base.num_replicas.max(1);
    counts
        .iter()
        .map(|&c| {
            let mut cfg = base.clone();
            cfg.num_replicas = c;
            cfg.clients = per_clients * c;
            cfg.total_requests = per_total * c;
            (c, run(&cfg))
        })
        .collect()
}

/// The bursty-arrival regime the autoscaler is for: shared by the
/// elastic-vs-static unit test and `benches/fig_autoscale.rs`. Sized
/// so one replica handles the trough and ~5 the burst.
pub fn bursty_config(total_requests: usize) -> FleetSimConfig {
    let mut cfg = FleetSimConfig::default_fleet(1);
    cfg.lengths = LengthProfile::new(1500.0, 1.0, 16384);
    cfg.sync_interval = 0.0;
    cfg.total_requests = total_requests;
    cfg.arrivals = Some(BurstTrace {
        base_rate: 0.3,
        burst_rate: 6.0,
        period: 200.0,
        duty: 0.25,
    });
    cfg
}

/// The elastic arm's scaler bounds for [`bursty_config`].
pub fn bursty_autoscale(min_replicas: usize, max_replicas: usize) -> AutoscaleCfg {
    AutoscaleCfg {
        enabled: true,
        min_replicas,
        max_replicas,
        target_queue_depth: 12.0,
        interval: 5.0,
        cooldown: 10.0,
        hysteresis: 0.2,
        adaptive_target: false,
        decode_knee: 16.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed(policy: RoutePolicy) -> FleetSimConfig {
        let mut c = FleetSimConfig::default_fleet(4);
        c.route_policy = policy;
        // heavy tail: the longest responses exceed the median >20x
        c.lengths = LengthProfile::new(800.0, 1.3, 30000);
        c.clients = 32;
        c.total_requests = 240;
        c.sync_interval = 0.0; // isolate the routing effect
        c
    }

    /// One 6x fail-slow replica plus a migration watchdog: the regime
    /// the salvage arm is for.
    fn fail_slow(partial: bool) -> FleetSimConfig {
        let mut c = skewed(RoutePolicy::LeastOutstanding);
        c.slow_replica = Some((2, 6.0));
        c.hang_timeout = 60.0;
        c.partial_migration = partial;
        c
    }

    #[test]
    fn least_outstanding_beats_round_robin_under_skew() {
        let rr = run(&skewed(RoutePolicy::RoundRobin));
        let lo = run(&skewed(RoutePolicy::LeastOutstanding));
        assert_eq!(rr.completed, 240);
        assert_eq!(lo.completed, 240);
        assert!(
            lo.makespan < rr.makespan,
            "least-outstanding {:.0}s should beat round-robin {:.0}s",
            lo.makespan,
            rr.makespan
        );
        assert!(lo.p99_latency <= rr.p99_latency * 1.05, "tail should not regress");
    }

    #[test]
    fn queue_sched_bounds_coresidency() {
        let mut c = skewed(RoutePolicy::QueueSched);
        c.max_active = 8; // force backpressure: 32 clients > 4*8 slots
        let r = run(&c);
        assert_eq!(r.completed, c.total_requests);
        assert!(r.max_inflight <= c.max_active, "{} > {}", r.max_inflight, c.max_active);
        assert!(r.pool_queue_max > 0, "expected pool-side queueing");
        // load-blind routing over-admits the straggler replica under
        // the same cap (completions elsewhere keep feeding it)
        let mut rr = skewed(RoutePolicy::RoundRobin);
        rr.max_active = 8;
        assert!(run(&rr).max_inflight > 8);
    }

    #[test]
    fn ewma_starves_fail_slow_replica_more_than_least_outstanding() {
        // replica 2 decodes 5x slower; both policies must finish the
        // same work budget, but EWMA should place visibly less of it on
        // the cripple (rate-aware) than least-outstanding (queue-aware)
        let base = {
            let mut c = skewed(RoutePolicy::LeastOutstanding);
            c.slow_replica = Some((2, 5.0));
            c
        };
        let lo = run(&base);
        let mut ewma_cfg = base.clone();
        ewma_cfg.route_policy = RoutePolicy::Ewma;
        let ew = run(&ewma_cfg);
        assert_eq!(lo.completed, base.total_requests);
        assert_eq!(ew.completed, base.total_requests);
        assert!(
            ew.routed[2] < lo.routed[2],
            "ewma must starve the slow replica: ewma {:?} vs lo {:?}",
            ew.routed,
            lo.routed
        );
        assert!(
            ew.makespan <= lo.makespan * 1.05,
            "ewma {:.0}s must not lose to least-outstanding {:.0}s",
            ew.makespan,
            lo.makespan
        );
    }

    #[test]
    fn ewma_matches_least_outstanding_on_homogeneous_fleet() {
        // with identical replicas the rate estimates converge and EWMA
        // behaves like least-outstanding: no pathological imbalance
        let lo = run(&skewed(RoutePolicy::LeastOutstanding));
        let ew = run(&skewed(RoutePolicy::Ewma));
        assert_eq!(ew.completed, lo.completed);
        assert!(
            ew.makespan <= lo.makespan * 1.25,
            "homogeneous ewma {:.0}s vs lo {:.0}s",
            ew.makespan,
            lo.makespan
        );
        assert!(ew.routed.iter().all(|&r| r > 0), "every replica serves: {:?}", ew.routed);
    }

    #[test]
    fn watchdog_migrates_and_salvage_conserves_work() {
        let r = run(&fail_slow(true));
        assert_eq!(r.completed, 240, "every request must still finish");
        assert!(r.migrations > 0, "watchdog must fire on the fail-slow replica");
        assert!(r.salvaged_tokens > 0.0, "salvage must carry decoded work: {r:?}");
        // the only waste path on the partial arm is a sub-min_salvage
        // prefix (< 1 token of progress); real progress is conserved
        assert!(
            r.wasted_tokens < r.salvaged_tokens,
            "partial arm must keep, not burn, decoded work: {r:?}"
        );
    }

    #[test]
    fn from_scratch_arm_wastes_what_salvage_keeps() {
        let scratch = run(&fail_slow(false));
        let partial = run(&fail_slow(true));
        assert_eq!(scratch.completed, partial.completed);
        assert!(scratch.migrations > 0 && partial.migrations > 0);
        assert!(
            partial.wasted_tokens < scratch.wasted_tokens,
            "salvage must strictly reduce wasted tokens: partial {:.0} vs scratch {:.0}",
            partial.wasted_tokens,
            scratch.wasted_tokens
        );
        // same seed, same arrivals: the salvage arm replays prefixes
        // through prefill (~2.5% of their decode cost) instead of
        // re-decoding them outright, so it still does less total work
        assert!(
            partial.tokens <= scratch.tokens + 1e-6,
            "salvage must not add decode work: {:.0} vs {:.0}",
            partial.tokens,
            scratch.tokens
        );
        assert!(
            partial.prefill_replay_tokens > 0.0,
            "salvage re-dispatch must pay the KV rebuild: {partial:?}"
        );
        assert_eq!(
            scratch.prefill_replay_tokens, 0.0,
            "from-scratch re-decodes; it never replays a prefix"
        );
        // a migrated-and-resumed request loses and duplicates nothing:
        // decoded work for the completed set matches the assignment
        assert!(
            partial.salvaged_tokens > 0.0,
            "the comparison is vacuous without salvage: {partial:?}"
        );
    }

    #[test]
    fn prefill_replay_cost_is_charged_per_salvaged_token() {
        // the same fail-slow run with free vs costed prefill replay:
        // identical event order (resubmit sizes differ only by the
        // replay term), strictly more decode-equivalent work when the
        // KV rebuild is priced in
        let mut free = fail_slow(true);
        free.prefill_time_per_token = 0.0;
        let mut costed = fail_slow(true);
        costed.prefill_time_per_token = 2e-3; // replay at 1/4 of decode cost (exaggerated)
        let f = run(&free);
        let c = run(&costed);
        assert_eq!(f.completed, c.completed);
        assert!(f.salvaged_tokens > 0.0 && c.salvaged_tokens > 0.0);
        assert!(
            c.tokens > f.tokens,
            "costed replay must add work: {:.0} vs {:.0}",
            c.tokens,
            f.tokens
        );
        // every salvaged token is replayed through prefill — the knob
        // only prices the replay, it does not change what is replayed
        assert_eq!(f.prefill_replay_tokens, f.salvaged_tokens);
        assert_eq!(c.prefill_replay_tokens, c.salvaged_tokens);
    }

    #[test]
    fn migration_determinism() {
        let a = run(&fail_slow(true));
        let b = run(&fail_slow(true));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.salvaged_tokens, b.salvaged_tokens);
        assert_eq!(a.reclaims_in_place, b.reclaims_in_place);
    }

    /// A saturated pool (every peer's decode window full at watchdog
    /// time) must resolve the hang as ReclaimInPlace: salvage + rejoin
    /// the pool queue, escaping to whichever window frees first. With
    /// 6 closed-loop clients over 2 one-slot replicas, both windows
    /// are full at every instant a watchdog can fire, so in-place is
    /// the only arm that can trigger.
    #[test]
    fn saturated_watchdog_reclaims_in_place() {
        let mut c = FleetSimConfig::default_fleet(2);
        c.route_policy = RoutePolicy::QueueSched;
        c.max_active = 1;
        c.clients = 6;
        c.total_requests = 40;
        c.sync_interval = 0.0;
        c.lengths = LengthProfile::new(800.0, 1.0, 8192);
        c.slow_replica = Some((0, 8.0));
        c.hang_timeout = 30.0;
        let r = run(&c);
        assert_eq!(r.completed, 40, "every request must still finish");
        assert!(r.reclaims_in_place > 0, "saturation must trigger the in-place arm: {r:?}");
        assert_eq!(r.migrations, 0, "no free window ever existed to migrate into: {r:?}");
        assert!(r.salvaged_tokens > 0.0, "the pause keeps decoded work: {r:?}");
        // the knob off: the watchdog just re-arms — no reclaim at all
        let mut off = c.clone();
        off.reclaim_in_place = false;
        let r_off = run(&off);
        assert_eq!(r_off.completed, 40);
        assert_eq!(r_off.reclaims_in_place, 0);
        assert_eq!(r_off.migrations, 0);
    }

    /// The latency satellite's sim mirror: autoscale shrink performs
    /// its whole salvage drain at one virtual instant — zero blocked
    /// virtual time, guarding against ever reintroducing a synchronous
    /// SALVAGE_WAIT on the scale-down path.
    #[test]
    fn autoscale_shrink_blocks_zero_virtual_time() {
        let mut cfg = bursty_config(680);
        cfg.autoscale = Some(bursty_autoscale(1, 6));
        let r = run(&cfg);
        assert!(r.scale_downs > 0, "the trough must actually drain replicas: {r:?}");
        assert_eq!(
            r.drain_virtual_secs, 0.0,
            "scale-down salvage must not consume virtual time: {r:?}"
        );
    }

    #[test]
    fn rolling_sync_keeps_n_minus_1_decoding() {
        let mut c = FleetSimConfig::default_fleet(4);
        c.sync_interval = 60.0;
        let rolling = run(&c);
        assert!(rolling.sync_waves >= 1, "expected at least one wave");
        assert_eq!(rolling.min_decoding_during_sync, 3);
        c.rolling_update = false;
        let broadcast = run(&c);
        assert!(broadcast.sync_waves >= 1);
        assert_eq!(broadcast.min_decoding_during_sync, 0);
    }

    /// Governor mirror on the fleet sim: a gap budget of 1 with waves
    /// every 30 virtual seconds means every window containing a wave
    /// measures gap >= 1 (in-flight requests span the version bump), so
    /// the governor must tighten — and once tight (rank >= 2), sync
    /// waves turn fleet-wide broadcast even though the config asked for
    /// rolling updates. Also exercises the governor-derived telemetry
    /// plane (no explicit `telemetry` block).
    #[test]
    fn governor_forces_broadcast_waves_under_tight_budget() {
        let mut c = FleetSimConfig::default_fleet(3);
        c.sync_interval = 30.0;
        c.sync_time = 2.0;
        c.governor = Some(GovernorCfg {
            gap_budget: 1.0,
            interval: 10.0,
            cooldown: 20.0,
            ..GovernorCfg::on()
        });
        let r = run(&c);
        assert_eq!(r.completed, c.total_requests);
        assert!(
            !r.telemetry.is_empty(),
            "an enabled governor must derive a telemetry plane when none is configured"
        );
        assert!(
            r.mode_timeline[0].0 == 0.0 && r.mode_timeline[0].1.starts_with("async"),
            "timeline seeds with the optimistic starting mode: {:?}",
            r.mode_timeline
        );
        assert!(
            r.mode_transitions >= 1,
            "a binding budget must force at least one transition: {:?}",
            r.mode_timeline
        );
        assert!(
            r.telemetry.iter().any(|w| w.version_gap >= 1.0),
            "requests spanning a wave must register a measured gap: {:?}",
            r.telemetry.iter().map(|w| w.version_gap).collect::<Vec<_>>()
        );
        assert_eq!(
            r.min_decoding_during_sync, 0,
            "tight modes must broadcast-pause the whole fleet despite rolling_update=true: {:?}",
            r.mode_timeline
        );
        // virtual-time determinism: the governed run replays exactly
        let r2 = run(&c);
        assert_eq!(r.makespan, r2.makespan);
        assert_eq!(r.mode_timeline, r2.mode_timeline);
        assert_eq!(r.mode_transitions, r2.mode_transitions);
    }

    #[test]
    fn replica_scaling_increases_throughput() {
        let rows = sweep_replicas(&FleetSimConfig::default_fleet(1), &[1, 2, 4]);
        assert_eq!(rows.len(), 3);
        let t1 = rows[0].1.throughput;
        let t4 = rows[2].1.throughput;
        assert!(t4 > 2.0 * t1, "4 replicas {t4:.0} tok/s vs 1 replica {t1:.0} tok/s");
        for (_, r) in &rows {
            for u in &r.per_replica_util {
                assert!(*u > 0.0 && *u <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn static_fleet_replica_seconds_are_n_times_makespan() {
        let c = skewed(RoutePolicy::LeastOutstanding);
        let r = run(&c);
        assert!(
            (r.replica_seconds - 4.0 * r.makespan).abs() < 1e-6,
            "static 4-replica fleet: {} vs {}",
            r.replica_seconds,
            4.0 * r.makespan
        );
        assert_eq!(r.final_replicas, 4);
        assert_eq!(r.peak_replicas, 4);
        assert_eq!(r.scale_ups + r.scale_downs, 0);
    }

    #[test]
    fn elastic_fleet_follows_the_burst_and_drains_back() {
        // 680 requests: the last arrival lands deep in a trough, so the
        // scaler has drained back to min by the time the run ends
        let mut cfg = bursty_config(680);
        cfg.autoscale = Some(bursty_autoscale(1, 6));
        let r = run(&cfg);
        assert_eq!(r.completed, 680, "every request must finish");
        assert!(
            r.peak_replicas >= 3,
            "the burst must grow the fleet well past min: {r:?}"
        );
        assert!(r.scale_ups > 0 && r.scale_downs > 0, "{r:?}");
        assert_eq!(
            r.final_replicas,
            1,
            "the trough must drain the fleet back to min_replicas: {r:?}"
        );
        // drains salvage decoded work instead of burning it
        assert!(
            r.wasted_tokens <= r.salvaged_tokens,
            "scale-down must salvage, not waste: {r:?}"
        );
    }

    /// The acceptance shape for `benches/fig_autoscale.rs`: elastic
    /// matches the static peak's completion rate within 5% while using
    /// strictly fewer replica-seconds.
    #[test]
    fn elastic_matches_static_peak_at_lower_replica_seconds() {
        let total = 680;
        let static_peak = {
            let mut c = bursty_config(total);
            c.num_replicas = 6;
            run(&c)
        };
        let elastic = {
            let mut c = bursty_config(total);
            c.autoscale = Some(bursty_autoscale(1, 6));
            run(&c)
        };
        assert_eq!(static_peak.completed, elastic.completed);
        // same completed work budget: completion rate = total/makespan
        let rate_ratio = static_peak.makespan / elastic.makespan;
        assert!(
            rate_ratio >= 0.95,
            "elastic must stay within 5% of static-peak throughput: \
             elastic {:.0}s vs static {:.0}s ({rate_ratio:.3})",
            elastic.makespan,
            static_peak.makespan
        );
        assert!(
            elastic.replica_seconds < static_peak.replica_seconds,
            "elastic must hold strictly fewer replica-seconds: {:.0} vs {:.0}",
            elastic.replica_seconds,
            static_peak.replica_seconds
        );
    }

    #[test]
    fn elastic_determinism() {
        let mut cfg = bursty_config(600);
        cfg.autoscale = Some(bursty_autoscale(1, 6));
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.scale_ups, b.scale_ups);
        assert_eq!(a.scale_downs, b.scale_downs);
        assert_eq!(a.replica_seconds, b.replica_seconds);
    }

    #[test]
    fn determinism() {
        let cfg = skewed(RoutePolicy::LeastOutstanding);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tokens, b.tokens);
    }

    /// The tentpole's sim acceptance: length-aware scheduling over a
    /// heavy-tailed length distribution must finish the same work
    /// budget faster than FIFO-ish round-robin without regressing the
    /// tail — the comparison `benches/fig_tail_latency.rs` tabulates.
    #[test]
    fn tail_aware_beats_round_robin_under_heavy_tail() {
        let rr = run(&skewed(RoutePolicy::RoundRobin));
        let ta = run(&skewed(RoutePolicy::TailAware));
        assert_eq!(rr.completed, 240);
        assert_eq!(ta.completed, 240, "tail-aware must not strand work");
        assert!(
            ta.makespan < rr.makespan,
            "tail-aware {:.0}s should beat round-robin {:.0}s",
            ta.makespan,
            rr.makespan
        );
        assert!(ta.p99_latency <= rr.p99_latency * 1.05, "tail should not regress");
        assert!(ta.p50_latency <= rr.p50_latency * 1.05, "median should not regress");
        // quantiles are ordered and populated
        assert!(ta.p50_latency > 0.0);
        assert!(ta.p50_latency <= ta.p90_latency && ta.p90_latency <= ta.p99_latency);
    }

    #[test]
    fn tail_aware_determinism() {
        let cfg = skewed(RoutePolicy::TailAware);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.routed, b.routed);
        assert_eq!(a.p99_latency, b.p99_latency);
    }

    /// No-starvation under churn: tail-aware admission + routing with
    /// watchdog reclaims and autoscale grow/drain all active must still
    /// complete every request (the aging bound and work-conserving
    /// spill guarantee progress for both classes).
    #[test]
    fn tail_aware_survives_churn_without_starvation() {
        let mut cfg = bursty_config(400);
        cfg.route_policy = RoutePolicy::TailAware;
        cfg.lengths = LengthProfile::new(800.0, 1.3, 30000);
        cfg.hang_timeout = 90.0;
        cfg.autoscale = Some(bursty_autoscale(1, 6));
        let r = run(&cfg);
        assert_eq!(r.completed, 400, "churn must not starve any request: {r:?}");
        assert!(r.scale_ups > 0 && r.scale_downs > 0, "{r:?}");
        // and it stays deterministic with every mechanism engaged
        let again = run(&cfg);
        assert_eq!(r.makespan, again.makespan);
        assert_eq!(r.migrations + r.reclaims_in_place, again.migrations + again.reclaims_in_place);
    }

    /// The sim half of the recorder satellite: with a fail-slow
    /// replica, salvage migrations, and rolling sync all active, the
    /// six attribution categories must tile the serving replica-second
    /// integral exactly — no wall-second unaccounted, none counted
    /// twice.
    #[test]
    fn attribution_tiles_serving_replica_seconds() {
        let mut c = fail_slow(true);
        c.sync_interval = 60.0;
        let r = run(&c);
        assert_eq!(r.completed, c.total_requests);
        let a = r.attr;
        assert!(r.migrations > 0 && r.sync_waves > 0, "{r:?}");
        assert!(a.decode_busy > 0.0, "{a:?}");
        assert!(a.prefill > 0.0, "every completion paid prefill: {a:?}");
        assert!(a.prefill_replay > 0.0, "salvage re-dispatch replays prefixes: {a:?}");
        assert!(a.weight_sync > 0.0, "rolling waves paused replicas: {a:?}");
        assert_eq!(a.draining, 0.0, "sim drains are instantaneous");
        assert!(a.idle_bubble >= 0.0, "{a:?}");
        let sum = a.total();
        assert!(
            (sum - r.replica_seconds).abs() < 1e-6 * r.replica_seconds.max(1.0),
            "categories must tile the serving integral: {sum:.3} vs {:.3} ({a:?})",
            r.replica_seconds
        );
        // the idle residual is genuine, not manufactured by clamping:
        // busy work + pauses really fit inside the serving integral
        assert!(
            a.decode_busy + a.prefill + a.prefill_replay + a.weight_sync
                <= r.replica_seconds + 1e-6,
            "{a:?} vs {}",
            r.replica_seconds
        );
    }

    /// Multi-turn agentic workload on an EWMA fleet, with and without
    /// the KV-prefix index. Same seed, same lengths, same turn chain.
    fn multi_turn(kv: bool) -> FleetSimConfig {
        let mut c = FleetSimConfig::default_fleet(4);
        c.route_policy = RoutePolicy::Ewma;
        c.lengths = LengthProfile::new(800.0, 1.0, 8192);
        c.clients = 32;
        c.total_requests = 240;
        c.sync_interval = 0.0;
        c.multi_turn = 4;
        if kv {
            c.kv_cache = KvCacheCfg {
                enabled: true,
                block_tokens: 16,
                kv_bytes_budget: 1 << 30,
                bytes_per_token: 4096,
                invalidate_on_weight_sync: true,
            };
        }
        c
    }

    /// The tentpole's sim acceptance: on multi-turn agentic traffic,
    /// cache-aware routing returns follow-up turns to the replica
    /// already holding the conversation's KV, cutting prefill replay
    /// by >= 90% versus plain EWMA on the identical workload — and the
    /// saved replay work shows up as a faster completion rate.
    #[test]
    fn cache_aware_routing_cuts_prefill_replay_on_multi_turn() {
        let off = run(&multi_turn(false));
        let rec = Arc::new(FlightRecorder::new(65536));
        let mut kv_cfg = multi_turn(true);
        kv_cfg.trace = Some(rec.clone());
        let on = run(&kv_cfg);
        assert_eq!(off.completed, 240);
        assert_eq!(on.completed, 240);
        assert!(
            off.prefill_replay_tokens > 0.0,
            "without the index every follow-up replays its context: {off:?}"
        );
        assert!(
            on.prefill_replay_tokens <= 0.10 * off.prefill_replay_tokens,
            "cache-aware must cut prefill replay >= 90%: {:.0} vs {:.0}",
            on.prefill_replay_tokens,
            off.prefill_replay_tokens
        );
        assert!(on.kv_hits > 0 && on.kv_hit_tokens > 0.0, "{on:?}");
        assert_eq!(off.kv_hits, 0, "the disabled arm must report no cache activity");
        assert_eq!(off.kv_hit_tokens, 0.0);
        assert!(
            on.makespan < off.makespan,
            "skipped replay must beat full replay on completion rate: \
             {:.0}s vs {:.0}s",
            on.makespan,
            off.makespan
        );
        // hit instants land in the trace with the real pool's schema
        let hits = rec.events().iter().filter(|e| e.name == "kv_hit").count();
        assert_eq!(hits as u64, on.kv_hits, "one kv_hit event per cache hit");
    }

    #[test]
    fn kv_cache_determinism() {
        let a = run(&multi_turn(true));
        let b = run(&multi_turn(true));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.kv_hits, b.kv_hits);
        assert_eq!(a.kv_hit_tokens, b.kv_hit_tokens);
        assert_eq!(a.kv_evictions, b.kv_evictions);
        assert_eq!(a.prefill_replay_tokens, b.prefill_replay_tokens);
        assert_eq!(a.routed, b.routed);
    }

    /// A budget far below the live conversation footprint forces the
    /// per-replica LRU to evict; the run still completes and caches
    /// still land some hits on the survivors.
    #[test]
    fn kv_cache_evicts_under_budget_pressure() {
        let mut c = multi_turn(true);
        // ~1024 cached tokens per replica vs ~8 live conversations of
        // 800+ tokens each: constant eviction pressure
        c.kv_cache.kv_bytes_budget = 4096 * 1024;
        let r = run(&c);
        assert_eq!(r.completed, 240);
        assert!(r.kv_evictions > 0, "budget pressure must evict: {r:?}");
        let unbounded = run(&multi_turn(true));
        assert_eq!(unbounded.kv_evictions, 0, "a huge budget never evicts");
        assert!(
            r.prefill_replay_tokens >= unbounded.prefill_replay_tokens,
            "evictions can only lose reuse, not invent it: {:.0} vs {:.0}",
            r.prefill_replay_tokens,
            unbounded.prefill_replay_tokens
        );
    }

    /// With the index off (the default), no kv counters may move — the
    /// legacy arms stay bit-for-bit silent on cache activity.
    #[test]
    fn kv_disabled_reports_zero_cache_activity() {
        let r = run(&fail_slow(true));
        assert_eq!(r.kv_hits, 0);
        assert_eq!(r.kv_misses, 0);
        assert_eq!(r.kv_hit_tokens, 0.0);
        assert_eq!(r.kv_evictions, 0);
    }

    /// A traced sim run records the real pool's event schema on the
    /// virtual clock: one submit and one done per request, salvage
    /// instants for every watchdog reclaim, well-formed span nesting,
    /// and a Chrome-trace export that parses.
    #[test]
    fn virtual_time_trace_mirrors_pool_schema() {
        let rec = Arc::new(FlightRecorder::new(4096));
        let mut c = fail_slow(true);
        c.trace = Some(rec.clone());
        let r = run(&c);
        assert!(r.migrations > 0, "{r:?}");
        assert_eq!(rec.dropped(), 0, "rings must hold the whole run");
        let events = rec.events();
        let count = |n: &str| events.iter().filter(|e| e.name == n).count();
        assert_eq!(count("submit"), c.total_requests);
        assert_eq!(count("done"), c.total_requests);
        assert_eq!(
            count("salvage"),
            r.migrations + r.reclaims_in_place,
            "one salvage instant per watchdog reclaim: {r:?}"
        );
        assert!(count("route") >= c.total_requests, "re-dispatches add routes");
        crate::metrics::trace::check_span_nesting(&events).unwrap();
        // timestamps are the virtual clock: the run's last event is the
        // final completion, at exactly the reported makespan
        let t_max = events.iter().map(|e| e.t).fold(0.0, f64::max);
        assert!((t_max - r.makespan).abs() < 1e-9, "{t_max} vs {}", r.makespan);
        let parsed = crate::util::json::Json::parse(&rec.export_chrome_trace())
            .expect("chrome trace must parse");
        let n = parsed.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len());
        assert_eq!(n, Some(events.len()));
        // tracing must not perturb the virtual timeline
        let mut untraced = c.clone();
        untraced.trace = None;
        let u = run(&untraced);
        assert_eq!(u.makespan, r.makespan);
        assert_eq!(u.migrations, r.migrations);
    }

    use crate::metrics::telemetry::{AlertKind, BottleneckVerdict};

    /// The telemetry tentpole's sim acceptance, arm 1: a fleet paused
    /// for broadcast weight sync while a fail-slow replica burns
    /// progress (from-scratch migration) must be *diagnosed* live —
    /// SyncStall verdicts in the window timeline and a firing
    /// waste-budget alarm — by the same plane the real controller
    /// ticks, here on the virtual clock.
    #[test]
    fn telemetry_diagnoses_sync_stall_and_waste_on_fail_slow() {
        let mut c = fail_slow(false); // from-scratch arm: migrations waste tokens
        c.rolling_update = false; // broadcast: every replica pauses together
        c.sync_interval = 20.0;
        c.sync_time = 10.0;
        let mut t = TelemetryCfg::on();
        t.waste_budget = 0.02;
        c.telemetry = Some(t);
        let r = run(&c);
        assert_eq!(r.completed, c.total_requests);
        assert!(!r.telemetry.is_empty(), "windows must close on the virtual clock");
        let stalls = r
            .telemetry
            .iter()
            .filter(|w| w.verdict == BottleneckVerdict::SyncStall)
            .count();
        assert!(
            stalls > 0,
            "broadcast pauses must be diagnosed as SyncStall: {:?}",
            r.telemetry.iter().map(|w| w.verdict).collect::<Vec<_>>()
        );
        assert!(r.wasted_tokens > 0.0, "the from-scratch arm must waste: {r:?}");
        assert!(
            r.telemetry_alerts.iter().any(|a| a.kind == AlertKind::WasteBudget && a.firing),
            "burned progress must raise the waste alarm: {:?}",
            r.telemetry_alerts
        );
    }

    /// Arm 2: heavy-tailed lengths on a load-blind router stretch the
    /// window p99 far past the p50 — the timeline must call TailBound,
    /// and never SyncStall (no sync is configured).
    #[test]
    fn telemetry_diagnoses_tail_bound_under_heavy_tail() {
        let mut c = skewed(RoutePolicy::RoundRobin);
        let mut t = TelemetryCfg::on();
        t.tail_ratio = 4.0;
        c.telemetry = Some(t);
        let r = run(&c);
        assert_eq!(r.completed, 240);
        let tails = r
            .telemetry
            .iter()
            .filter(|w| w.verdict == BottleneckVerdict::TailBound)
            .count();
        assert!(
            tails > 0,
            "a lognormal tail must be diagnosed as TailBound: {:?}",
            r.telemetry.iter().map(|w| w.verdict).collect::<Vec<_>>()
        );
        assert!(
            r.telemetry.iter().all(|w| w.verdict != BottleneckVerdict::SyncStall),
            "no sync configured: SyncStall must never fire"
        );
    }

    /// The plane is a pure observer: enabling it must not perturb the
    /// virtual timeline by a single event, and with it off the
    /// report's telemetry surfaces stay empty.
    #[test]
    fn telemetry_is_a_pure_observer() {
        let base = run(&fail_slow(true));
        assert!(base.telemetry.is_empty() && base.telemetry_alerts.is_empty());
        let mut on = fail_slow(true);
        on.telemetry = Some(TelemetryCfg::on());
        let t = run(&on);
        assert_eq!(t.makespan, base.makespan, "telemetry must not move the clock");
        assert_eq!(t.migrations, base.migrations);
        assert_eq!(t.routed, base.routed);
        assert!(!t.telemetry.is_empty());
        let t2 = run(&on);
        assert_eq!(t.telemetry.len(), t2.telemetry.len(), "plane output is deterministic");
    }

    /// Property over seeds: with churn from every mechanism at once —
    /// autoscale grow/drain, watchdog salvage, bursty arrivals — the
    /// telemetry windows tile virtual time exactly (first opens at 0,
    /// consecutive windows share a boundary, the flush closes at the
    /// makespan) and the per-window attribution deltas telescope back
    /// to the run's serving replica-second integral.
    #[test]
    fn telemetry_windows_tile_virtual_time_across_churn() {
        for seed in [3u64, 17, 41] {
            let mut c = bursty_config(300);
            c.lengths = LengthProfile::new(800.0, 1.3, 30000);
            c.hang_timeout = 90.0;
            c.autoscale = Some(bursty_autoscale(1, 6));
            c.seed = seed;
            c.telemetry = Some(TelemetryCfg::on());
            let r = run(&c);
            assert_eq!(r.completed, 300, "seed {seed}");
            let ws = &r.telemetry;
            assert!(ws.len() >= 2, "seed {seed}: {} windows", ws.len());
            assert_eq!(ws[0].t0, 0.0, "seed {seed}: baseline seeds at virtual zero");
            for pair in ws.windows(2) {
                assert_eq!(pair[0].t1, pair[1].t0, "seed {seed}: windows must tile");
            }
            let last = ws.last().unwrap();
            assert!(
                (last.t1 - r.makespan).abs() < 1e-9,
                "seed {seed}: flush must close at makespan: {} vs {}",
                last.t1,
                r.makespan
            );
            // telescoping: Σ window attr == final serving integral,
            // within the small slack the per-field delta clamp can
            // shave off prefill-counter jumps at window boundaries
            let sum: f64 = ws.iter().map(|w| w.attr.total()).sum();
            assert!(
                (sum - r.replica_seconds).abs() <= 0.01 * r.replica_seconds.max(1.0),
                "seed {seed}: window attr must telescope to the serving integral: \
                 {sum:.3} vs {:.3}",
                r.replica_seconds
            );
        }
    }
}
