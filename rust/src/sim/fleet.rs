//! Virtual-time mirror of the coordinator's inference fleet
//! (`coordinator/fleet.rs`): N single-GPU decode replicas behind the
//! *same* `Router` the real pool uses, driven by closed-loop clients
//! (stand-ins for EnvManagers) over the paper's long-tail response
//! lengths.
//!
//! This is where the fleet-level phenomena are reproduced at scale
//! without hardware (DESIGN.md §3):
//!
//!   * round-robin placement stacks short requests behind 30k-token
//!     stragglers, while least-outstanding routing redirects inflow to
//!     the replicas that are actually draining — lower makespan and
//!     tail latency under skewed lengths;
//!   * queue scheduling (pool-side backpressure at the decode-slot
//!     cap) bounds per-replica co-residency, avoiding the
//!     processor-sharing slowdown beyond the bandwidth knee;
//!   * EWMA latency-aware routing measures each replica's delivered
//!     token rate (the same `Router::on_completion` feed the real
//!     pool's collectors use) and starves fail-slow / heterogeneous
//!     replicas (`slow_replica`) that least-outstanding keeps feeding;
//!   * staggered (rolling) weight sync keeps N-1 replicas decoding
//!     through a model update; broadcast sync stalls all of them;
//!   * *prefix-salvaging migration* (`hang_timeout` > 0): a request
//!     that runs past the watchdog deadline is aborted off its replica
//!     and resubmitted elsewhere through the same exclusion-routing
//!     the real `LlmProxyPool::migrate` uses. With `partial_migration`
//!     only the *remaining* tokens are re-decoded (the decoded prefix
//!     is salvaged, counted in `salvaged_tokens`); the from-scratch
//!     arm re-decodes everything and burns the progress into
//!     `wasted_tokens` — the cost model behind
//!     `benches/fig_fleet_scaling.rs`'s wasted-token comparison.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

use crate::coordinator::routing::{ReplicaLoad, RoutePolicy, Router};
use crate::sim::queue::{GpuPool, T};
use crate::util::rng::Rng;
use crate::workload::{DecodeCost, LengthProfile};

/// Give up migrating a request after this many moves (mirrors the
/// engine's MAX_GEN_MIGRATIONS): a genuinely long generation must be
/// allowed to finish somewhere.
const MAX_SIM_MIGRATIONS: u32 = 3;

#[derive(Clone, Debug)]
pub struct FleetSimConfig {
    pub num_replicas: usize,
    pub route_policy: RoutePolicy,
    /// staggered weight sync (one replica paused at a time) vs
    /// broadcast (all paused together)
    pub rolling_update: bool,
    /// closed-loop clients (EnvManager stand-ins), each with one
    /// request in flight
    pub clients: usize,
    /// total requests to complete (the sweep's fixed work budget)
    pub total_requests: usize,
    /// full-speed co-resident sequences per replica
    pub knee: usize,
    /// decode-slot admission cap (what queue scheduling routes against)
    pub max_active: usize,
    pub lengths: LengthProfile,
    pub decode: DecodeCost,
    /// virtual seconds between weight-sync waves (0 = never sync)
    pub sync_interval: f64,
    /// pause duration per replica per wave
    pub sync_time: f64,
    /// heterogeneous fleet: replica `index` decodes `factor`x slower
    /// (fail-slow hardware, thermal throttling, a noisy neighbor)
    pub slow_replica: Option<(usize, f64)>,
    /// migration watchdog: a request still running this many virtual
    /// seconds after dispatch is moved to another replica (0 = never)
    pub hang_timeout: f64,
    /// carry the decoded prefix across migration (resume) vs re-decode
    /// from scratch
    pub partial_migration: bool,
    /// shortest decoded prefix (token units) worth salvaging
    pub min_salvage_tokens: f64,
    pub seed: u64,
}

impl FleetSimConfig {
    /// Paper-flavored defaults, scaled to the replica count so each
    /// replica sees the same offered load across a sweep.
    pub fn default_fleet(num_replicas: usize) -> Self {
        FleetSimConfig {
            num_replicas,
            route_policy: RoutePolicy::LeastOutstanding,
            rolling_update: true,
            clients: 24 * num_replicas,
            total_requests: 150 * num_replicas,
            knee: 16,
            max_active: 48,
            lengths: LengthProfile::qwen3_base(),
            decode: DecodeCost::qwen3_8b(),
            sync_interval: 120.0,
            sync_time: 10.0,
            slow_replica: None,
            hang_timeout: 0.0,
            partial_migration: true,
            min_salvage_tokens: 1.0,
            seed: 17,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct FleetSimReport {
    pub makespan: f64,
    pub completed: usize,
    /// decode work performed, in short-context token units
    pub tokens: f64,
    /// tokens per virtual second over the whole run
    pub throughput: f64,
    pub mean_latency: f64,
    pub p99_latency: f64,
    pub per_replica_util: Vec<f64>,
    /// fewest replicas decoding at any instant inside a sync window
    /// (rolling => N-1, broadcast => 0)
    pub min_decoding_during_sync: usize,
    pub sync_waves: usize,
    /// largest per-replica co-residency observed (queue scheduling
    /// keeps this <= max_active)
    pub max_inflight: usize,
    /// largest pool-side queue observed (backpressure depth)
    pub pool_queue_max: usize,
    /// requests placed on each replica (routing share)
    pub routed: Vec<usize>,
    /// watchdog migrations performed
    pub migrations: usize,
    /// decoded tokens carried across migrations (partial arm)
    pub salvaged_tokens: f64,
    /// decoded tokens re-decoded from scratch (the from-scratch bill)
    pub wasted_tokens: f64,
}

#[derive(Clone, Copy)]
enum SyncPhase {
    Idle { next: f64 },
    Broadcast { until: f64 },
    Rolling { replica: usize, until: f64 },
}

pub fn run(cfg: &FleetSimConfig) -> FleetSimReport {
    assert!(cfg.num_replicas > 0, "empty fleet");
    let n = cfg.num_replicas;
    let mut rng = Rng::new(cfg.seed);
    let mut replicas: Vec<GpuPool> = (0..n)
        .map(|r| {
            let factor = match cfg.slow_replica {
                Some((slow, f)) if slow == r => f.max(1e-9),
                _ => 1.0,
            };
            GpuPool::new(1, cfg.decode.token_time * factor, cfg.knee, cfg.max_active)
        })
        .collect();
    let mut paused = vec![false; n];
    let mut router = Router::new(cfg.route_policy);

    let mut pending: VecDeque<(u64, f64)> = VecDeque::new(); // (id, tokens to decode)
    let mut submit_time: HashMap<u64, (f64, f64)> = HashMap::new(); // id -> (t, tokens)
    // id -> placement time: the router's EWMA feed measures dispatch->
    // completion, matching the real pool (InFlight::dispatched), not
    // pool-queue wait
    let mut dispatch_time: HashMap<u64, f64> = HashMap::new();
    // id -> current replica (the pool's InFlight::replica)
    let mut placed: HashMap<u64, usize> = HashMap::new();
    // id -> tokens assigned at the current dispatch (salvage baseline)
    let mut work_left: HashMap<u64, f64> = HashMap::new();
    // id -> watchdog strikes (mirrors InFlight::migrations)
    let mut strikes: HashMap<u64, u32> = HashMap::new();
    // (deadline, id, replica) — stale entries skipped on pop
    let mut watchdogs: BinaryHeap<Reverse<(T, u64, usize)>> = BinaryHeap::new();
    let mut next_id = 0u64;
    let mut now = 0.0f64;
    let mut submitted = 0usize;
    let mut completed = 0usize;
    let mut latencies: Vec<f64> = Vec::with_capacity(cfg.total_requests);
    let mut report = FleetSimReport { routed: vec![0; n], ..Default::default() };
    let mut max_paused = 0usize;
    let mut phase = SyncPhase::Idle {
        next: if cfg.sync_interval > 0.0 { cfg.sync_interval } else { f64::INFINITY },
    };

    let new_request = |pending: &mut VecDeque<(u64, f64)>,
                           submit_time: &mut HashMap<u64, (f64, f64)>,
                           next_id: &mut u64,
                           rng: &mut Rng,
                           now: f64| {
        let len = cfg.lengths.sample(rng);
        let tokens =
            cfg.decode.effective_tokens(len) + cfg.decode.prefill_time / cfg.decode.token_time;
        pending.push_back((*next_id, tokens));
        submit_time.insert(*next_id, (now, tokens));
        *next_id += 1;
    };

    // place a request on a specific replica (shared by pool-queue
    // dispatch and migration), arming its watchdog
    macro_rules! place {
        ($r:expr, $id:expr, $tokens:expr, $now:expr) => {{
            replicas[$r].submit_to(0, $id, $tokens, $now);
            dispatch_time.insert($id, $now);
            placed.insert($id, $r);
            work_left.insert($id, $tokens);
            report.routed[$r] += 1;
            report.max_inflight = report.max_inflight.max(replicas[$r].in_flight());
            if cfg.hang_timeout > 0.0 {
                watchdogs.push(Reverse((T($now + cfg.hang_timeout), $id, $r)));
            }
        }};
    }

    // dispatch pool-queued requests while the router allows
    macro_rules! dispatch {
        ($now:expr) => {{
            while !pending.is_empty() {
                let loads: Vec<ReplicaLoad> = (0..replicas.len())
                    .map(|r| ReplicaLoad {
                        outstanding: replicas[r].in_flight(),
                        slots: cfg.max_active,
                        suspended: paused[r],
                    })
                    .collect();
                let Some(r) = router.route(&loads) else { break };
                let (id, tokens) = pending.pop_front().unwrap();
                place!(r, id, tokens, $now);
            }
            report.pool_queue_max = report.pool_queue_max.max(pending.len());
        }};
    }

    for _ in 0..cfg.clients.min(cfg.total_requests) {
        new_request(&mut pending, &mut submit_time, &mut next_id, &mut rng, now);
        submitted += 1;
    }
    dispatch!(now);

    while completed < cfg.total_requests {
        // earliest generation completion across the fleet
        let mut gen: Option<(f64, usize)> = None;
        for r in 0..n {
            if let Some(t) = replicas[r].peek_completion() {
                if gen.map(|(bt, _)| t < bt).unwrap_or(true) {
                    gen = Some((t, r));
                }
            }
        }
        let sync_t = match phase {
            SyncPhase::Idle { next } => next,
            SyncPhase::Broadcast { until } => until,
            SyncPhase::Rolling { until, .. } => until,
        };
        let dog_t = watchdogs.peek().map(|Reverse((t, _, _))| t.0).unwrap_or(f64::INFINITY);

        if dog_t.is_finite() && dog_t <= sync_t && gen.map(|(t, _)| dog_t <= t).unwrap_or(true) {
            // --- watchdog: migrate a still-running request ------------
            let Reverse((t, id, r)) = watchdogs.pop().unwrap();
            if placed.get(&id) != Some(&r) {
                continue; // stale: completed or already migrated
            }
            now = t.0;
            if strikes.get(&id).copied().unwrap_or(0) >= MAX_SIM_MIGRATIONS {
                continue; // let it finish where it is
            }
            let loads: Vec<ReplicaLoad> = (0..n)
                .map(|i| ReplicaLoad {
                    outstanding: replicas[i].in_flight(),
                    slots: cfg.max_active,
                    suspended: paused[i],
                })
                .collect();
            // the policy's pick, then least-outstanding survivor — the
            // same fallback LlmProxyPool::migrate uses
            let target = router.route_excluding(&loads, Some(r)).or_else(|| {
                (0..n)
                    .filter(|&i| i != r && !loads[i].suspended)
                    .min_by_key(|&i| loads[i].outstanding)
            });
            let Some(new_r) = target else {
                // nowhere to move it right now (peers paused or
                // saturated): re-arm and try again next period, like
                // the real watchdog re-firing every hang_timeout
                watchdogs.push(Reverse((T(now + cfg.hang_timeout), id, r)));
                continue;
            };
            *strikes.entry(id).or_insert(0) += 1;
            let remaining = replicas[r].abort(id, now).unwrap_or(0.0);
            let assigned = work_left.get(&id).copied().unwrap_or(remaining);
            let decoded = (assigned - remaining).max(0.0);
            report.migrations += 1;
            let resubmit = if cfg.partial_migration && decoded >= cfg.min_salvage_tokens {
                report.salvaged_tokens += decoded;
                remaining.max(1e-9)
            } else {
                report.wasted_tokens += decoded;
                assigned
            };
            place!(new_r, id, resubmit, now);
        } else {
            match gen {
                Some((t, r)) if t <= sync_t => {
                    now = t;
                    let id = replicas[r].pop_completion(t);
                    placed.remove(&id);
                    strikes.remove(&id);
                    let (t_submit, tokens) = submit_time.remove(&id).unwrap_or((now, 0.0));
                    let assigned = work_left.remove(&id).unwrap_or(tokens);
                    let t_dispatch = dispatch_time.remove(&id).unwrap_or(t_submit);
                    // the same observation feed the real pool's
                    // collectors give the Router: dispatch-to-completion
                    // token rate, counting only the tokens decoded on
                    // THIS replica since its dispatch (a salvaged
                    // prefix must not inflate the target's EWMA)
                    router.on_completion(r, assigned, now - t_dispatch);
                    latencies.push(now - t_submit);
                    completed += 1;
                    // closed loop: the freed client submits its next task
                    if submitted < cfg.total_requests {
                        new_request(&mut pending, &mut submit_time, &mut next_id, &mut rng, now);
                        submitted += 1;
                    }
                    dispatch!(now);
                }
                _ => {
                    assert!(
                        sync_t.is_finite(),
                        "fleet sim starved: no completions, watchdogs, or sync events \
                         (completed {completed}/{})",
                        cfg.total_requests
                    );
                    now = sync_t;
                    phase = match phase {
                        SyncPhase::Idle { .. } => {
                            report.sync_waves += 1;
                            if cfg.rolling_update {
                                paused[0] = true;
                                replicas[0].set_paused(true, now);
                                max_paused = max_paused.max(1);
                                SyncPhase::Rolling { replica: 0, until: now + cfg.sync_time }
                            } else {
                                for r in 0..n {
                                    paused[r] = true;
                                    replicas[r].set_paused(true, now);
                                }
                                max_paused = n;
                                SyncPhase::Broadcast { until: now + cfg.sync_time }
                            }
                        }
                        SyncPhase::Rolling { replica, .. } => {
                            paused[replica] = false;
                            replicas[replica].set_paused(false, now);
                            if replica + 1 < n {
                                paused[replica + 1] = true;
                                replicas[replica + 1].set_paused(true, now);
                                SyncPhase::Rolling {
                                    replica: replica + 1,
                                    until: now + cfg.sync_time,
                                }
                            } else {
                                SyncPhase::Idle { next: now + cfg.sync_interval }
                            }
                        }
                        SyncPhase::Broadcast { .. } => {
                            for r in 0..n {
                                paused[r] = false;
                                replicas[r].set_paused(false, now);
                            }
                            SyncPhase::Idle { next: now + cfg.sync_interval }
                        }
                    };
                    dispatch!(now);
                }
            }
        }
    }

    report.makespan = now;
    report.completed = completed;
    report.tokens = replicas.iter().map(|p| p.total_work_done(now)).sum();
    report.throughput = if now > 0.0 { report.tokens / now } else { 0.0 };
    report.mean_latency = crate::util::mean(&latencies);
    report.p99_latency = crate::util::percentile(&latencies, 99.0);
    report.per_replica_util = replicas
        .iter()
        .map(|p| p.total_work_done(now) / (p.capacity_rate() * now.max(1e-9)))
        .collect();
    report.min_decoding_during_sync = if report.sync_waves > 0 { n - max_paused } else { n };
    report
}

/// Mirrored replica-count sweep (the Fig 1b-style scaling axis for the
/// fleet layer): offered load scales with the replica count so the
/// per-replica pressure is constant.
pub fn sweep_replicas(base: &FleetSimConfig, counts: &[usize]) -> Vec<(usize, FleetSimReport)> {
    let per_clients = base.clients / base.num_replicas.max(1);
    let per_total = base.total_requests / base.num_replicas.max(1);
    counts
        .iter()
        .map(|&c| {
            let mut cfg = base.clone();
            cfg.num_replicas = c;
            cfg.clients = per_clients * c;
            cfg.total_requests = per_total * c;
            (c, run(&cfg))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed(policy: RoutePolicy) -> FleetSimConfig {
        let mut c = FleetSimConfig::default_fleet(4);
        c.route_policy = policy;
        // heavy tail: the longest responses exceed the median >20x
        c.lengths = LengthProfile::new(800.0, 1.3, 30000);
        c.clients = 32;
        c.total_requests = 240;
        c.sync_interval = 0.0; // isolate the routing effect
        c
    }

    /// One 6x fail-slow replica plus a migration watchdog: the regime
    /// the salvage arm is for.
    fn fail_slow(partial: bool) -> FleetSimConfig {
        let mut c = skewed(RoutePolicy::LeastOutstanding);
        c.slow_replica = Some((2, 6.0));
        c.hang_timeout = 60.0;
        c.partial_migration = partial;
        c
    }

    #[test]
    fn least_outstanding_beats_round_robin_under_skew() {
        let rr = run(&skewed(RoutePolicy::RoundRobin));
        let lo = run(&skewed(RoutePolicy::LeastOutstanding));
        assert_eq!(rr.completed, 240);
        assert_eq!(lo.completed, 240);
        assert!(
            lo.makespan < rr.makespan,
            "least-outstanding {:.0}s should beat round-robin {:.0}s",
            lo.makespan,
            rr.makespan
        );
        assert!(lo.p99_latency <= rr.p99_latency * 1.05, "tail should not regress");
    }

    #[test]
    fn queue_sched_bounds_coresidency() {
        let mut c = skewed(RoutePolicy::QueueSched);
        c.max_active = 8; // force backpressure: 32 clients > 4*8 slots
        let r = run(&c);
        assert_eq!(r.completed, c.total_requests);
        assert!(r.max_inflight <= c.max_active, "{} > {}", r.max_inflight, c.max_active);
        assert!(r.pool_queue_max > 0, "expected pool-side queueing");
        // load-blind routing over-admits the straggler replica under
        // the same cap (completions elsewhere keep feeding it)
        let mut rr = skewed(RoutePolicy::RoundRobin);
        rr.max_active = 8;
        assert!(run(&rr).max_inflight > 8);
    }

    #[test]
    fn ewma_starves_fail_slow_replica_more_than_least_outstanding() {
        // replica 2 decodes 5x slower; both policies must finish the
        // same work budget, but EWMA should place visibly less of it on
        // the cripple (rate-aware) than least-outstanding (queue-aware)
        let base = {
            let mut c = skewed(RoutePolicy::LeastOutstanding);
            c.slow_replica = Some((2, 5.0));
            c
        };
        let lo = run(&base);
        let mut ewma_cfg = base.clone();
        ewma_cfg.route_policy = RoutePolicy::Ewma;
        let ew = run(&ewma_cfg);
        assert_eq!(lo.completed, base.total_requests);
        assert_eq!(ew.completed, base.total_requests);
        assert!(
            ew.routed[2] < lo.routed[2],
            "ewma must starve the slow replica: ewma {:?} vs lo {:?}",
            ew.routed,
            lo.routed
        );
        assert!(
            ew.makespan <= lo.makespan * 1.05,
            "ewma {:.0}s must not lose to least-outstanding {:.0}s",
            ew.makespan,
            lo.makespan
        );
    }

    #[test]
    fn ewma_matches_least_outstanding_on_homogeneous_fleet() {
        // with identical replicas the rate estimates converge and EWMA
        // behaves like least-outstanding: no pathological imbalance
        let lo = run(&skewed(RoutePolicy::LeastOutstanding));
        let ew = run(&skewed(RoutePolicy::Ewma));
        assert_eq!(ew.completed, lo.completed);
        assert!(
            ew.makespan <= lo.makespan * 1.25,
            "homogeneous ewma {:.0}s vs lo {:.0}s",
            ew.makespan,
            lo.makespan
        );
        assert!(ew.routed.iter().all(|&r| r > 0), "every replica serves: {:?}", ew.routed);
    }

    #[test]
    fn watchdog_migrates_and_salvage_conserves_work() {
        let r = run(&fail_slow(true));
        assert_eq!(r.completed, 240, "every request must still finish");
        assert!(r.migrations > 0, "watchdog must fire on the fail-slow replica");
        assert!(r.salvaged_tokens > 0.0, "salvage must carry decoded work: {r:?}");
        // the only waste path on the partial arm is a sub-min_salvage
        // prefix (< 1 token of progress); real progress is conserved
        assert!(
            r.wasted_tokens < r.salvaged_tokens,
            "partial arm must keep, not burn, decoded work: {r:?}"
        );
    }

    #[test]
    fn from_scratch_arm_wastes_what_salvage_keeps() {
        let scratch = run(&fail_slow(false));
        let partial = run(&fail_slow(true));
        assert_eq!(scratch.completed, partial.completed);
        assert!(scratch.migrations > 0 && partial.migrations > 0);
        assert!(
            partial.wasted_tokens < scratch.wasted_tokens,
            "salvage must strictly reduce wasted tokens: partial {:.0} vs scratch {:.0}",
            partial.wasted_tokens,
            scratch.wasted_tokens
        );
        // same seed, same arrivals: total decode work (tokens) only
        // differs by the re-decoded prefixes, so the salvage arm does
        // no MORE work and finishes no later than from-scratch re-runs
        assert!(
            partial.tokens <= scratch.tokens + 1e-6,
            "salvage must not add decode work: {:.0} vs {:.0}",
            partial.tokens,
            scratch.tokens
        );
        // a migrated-and-resumed request loses and duplicates nothing:
        // decoded work for the completed set matches the assignment
        assert!(
            partial.salvaged_tokens > 0.0,
            "the comparison is vacuous without salvage: {partial:?}"
        );
    }

    #[test]
    fn migration_determinism() {
        let a = run(&fail_slow(true));
        let b = run(&fail_slow(true));
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.salvaged_tokens, b.salvaged_tokens);
    }

    #[test]
    fn rolling_sync_keeps_n_minus_1_decoding() {
        let mut c = FleetSimConfig::default_fleet(4);
        c.sync_interval = 60.0;
        let rolling = run(&c);
        assert!(rolling.sync_waves >= 1, "expected at least one wave");
        assert_eq!(rolling.min_decoding_during_sync, 3);
        c.rolling_update = false;
        let broadcast = run(&c);
        assert!(broadcast.sync_waves >= 1);
        assert_eq!(broadcast.min_decoding_during_sync, 0);
    }

    #[test]
    fn replica_scaling_increases_throughput() {
        let rows = sweep_replicas(&FleetSimConfig::default_fleet(1), &[1, 2, 4]);
        assert_eq!(rows.len(), 3);
        let t1 = rows[0].1.throughput;
        let t4 = rows[2].1.throughput;
        assert!(t4 > 2.0 * t1, "4 replicas {t4:.0} tok/s vs 1 replica {t1:.0} tok/s");
        for (_, r) in &rows {
            for u in &r.per_replica_util {
                assert!(*u > 0.0 && *u <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn determinism() {
        let cfg = skewed(RoutePolicy::LeastOutstanding);
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.tokens, b.tokens);
    }
}
