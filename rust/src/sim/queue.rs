//! Discrete-event primitives for the virtual-time cluster simulator:
//! a processor-sharing GPU pool (memory-bandwidth-bound decode model)
//! and a FIFO service pool (reward workers, env threads).
//!
//! Decode model: a GPU decodes up to `knee` co-resident sequences at
//! full speed (`1/token_time` tokens/s each); beyond the knee the
//! bandwidth is shared and per-sequence rate degrades as `knee/n`.
//! This reproduces the two phenomena the paper builds on: (1) adding
//! GPUs cannot shorten one long rollout, and (2) concentrating a
//! prompt's n candidates on one worker amplifies stragglers
//! (Section 5.1.2).

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

/// Totally ordered f64 for the event heap (no NaNs in the sim).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct T(pub f64);

impl Eq for T {}

impl PartialOrd for T {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for T {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).expect("NaN time in simulator")
    }
}

#[derive(Clone, Debug)]
struct Active {
    id: u64,
    /// tokens still to decode
    remaining: f64,
}

#[derive(Clone, Debug, Default)]
struct Gpu {
    active: Vec<Active>,
    /// virtual time of last progress update
    last: f64,
    /// invalidates cached completion events in the heap
    epoch: u64,
    /// cumulative decoded tokens (utilization accounting)
    work_done: f64,
    /// seconds with >= 1 resident sequence actually decoding — the
    /// direct integral, NOT derivable from `work_done` (below the knee
    /// a busy second decodes fewer than `knee` tokens)
    busy_secs: f64,
}

impl Gpu {
    /// Per-sequence decode rate in tokens/sec.
    fn rate(&self, token_time: f64, knee: usize, paused: bool) -> f64 {
        if paused || self.active.is_empty() {
            return 0.0;
        }
        let n = self.active.len() as f64;
        let share = (knee as f64 / n).min(1.0);
        share / token_time
    }

    fn update_to(&mut self, t: f64, token_time: f64, knee: usize, paused: bool) {
        let rate = self.rate(token_time, knee, paused);
        let dt = t - self.last;
        if dt > 0.0 && rate > 0.0 {
            for a in &mut self.active {
                a.remaining -= dt * rate;
            }
            self.work_done += dt * rate * self.active.len() as f64;
            self.busy_secs += dt;
        }
        self.last = t;
    }

    fn next_finish(&self, token_time: f64, knee: usize, paused: bool) -> Option<f64> {
        let rate = self.rate(token_time, knee, paused);
        if rate <= 0.0 {
            return None;
        }
        self.active
            .iter()
            .map(|a| self.last + a.remaining.max(0.0) / rate)
            .min_by(|a, b| a.partial_cmp(b).unwrap())
    }
}

/// Processor-sharing GPU pool with lazy completion-event invalidation.
pub struct GpuPool {
    gpus: Vec<Gpu>,
    pub token_time: f64,
    pub knee: usize,
    pub max_active: usize,
    paused: bool,
    /// completed pause intervals (weight-sync barriers), in seconds
    paused_total: f64,
    /// virtual time the current pause began, while paused
    paused_since: Option<f64>,
    /// (finish_time, gpu, epoch) — stale entries skipped on pop
    heap: BinaryHeap<Reverse<(T, usize, u64)>>,
    /// seq id -> gpu index
    placement: HashMap<u64, usize>,
}

impl GpuPool {
    pub fn new(n_gpus: usize, token_time: f64, knee: usize, max_active: usize) -> Self {
        assert!(n_gpus > 0 && knee > 0 && max_active >= knee);
        GpuPool {
            gpus: vec![Gpu::default(); n_gpus],
            token_time,
            knee,
            max_active,
            paused: false,
            paused_total: 0.0,
            paused_since: None,
            heap: BinaryHeap::new(),
            placement: HashMap::new(),
        }
    }

    pub fn n_gpus(&self) -> usize {
        self.gpus.len()
    }

    pub fn in_flight(&self) -> usize {
        self.placement.len()
    }

    /// Aggregate decode capacity in tokens/sec.
    pub fn capacity_rate(&self) -> f64 {
        self.gpus.len() as f64 * self.knee as f64 / self.token_time
    }

    pub fn total_work_done(&self, now: f64) -> f64 {
        // include progress up to `now` without mutating
        self.gpus
            .iter()
            .map(|g| {
                let rate = g.rate(self.token_time, self.knee, self.paused);
                g.work_done + rate * (now - g.last).max(0.0) * g.active.len() as f64
            })
            .sum()
    }

    /// GPU-seconds spent decoding (>= 1 resident sequence, unpaused)
    /// up to `now`, without mutating — the sim's DecodeBusy category.
    pub fn total_busy_secs(&self, now: f64) -> f64 {
        self.gpus
            .iter()
            .map(|g| {
                let decoding = g.rate(self.token_time, self.knee, self.paused) > 0.0;
                g.busy_secs + if decoding { (now - g.last).max(0.0) } else { 0.0 }
            })
            .sum()
    }

    /// Seconds the whole pool has spent suspended for weight sync up
    /// to `now` (each second costs `n_gpus` replica-seconds).
    pub fn paused_secs(&self, now: f64) -> f64 {
        self.paused_total + self.paused_since.map_or(0.0, |s| (now - s).max(0.0))
    }

    fn reschedule(&mut self, gi: usize) {
        self.gpus[gi].epoch += 1;
        if let Some(t) = self.gpus[gi].next_finish(self.token_time, self.knee, self.paused) {
            self.heap.push(Reverse((T(t), gi, self.gpus[gi].epoch)));
        }
    }

    /// Least-loaded GPU with a free slot.
    pub fn pick_gpu(&self) -> Option<usize> {
        self.gpus
            .iter()
            .enumerate()
            .filter(|(_, g)| g.active.len() < self.max_active)
            .min_by_key(|(_, g)| g.active.len())
            .map(|(i, _)| i)
    }

    pub fn has_capacity(&self) -> bool {
        self.pick_gpu().is_some()
    }

    /// Place a sequence on a specific GPU (prompt-group co-residency).
    pub fn submit_to(&mut self, gi: usize, id: u64, tokens: f64, now: f64) {
        assert!(!self.placement.contains_key(&id), "duplicate submit {id}");
        self.gpus[gi].update_to(now, self.token_time, self.knee, self.paused);
        self.gpus[gi].active.push(Active { id, remaining: tokens.max(1e-9) });
        self.placement.insert(id, gi);
        self.reschedule(gi);
    }

    /// Queue-scheduling placement: least-loaded GPU. Returns false if
    /// the whole pool is at max_active.
    pub fn submit(&mut self, id: u64, tokens: f64, now: f64) -> bool {
        match self.pick_gpu() {
            Some(gi) => {
                self.submit_to(gi, id, tokens, now);
                true
            }
            None => false,
        }
    }

    /// ABORT command: reclaim a running sequence (LLMProxy semantics).
    /// Returns remaining tokens if it was in flight.
    pub fn abort(&mut self, id: u64, now: f64) -> Option<f64> {
        let gi = self.placement.remove(&id)?;
        self.gpus[gi].update_to(now, self.token_time, self.knee, self.paused);
        let idx = self.gpus[gi].active.iter().position(|a| a.id == id)?;
        let a = self.gpus[gi].active.swap_remove(idx);
        self.reschedule(gi);
        Some(a.remaining.max(0.0))
    }

    /// Earliest completion event across the pool, if any.
    pub fn peek_completion(&mut self) -> Option<f64> {
        while let Some(Reverse((t, gi, epoch))) = self.heap.peek().copied() {
            if self.gpus[gi].epoch == epoch {
                return Some(t.0);
            }
            self.heap.pop();
        }
        None
    }

    /// Pop the completion at time `t` (must equal peek_completion()).
    /// Returns the finished sequence id.
    pub fn pop_completion(&mut self, t: f64) -> u64 {
        let Reverse((tt, gi, epoch)) = self.heap.pop().expect("no completion");
        debug_assert_eq!(self.gpus[gi].epoch, epoch);
        debug_assert!((tt.0 - t).abs() < 1e-9);
        self.gpus[gi].update_to(t, self.token_time, self.knee, self.paused);
        // finished = smallest remaining (numerically ~0)
        let idx = self.gpus[gi]
            .active
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.remaining.partial_cmp(&b.1.remaining).unwrap())
            .map(|(i, _)| i)
            .expect("completion on empty gpu");
        let a = self.gpus[gi].active.swap_remove(idx);
        self.placement.remove(&a.id);
        self.reschedule(gi);
        a.id
    }

    /// Suspend / resume the whole pool (weight-sync barrier).
    pub fn set_paused(&mut self, paused: bool, now: f64) {
        if self.paused == paused {
            return;
        }
        for gi in 0..self.gpus.len() {
            self.gpus[gi].update_to(now, self.token_time, self.knee, self.paused);
        }
        self.paused = paused;
        if paused {
            self.paused_since = Some(now);
        } else if let Some(s) = self.paused_since.take() {
            self.paused_total += (now - s).max(0.0);
        }
        for gi in 0..self.gpus.len() {
            self.reschedule(gi);
        }
    }

    /// Number of active sequences on each GPU (diagnostics/tests).
    pub fn loads(&self) -> Vec<usize> {
        self.gpus.iter().map(|g| g.active.len()).collect()
    }
}

/// M parallel single-slot FIFO servers (reward workers, CPU pools).
#[derive(Clone, Debug)]
pub struct ServicePool {
    free_at: Vec<f64>,
}

impl ServicePool {
    pub fn new(workers: usize) -> Self {
        ServicePool { free_at: vec![0.0; workers.max(1)] }
    }

    /// Enqueue a job of `dur` seconds at `now`; returns completion time.
    pub fn submit(&mut self, now: f64, dur: f64) -> f64 {
        let (i, start) = self
            .free_at
            .iter()
            .enumerate()
            .map(|(i, &f)| (i, f.max(now)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        self.free_at[i] = start + dur;
        self.free_at[i]
    }

    pub fn idle_from(&self) -> f64 {
        self.free_at.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_seq_full_speed() {
        let mut pool = GpuPool::new(1, 0.01, 4, 8);
        pool.submit(1, 100.0, 0.0);
        let t = pool.peek_completion().unwrap();
        assert!((t - 1.0).abs() < 1e-9, "{t}"); // 100 tokens * 0.01
        assert_eq!(pool.pop_completion(t), 1);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn knee_sharing_slows_decode() {
        // 8 seqs on a knee-4 gpu: each runs at half speed.
        let mut pool = GpuPool::new(1, 0.01, 4, 16);
        for id in 0..8 {
            pool.submit(id, 100.0, 0.0);
        }
        let t = pool.peek_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn below_knee_no_interference() {
        let mut pool = GpuPool::new(1, 0.01, 4, 16);
        pool.submit(1, 100.0, 0.0);
        pool.submit(2, 200.0, 0.0);
        let t1 = pool.peek_completion().unwrap();
        assert!((t1 - 1.0).abs() < 1e-9);
        pool.pop_completion(t1);
        let t2 = pool.peek_completion().unwrap();
        assert!((t2 - 2.0).abs() < 1e-9, "{t2}");
    }

    #[test]
    fn least_loaded_placement() {
        let mut pool = GpuPool::new(2, 0.01, 2, 4);
        pool.submit(1, 100.0, 0.0);
        pool.submit(2, 100.0, 0.0);
        assert_eq!(pool.loads(), vec![1, 1]);
    }

    #[test]
    fn abort_reclaims_and_speeds_up_rest() {
        let mut pool = GpuPool::new(1, 0.01, 1, 4);
        pool.submit(1, 100.0, 0.0);
        pool.submit(2, 100.0, 0.0); // sharing: both at half speed
        let rem = pool.abort(2, 0.5).unwrap();
        assert!((rem - 75.0).abs() < 1e-6, "{rem}"); // 0.5s at 50 tok/s
        let t = pool.peek_completion().unwrap();
        // seq 1 has 75 tokens left at full speed from t=0.5
        assert!((t - 1.25).abs() < 1e-9, "{t}");
    }

    #[test]
    fn pause_freezes_progress() {
        let mut pool = GpuPool::new(1, 0.01, 4, 8);
        pool.submit(1, 100.0, 0.0);
        pool.set_paused(true, 0.5);
        assert!(pool.peek_completion().is_none());
        pool.set_paused(false, 1.5); // 1s pause
        let t = pool.peek_completion().unwrap();
        assert!((t - 2.0).abs() < 1e-9, "{t}");
    }

    #[test]
    fn service_pool_fifo() {
        let mut p = ServicePool::new(2);
        assert!((p.submit(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((p.submit(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((p.submit(0.0, 1.0) - 2.0).abs() < 1e-12); // queues
    }

    #[test]
    fn capacity_respected() {
        let mut pool = GpuPool::new(1, 0.01, 1, 2);
        assert!(pool.submit(1, 10.0, 0.0));
        assert!(pool.submit(2, 10.0, 0.0));
        assert!(!pool.submit(3, 10.0, 0.0));
        assert!(!pool.has_capacity());
    }

    #[test]
    fn work_accounting() {
        let mut pool = GpuPool::new(1, 0.01, 4, 8);
        pool.submit(1, 100.0, 0.0);
        let t = pool.peek_completion().unwrap();
        pool.pop_completion(t);
        assert!((pool.total_work_done(t) - 100.0).abs() < 1e-6);
    }

    #[test]
    fn busy_and_paused_time_integrals() {
        let mut pool = GpuPool::new(2, 0.01, 4, 8);
        // one 100-token seq on gpu 0: busy exactly [0, 1], gpu 1 idle —
        // below the knee, so work_done*token_time/knee would UNDERCOUNT
        // busy time (1 token/step, not 4); the direct integral must not
        pool.submit(1, 100.0, 0.0);
        let t = pool.peek_completion().unwrap();
        pool.pop_completion(t);
        assert!((pool.total_busy_secs(t) - 1.0).abs() < 1e-9);
        assert_eq!(pool.paused_secs(t), 0.0);
        // a 2s weight-sync pause accrues paused time, not busy time
        pool.submit(2, 100.0, t);
        pool.set_paused(true, t + 0.5);
        assert!((pool.paused_secs(t + 1.5) - 1.0).abs() < 1e-9, "mid-pause read");
        pool.set_paused(false, t + 2.5);
        assert!((pool.paused_secs(t + 2.5) - 2.0).abs() < 1e-9);
        let done = pool.peek_completion().unwrap();
        assert!((done - (t + 3.0)).abs() < 1e-9, "0.5s decode + 2s pause + 0.5s decode");
        pool.pop_completion(done);
        assert!((pool.total_busy_secs(done) - 2.0).abs() < 1e-9, "pause must not count busy");
    }
}
