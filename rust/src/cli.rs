//! Minimal argument parser substrate (clap is unavailable offline):
//! `name=value` pairs plus positional subcommand.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + key=value options.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub command: String,
    opts: BTreeMap<String, String>,
}

impl Cli {
    pub fn parse(args: impl Iterator<Item = String>) -> Cli {
        let mut cli = Cli::default();
        for a in args {
            if let Some((k, v)) = a.split_once('=') {
                cli.opts.insert(k.trim_start_matches('-').to_string(), v.to_string());
            } else if cli.command.is_empty() {
                cli.command = a;
            }
        }
        cli
    }

    pub fn from_env() -> Cli {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Boolean option: `key=1|true|yes|on` (anything else is false).
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        match self.get(key) {
            Some(v) => matches!(v, "1" | "true" | "yes" | "on"),
            None => default,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_and_options() {
        let cli = Cli::parse(
            ["train", "steps=40", "--alpha=2.5", "model=small"].iter().map(|s| s.to_string()),
        );
        assert_eq!(cli.command, "train");
        assert_eq!(cli.parse_or("steps", 0usize), 40);
        assert_eq!(cli.parse_or("alpha", 0.0f64), 2.5);
        assert_eq!(cli.str_or("model", "tiny"), "small");
        assert_eq!(cli.parse_or("missing", 7u32), 7);
    }

    #[test]
    fn parses_bools() {
        let cli = Cli::parse(
            ["run", "rolling=true", "sync=0", "weird=maybe"].iter().map(|s| s.to_string()),
        );
        assert!(cli.bool_or("rolling", false));
        assert!(!cli.bool_or("sync", true));
        assert!(!cli.bool_or("weird", true));
        assert!(cli.bool_or("missing", true));
        assert!(!cli.bool_or("missing", false));
    }
}
