//! Artifact manifest (shapes/dtypes of AOT entry points), parsed with
//! the in-tree JSON substrate.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .context("spec.shape")?
            .iter()
            .map(|v| v.as_usize().context("dim"))
            .collect::<Result<_>>()?;
        let dtype = j.get("dtype").and_then(Json::as_str).context("spec.dtype")?.to_string();
        Ok(TensorSpec { shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub hlo: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub model: String,
    pub n_params: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub prompt_len: usize,
    pub decode_batch: usize,
    pub train_batch: usize,
    pub pg_variants: Vec<String>,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest json: {e}"))?;
        let field = |k: &str| j.get(k).and_then(Json::as_usize).context(k.to_string());
        let mut entries = BTreeMap::new();
        for (name, e) in j.get("entries").and_then(Json::as_obj).context("entries")? {
            let parse_specs = |k: &str| -> Result<Vec<TensorSpec>> {
                e.get(k)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("{name}.{k}"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            entries.insert(
                name.clone(),
                EntrySpec {
                    hlo: e.get("hlo").and_then(Json::as_str).context("hlo")?.to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                },
            );
        }
        Ok(Manifest {
            model: j.get("model").and_then(Json::as_str).context("model")?.to_string(),
            n_params: field("n_params")?,
            vocab: field("vocab")?,
            d_model: field("d_model")?,
            n_layers: field("n_layers")?,
            n_heads: field("n_heads")?,
            d_ff: field("d_ff")?,
            max_seq: field("max_seq")?,
            prompt_len: field("prompt_len")?,
            decode_batch: field("decode_batch")?,
            train_batch: field("train_batch")?,
            pg_variants: j
                .get("pg_variants")
                .and_then(Json::as_arr)
                .context("pg_variants")?
                .iter()
                .map(|v| v.as_str().map(str::to_string).context("variant"))
                .collect::<Result<_>>()?,
            entries,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "model": "tiny", "n_params": 10, "vocab": 64, "d_model": 8,
      "n_layers": 1, "n_heads": 2, "d_ff": 16, "max_seq": 32,
      "prompt_len": 8, "decode_batch": 4, "train_batch": 8,
      "pg_variants": ["ppo"],
      "entries": {
        "decode_step": {
          "hlo": "decode_step.hlo.txt",
          "inputs": [{"shape": [10], "dtype": "float32"}],
          "outputs": [{"shape": [4, 64], "dtype": "float32"}]
        }
      }
    }"#;

    #[test]
    fn parses() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.n_params, 10);
        assert_eq!(m.entries["decode_step"].outputs[0].shape, vec![4, 64]);
        assert_eq!(m.entries["decode_step"].outputs[0].elements(), 256);
        assert_eq!(m.pg_variants, vec!["ppo"]);
    }

    #[test]
    fn missing_field_errors() {
        assert!(Manifest::parse(r#"{"model": "x"}"#).is_err());
    }
}
