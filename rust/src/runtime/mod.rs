//! PJRT runtime: load AOT artifacts (HLO text + manifest + params) and
//! execute them on the CPU client.
//!
//! This is the only place the `xla` crate is touched. Python runs once
//! at build time (`make artifacts`); everything here is pure Rust on
//! the request path. Pattern follows /opt/xla-example/load_hlo:
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`.
//!
//! Threading note: PJRT wrapper types are not `Send`; each coordinator
//! thread that needs compute constructs its own [`ModelRuntime`] and
//! weights travel between threads as `Vec<f32>` — which is exactly the
//! paper's `model_update` broadcast (Section 4.2).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub use manifest::{EntrySpec, Manifest, TensorSpec};

/// A loaded model: manifest + lazily compiled entry-point executables.
pub struct ModelRuntime {
    client: PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    executables: RefCell<HashMap<String, PjRtLoadedExecutable>>,
}

/// Mutable training state held between `train_step` calls.
pub struct TrainState {
    pub params: Literal,
    pub m: Literal,
    pub v: Literal,
    pub step: f32,
}

/// One rollout-consumption minibatch, row-major [B, S] flattened.
#[derive(Clone, Debug, Default)]
pub struct TrainBatch {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    pub adv: Vec<f32>,
    pub logp_old: Vec<f32>,
    pub logp_prox: Vec<f32>,
    pub sign: Vec<f32>,
}

/// Diagnostics returned by one training step.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainStats {
    pub loss: f32,
    pub grad_norm: f32,
    pub mean_ratio: f32,
    pub max_ratio: f32,
    pub clip_frac: f32,
    pub entropy: f32,
}

impl ModelRuntime {
    /// Load `artifacts/<model>` (manifest + HLO text files).
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ModelRuntime { client, dir, manifest, executables: RefCell::new(HashMap::new()) })
    }

    /// Initial parameters produced by aot.py (flat f32 LE).
    pub fn load_init_params(&self) -> Result<Vec<f32>> {
        let raw = std::fs::read(self.dir.join("init_params.bin"))?;
        if raw.len() != 4 * self.manifest.n_params {
            bail!("init_params.bin: got {} bytes, want {}", raw.len(), 4 * self.manifest.n_params);
        }
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// Fresh training state from flat parameters (Adam moments zeroed).
    pub fn train_state(&self, flat: &[f32]) -> Result<TrainState> {
        anyhow::ensure!(flat.len() == self.manifest.n_params, "param size mismatch");
        let zeros = vec![0f32; flat.len()];
        Ok(TrainState {
            params: Literal::vec1(flat),
            m: Literal::vec1(&zeros),
            v: Literal::vec1(&zeros),
            step: 0.0,
        })
    }

    fn executable(&self, entry: &str) -> Result<()> {
        if self.executables.borrow().contains_key(entry) {
            return Ok(());
        }
        let spec = self
            .manifest
            .entries
            .get(entry)
            .with_context(|| format!("unknown entry point {entry:?}"))?;
        let path = self.dir.join(&spec.hlo);
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {entry}"))?;
        self.executables.borrow_mut().insert(entry.to_string(), exe);
        Ok(())
    }

    /// Force-compile every entry point (used by warmup / perf runs).
    pub fn compile_all(&self) -> Result<()> {
        let names: Vec<String> = self.manifest.entries.keys().cloned().collect();
        for n in names {
            self.executable(&n)?;
        }
        Ok(())
    }

    fn run(&self, entry: &str, args: &[Literal]) -> Result<Vec<Literal>> {
        self.executable(entry)?;
        let map = self.executables.borrow();
        let exe = map.get(entry).unwrap();
        let result = exe.execute::<Literal>(args).with_context(|| format!("executing {entry}"))?;
        // aot.py lowers with return_tuple=True: single tuple output.
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Next-token logits for a [decode_batch, max_seq] buffer at
    /// per-row positions `pos` (continuous batching: rows advance
    /// independently). Returns [decode_batch * vocab] row-major logits.
    pub fn decode_step(&self, params: &Literal, tokens: &[i32], pos: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.manifest.decode_batch, self.manifest.max_seq);
        anyhow::ensure!(tokens.len() == b * s, "decode tokens: {} != {}", tokens.len(), b * s);
        anyhow::ensure!(pos.len() == b, "decode pos: {} != {}", pos.len(), b);
        let toks = Literal::vec1(tokens).reshape(&[b as i64, s as i64])?;
        let out = self.run("decode_step", &[params.clone(), toks, Literal::vec1(pos)])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Per-token logprobs for a [train_batch, max_seq] buffer.
    pub fn seq_logprobs(&self, params: &Literal, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.manifest.train_batch, self.manifest.max_seq);
        anyhow::ensure!(tokens.len() == b * s, "logprob tokens: {} != {}", tokens.len(), b * s);
        let toks = Literal::vec1(tokens).reshape(&[b as i64, s as i64])?;
        let out = self.run("seq_logprobs", &[params.clone(), toks])?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// One off-policy policy-gradient + Adam update, in place on `st`.
    pub fn train_step(
        &self,
        variant: &str,
        st: &mut TrainState,
        lr: f32,
        batch: &TrainBatch,
    ) -> Result<TrainStats> {
        let entry = format!("train_step_{variant}");
        let (b, s) = (self.manifest.train_batch, self.manifest.max_seq);
        anyhow::ensure!(batch.tokens.len() == b * s, "train batch shape");
        anyhow::ensure!(batch.sign.len() == b, "sign shape");
        let dims = [b as i64, s as i64];
        let args = [
            st.params.clone(),
            st.m.clone(),
            st.v.clone(),
            Literal::scalar(st.step),
            Literal::scalar(lr),
            Literal::vec1(&batch.tokens).reshape(&dims)?,
            Literal::vec1(&batch.mask).reshape(&dims)?,
            Literal::vec1(&batch.adv).reshape(&dims)?,
            Literal::vec1(&batch.logp_old).reshape(&dims)?,
            Literal::vec1(&batch.logp_prox).reshape(&dims)?,
            Literal::vec1(&batch.sign),
        ];
        let mut out = self.run(&entry, &args)?;
        anyhow::ensure!(out.len() == 9, "train_step outputs: {}", out.len());
        let scalar = |l: &Literal| -> Result<f32> { Ok(l.get_first_element::<f32>()?) };
        let stats = TrainStats {
            loss: scalar(&out[3])?,
            grad_norm: scalar(&out[4])?,
            mean_ratio: scalar(&out[5])?,
            max_ratio: scalar(&out[6])?,
            clip_frac: scalar(&out[7])?,
            entropy: scalar(&out[8])?,
        };
        st.v = out.remove(2);
        st.m = out.remove(1);
        st.params = out.remove(0);
        st.step += 1.0;
        Ok(stats)
    }

    /// Snapshot current weights as a flat vector (the `model_update`
    /// broadcast payload).
    pub fn snapshot(&self, st: &TrainState) -> Result<Vec<f32>> {
        Ok(st.params.to_vec::<f32>()?)
    }

    /// Build a params literal from a broadcast snapshot.
    pub fn params_literal(&self, flat: &[f32]) -> Result<Literal> {
        anyhow::ensure!(flat.len() == self.manifest.n_params, "param size mismatch");
        Ok(Literal::vec1(flat))
    }
}
