//! Minimal JSON parser + emitter (std-only substrate; serde is not
//! resolvable offline). Covers the subset used by artifact manifests
//! and metric dumps: objects, arrays, strings (with escapes), numbers,
//! booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let n = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(n).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape \\{}", c as char)),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""A""#).unwrap();
        assert_eq!(v.as_str(), Some("A"));
    }
}
