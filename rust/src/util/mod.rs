//! Std-only utility substrates (the offline registry carries no
//! rand/serde/etc. — DESIGN.md §7).

pub mod json;
pub mod rng;

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (linear interpolation) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!(std_dev(&xs) > 1.0 && std_dev(&xs) < 1.2);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
