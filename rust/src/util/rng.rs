//! Deterministic PRNG + distribution sampling (std-only substrate).
//!
//! PCG64-DXSM-style generator; Box-Muller normals; lognormal with
//! mean/percentile calibration helpers used by the workload models.

/// PCG-XSH-RR 64/32 state extended to produce u64 via two draws.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut r = Rng {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
            spare: None,
        };
        r.next_u64();
        r.state = r.state.wrapping_add(0x9e3779b97f4a7c15u128 ^ (seed as u128));
        r.next_u64();
        r
    }

    /// Derive an independent stream (for per-worker determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * th.sin());
        r * th.cos()
    }

    /// Normal with mean/std, truncated at `lo`.
    pub fn normal_trunc(&mut self, mean: f64, std: f64, lo: f64) -> f64 {
        (mean + std * self.normal()).max(lo)
    }

    /// Lognormal parameterized by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with the given mean.
    pub fn exponential(&mut self, mean: f64) -> f64 {
        -mean * self.f64().max(1e-300).ln()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return self.below(weights.len());
        }
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample a token id from logits (temperature-1 softmax — the paper's
    /// raw-logit sampling constraint, Appendix A).
    pub fn sample_logits(&mut self, logits: &[f32]) -> usize {
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut weights: Vec<f64> = Vec::with_capacity(logits.len());
        for &l in logits {
            weights.push(((l - max) as f64).exp());
        }
        self.categorical(&weights)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Solve (mu, sigma) of a lognormal from a target mean and a target
/// p99.9/mean tail factor — used to calibrate response-length
/// distributions to the paper's "longest exceeds median by >20x".
pub fn lognormal_params(mean: f64, sigma: f64) -> (f64, f64) {
    // E[X] = exp(mu + sigma^2/2)  =>  mu = ln(mean) - sigma^2/2
    (mean.ln() - sigma * sigma / 2.0, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let m: f64 = (0..20_000).map(|_| r.f64()).sum::<f64>() / 20_000.0;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let s = crate::util::std_dev(&xs);
        assert!(m.abs() < 0.02, "{m}");
        assert!((s - 1.0).abs() < 0.02, "{s}");
    }

    #[test]
    fn lognormal_calibration() {
        let (mu, sigma) = lognormal_params(2000.0, 1.0);
        let mut r = Rng::new(3);
        let xs: Vec<f64> = (0..40_000).map(|_| r.lognormal(mu, sigma)).collect();
        let m = crate::util::mean(&xs);
        assert!((m - 2000.0).abs() / 2000.0 < 0.05, "{m}");
        // heavy tail: max / median well above 10x at sigma = 1
        let med = crate::util::percentile(&xs, 50.0);
        let max = xs.iter().cloned().fold(0.0, f64::max);
        assert!(max / med > 10.0);
    }

    #[test]
    fn categorical_degenerate() {
        let mut r = Rng::new(4);
        assert_eq!(r.categorical(&[0.0, 1.0, 0.0]), 1);
    }

    #[test]
    fn sample_logits_prefers_max() {
        let mut r = Rng::new(5);
        let logits = vec![0.0f32, 10.0, 0.0, 0.0];
        let hits = (0..200).filter(|_| r.sample_logits(&logits) == 1).count();
        assert!(hits > 190, "{hits}");
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
