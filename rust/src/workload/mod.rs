//! Workload models calibrated to the paper's measurements:
//! response-length distributions (long-tail, Fig 1), environment
//! latency distributions (Gaussian, Fig 9), GPU decode/training cost
//! models, and failure injection (Section 5.2.2).

use crate::util::rng::{lognormal_params, Rng};

/// Response-length distribution for one model family.
///
/// The paper reports DAPO-Math rollouts with mean ~2k tokens for
/// Qwen3-8B-Base and ~11k for the Think model, max 30720, with the
/// longest responses exceeding the median by >20x (long tail).
#[derive(Clone, Copy, Debug)]
pub struct LengthProfile {
    /// underlying lognormal parameters
    mu: f64,
    sigma: f64,
    pub cap: usize,
    pub mean_target: f64,
}

impl LengthProfile {
    pub fn new(mean_tokens: f64, sigma: f64, cap: usize) -> Self {
        let (mu, sigma) = lognormal_params(mean_tokens, sigma);
        LengthProfile { mu, sigma, cap, mean_target: mean_tokens }
    }

    /// Qwen3-8B-Base profile: short mean, very heavy tail
    /// (empirically the Base model rarely saturates the 30720 cap).
    pub fn qwen3_base() -> Self {
        Self::new(2000.0, 1.1, 16384)
    }

    /// Qwen3-8B-Think profile: long mean, moderate tail.
    pub fn qwen3_think() -> Self {
        Self::new(11000.0, 0.75, 30720)
    }

    /// Fixed-length profile (for controlled tests).
    pub fn constant(len: usize) -> Self {
        LengthProfile { mu: (len as f64).ln(), sigma: 0.0, cap: len.max(1), mean_target: len as f64 }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let l = rng.lognormal(self.mu, self.sigma);
        (l.round() as usize).clamp(1, self.cap)
    }

    /// Scale the mean (e.g. Table 1's 4K/8K/16K/32K sweep).
    pub fn with_mean(&self, mean_tokens: f64) -> Self {
        Self::new(mean_tokens, self.sigma, self.cap)
    }
}

/// Open-loop bursty arrival trace: a square wave between `burst_rate`
/// (for `duty` of every `period`) and `base_rate` (the rest), with
/// exponential inter-arrival times at the current rate. This is the
/// demand shape the elastic-fleet autoscaler is for — a static fleet
/// must be provisioned for the burst and idles through the trough,
/// while the scaler follows the wave (see `benches/fig_autoscale.rs`).
#[derive(Clone, Copy, Debug)]
pub struct BurstTrace {
    /// arrivals per virtual second outside bursts (> 0)
    pub base_rate: f64,
    /// arrivals per virtual second during bursts
    pub burst_rate: f64,
    /// seconds per burst cycle
    pub period: f64,
    /// fraction of each period spent at `burst_rate` (bursts lead)
    pub duty: f64,
}

impl BurstTrace {
    /// Instantaneous arrival rate at virtual time `t`.
    pub fn rate_at(&self, t: f64) -> f64 {
        let phase = (t.max(0.0) % self.period) / self.period;
        if phase < self.duty {
            self.burst_rate
        } else {
            self.base_rate
        }
    }

    /// Next arrival time after `t` (exponential inter-arrival at the
    /// rate in force at `t` — a step-rate approximation that keeps the
    /// sim event loop single-pass and deterministic).
    pub fn next_arrival(&self, t: f64, rng: &mut Rng) -> f64 {
        let rate = self.rate_at(t).max(1e-9);
        t + rng.exponential(1.0 / rate)
    }

    /// Mean arrival rate over a full cycle.
    pub fn mean_rate(&self) -> f64 {
        self.duty * self.burst_rate + (1.0 - self.duty) * self.base_rate
    }
}

/// Gaussian environment step latency, truncated below (Fig 9).
#[derive(Clone, Copy, Debug)]
pub struct EnvLatency {
    pub mean: f64,
    pub std: f64,
    pub floor: f64,
}

impl EnvLatency {
    pub fn gaussian(mean: f64, std: f64) -> Self {
        EnvLatency { mean, std, floor: 0.05 }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.normal_trunc(self.mean, self.std, self.floor)
    }
}

/// Failure injection for agentic environments (Section 5.2.2):
/// fail-slow multiplies latency; fail-stop kills the trajectory.
#[derive(Clone, Copy, Debug, Default)]
pub struct FailureModel {
    pub fail_slow_prob: f64,
    pub fail_slow_factor: f64,
    pub fail_stop_prob: f64,
}

impl FailureModel {
    pub fn none() -> Self {
        Self::default()
    }

    /// Calibrated to "failures are common" in SWE-like envs.
    pub fn swe_like() -> Self {
        FailureModel { fail_slow_prob: 0.08, fail_slow_factor: 6.0, fail_stop_prob: 0.03 }
    }

    pub fn alfworld_like() -> Self {
        FailureModel { fail_slow_prob: 0.05, fail_slow_factor: 4.0, fail_stop_prob: 0.01 }
    }
}

/// GPU decode cost model. Decoding is memory-bandwidth bound: the
/// per-token step time is independent of how many GPUs serve the fleet,
/// which is exactly why scale-out does not shorten a single long rollout
/// (paper Section 1).
#[derive(Clone, Copy, Debug)]
pub struct DecodeCost {
    /// seconds per generated token per sequence (short-context)
    pub token_time: f64,
    /// fixed prefill + scheduling overhead per sequence
    pub prefill_time: f64,
    /// attention KV-read growth: decoding token t costs
    /// token_time * (1 + t / ctx_scale), so a length-L response costs
    /// ~ token_time * L * (1 + L / (2 ctx_scale)). This superlinear
    /// term is what makes 30k-token stragglers so much worse than
    /// their length alone suggests (the paper's long-tail rollouts).
    pub ctx_scale: f64,
}

impl DecodeCost {
    /// ~125 tok/s/sequence short-context decode (SGLang/vLLM-class
    /// serving of an 8B model), halving by ~32k context.
    pub fn qwen3_8b() -> Self {
        DecodeCost { token_time: 0.008, prefill_time: 0.3, ctx_scale: 16384.0 }
    }

    /// Effective decode work in short-context token units.
    pub fn effective_tokens(&self, tokens: usize) -> f64 {
        let l = tokens as f64;
        l * (1.0 + l / (2.0 * self.ctx_scale))
    }

    pub fn gen_time(&self, tokens: usize) -> f64 {
        self.prefill_time + self.token_time * self.effective_tokens(tokens)
    }

    /// Scale decode cost with model size (Table 1 model-size sweep).
    pub fn scaled(&self, factor: f64) -> Self {
        DecodeCost { token_time: self.token_time * factor, ..*self }
    }
}

/// Training-stage cost model: fixed overhead (load/offload, weight
/// sync) plus per-sample compute that parallelizes over the train pool.
/// Fig 3b: "training time scales approximately linearly with sample
/// count, with fixed constant overheads".
#[derive(Clone, Copy, Debug)]
pub struct TrainCost {
    pub fixed: f64,
    /// GPU-seconds per sample per epoch (divided by pool size)
    pub per_sample: f64,
    /// reuse count E (ppo_epochs)
    pub epochs: f64,
}

impl TrainCost {
    /// Calibrated so the rollout stage accounts for ~70% of a sync step
    /// at 1:1 pools (paper Section 1): one fwd+bwd plus the reference
    /// and proximal inference passes (paper footnote 1) over ~11k
    /// tokens costs ~4.4 GPU-seconds per sample.
    pub fn qwen3_8b() -> Self {
        Self::for_mean_len(11000.0)
    }

    /// Scale the per-sample cost with mean sequence length
    /// (~0.4 GPU-seconds per 1k consumed tokens for the 8B profile).
    pub fn for_mean_len(mean_tokens: f64) -> Self {
        TrainCost { fixed: 25.0, per_sample: 0.4 * mean_tokens / 1000.0, epochs: 1.0 }
    }

    pub fn step_time(&self, n_samples: usize, pool: usize) -> f64 {
        self.fixed + self.epochs * self.per_sample * n_samples as f64 / pool.max(1) as f64
    }
}

/// Reward/verifier cost (runs on CPU workers, overlaps generation when
/// queue scheduling is on).
#[derive(Clone, Copy, Debug)]
pub struct RewardCost {
    pub mean: f64,
    pub std: f64,
}

impl RewardCost {
    pub fn verifier() -> Self {
        RewardCost { mean: 0.4, std: 0.2 }
    }

    pub fn sample(&self, rng: &mut Rng) -> f64 {
        rng.normal_trunc(self.mean, self.std, 0.01)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_profiles_hit_target_means() {
        let mut rng = Rng::new(1);
        for profile in [LengthProfile::qwen3_base(), LengthProfile::qwen3_think()] {
            let xs: Vec<f64> = (0..30_000).map(|_| profile.sample(&mut rng) as f64).collect();
            let mean = crate::util::mean(&xs);
            // cap truncation pulls the mean slightly below target
            assert!(
                (mean - profile.mean_target).abs() / profile.mean_target < 0.15,
                "mean {mean} vs target {}",
                profile.mean_target
            );
        }
    }

    #[test]
    fn base_profile_is_long_tailed() {
        let mut rng = Rng::new(2);
        let p = LengthProfile::qwen3_base();
        let xs: Vec<f64> = (0..30_000).map(|_| p.sample(&mut rng) as f64).collect();
        let med = crate::util::percentile(&xs, 50.0);
        let max = xs.iter().cloned().fold(0.0, f64::max);
        // heavy tail: longest exceeds the median many times over
        assert!(max / med > 8.0, "tail factor {}", max / med);
    }

    #[test]
    fn constant_profile() {
        let mut rng = Rng::new(3);
        let p = LengthProfile::constant(100);
        for _ in 0..10 {
            assert_eq!(p.sample(&mut rng), 100);
        }
    }

    #[test]
    fn burst_trace_alternates_rates_and_orders_arrivals() {
        let trace =
            BurstTrace { base_rate: 1.0, burst_rate: 10.0, period: 100.0, duty: 0.3 };
        assert_eq!(trace.rate_at(0.0), 10.0);
        assert_eq!(trace.rate_at(29.0), 10.0);
        assert_eq!(trace.rate_at(31.0), 1.0);
        assert_eq!(trace.rate_at(131.0), 1.0, "periodic");
        assert!((trace.mean_rate() - 3.7).abs() < 1e-12);
        let mut rng = Rng::new(9);
        let mut t = 0.0;
        let mut in_burst = 0usize;
        for _ in 0..2000 {
            let next = trace.next_arrival(t, &mut rng);
            assert!(next > t, "arrivals must advance time");
            t = next;
            if (t % trace.period) / trace.period < trace.duty {
                in_burst += 1;
            }
        }
        // most arrivals land inside the burst windows (10x the rate on
        // 30% of the time axis)
        assert!(in_burst > 1000, "burst arrivals: {in_burst}/2000");
    }

    #[test]
    fn env_latency_respects_floor() {
        let mut rng = Rng::new(4);
        let lat = EnvLatency::gaussian(1.0, 5.0);
        for _ in 0..1000 {
            assert!(lat.sample(&mut rng) >= lat.floor);
        }
    }

    #[test]
    fn train_cost_parallelizes() {
        let c = TrainCost::qwen3_8b();
        assert!(c.step_time(256, 32) < c.step_time(256, 16));
        assert!(c.step_time(256, 16) > c.fixed);
    }
}
