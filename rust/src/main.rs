//! roll-flash launcher: train on the real engine, or run the virtual
//! cluster simulator, from a paper-style YAML config or CLI options.
//!
//!   roll-flash train  config=examples/rlvr.yaml steps=40
//!   roll-flash train  model=tiny alpha=2 variant=tis steps=20 \
//!                     num_replicas=3 route_policy=ewma rolling_update=true \
//!                     num_workers=8 redundancy_factor=1.25 \
//!                     partial_migration=true min_salvage_tokens=4 \
//!                     salvage_timeout=0.5 reclaim_in_place=true \
//!                     autoscale=true min_replicas=1 max_replicas=8 \
//!                     target_queue_depth=8 autoscale_interval=1 \
//!                     autoscale_cooldown=2 autoscale_hysteresis=0.25 \
//!                     adaptive_target=true decode_knee=16 \
//!                     predictor_beta=0.2 predictor_sketch=64 \
//!                     predictor_quantile=0.8 predictor_min_samples=8 \
//!                     predictor_default_len=256 \
//!                     kv_cache=true kv_block_tokens=16 kv_bytes_budget=67108864 \
//!                     kv_bytes_per_token=4096 kv_invalidate_on_sync=true \
//!                     trace=true trace_ring=4096 trace_path=/tmp/roll-trace \
//!                     telemetry=true telemetry_window=5 \
//!                     telemetry_prom=/tmp/roll-telemetry/metrics.prom \
//!                     telemetry_jsonl=/tmp/roll-telemetry/verdicts.jsonl \
//!                     governor=true governor_budget=8 governor_alpha_max=4 \
//!                     governor_every_k=4 governor_interval=5 governor_cooldown=10 \
//!                     governor_hysteresis=0.25 governor_relax_frac=0.7 \
//!                     governor_barrier_frac=0.9
//!   roll-flash simulate gpus=64 profile=think alpha=2 steps=3
//!   roll-flash inspect artifacts=artifacts/tiny

use std::path::PathBuf;

use anyhow::Result;
use roll_flash::cli::Cli;
use roll_flash::config::{PgVariant, RollConfig};
use roll_flash::coordinator::{
    format_log, run_training, AutoscaleCfg, ControllerCfg, GovernorCfg, KvCacheCfg, PredictorCfg,
    RolloutSystem, RolloutSystemCfg, RoutePolicy, TraceCfg,
};
use roll_flash::env::math::MathEnv;
use roll_flash::runtime::ModelRuntime;
use roll_flash::sim::rlvr::{run as run_sim, RlvrSimConfig, Scheduling};
use roll_flash::workload::{LengthProfile, TrainCost};

fn main() -> Result<()> {
    let cli = Cli::from_env();
    match cli.command.as_str() {
        "train" => train(&cli),
        "simulate" => simulate(&cli),
        "inspect" => inspect(&cli),
        _ => {
            eprintln!(
                "usage: roll-flash <train|simulate|inspect> [key=value ...]\n\
                 train:    config=<yaml> | model=<tiny|small> alpha=<f> variant=<pg> steps=<n> lr=<f>\n\
                 \u{20}         num_replicas=<n> route_policy=<round_robin|least_outstanding|queue|ewma|tail_aware> rolling_update=<bool>\n\
                 \u{20}         num_workers=<n> redundancy_factor=<f> partial_migration=<bool> min_salvage_tokens=<n>\n\
                 \u{20}         salvage_timeout=<f> reclaim_in_place=<bool>\n\
                 \u{20}         autoscale=<bool> min_replicas=<n> max_replicas=<n> target_queue_depth=<f>\n\
                 \u{20}         autoscale_interval=<f> autoscale_cooldown=<f> autoscale_hysteresis=<f>\n\
                 \u{20}         adaptive_target=<bool> decode_knee=<f>\n\
                 \u{20}         predictor_beta=<f> predictor_sketch=<n> predictor_quantile=<f>\n\
                 \u{20}         predictor_min_samples=<n> predictor_default_len=<f>\n\
                 \u{20}         kv_cache=<bool> kv_block_tokens=<n> kv_bytes_budget=<n>\n\
                 \u{20}         kv_bytes_per_token=<n> kv_invalidate_on_sync=<bool>\n\
                 \u{20}         trace=<bool> trace_ring=<n> trace_path=<dir>\n\
                 \u{20}         telemetry=<bool> telemetry_window=<f> telemetry_prom=<file> telemetry_jsonl=<file>\n\
                 \u{20}         governor=<bool> governor_budget=<f> governor_alpha_max=<f> governor_every_k=<n>\n\
                 \u{20}         governor_interval=<f> governor_cooldown=<f> governor_hysteresis=<f>\n\
                 \u{20}         governor_relax_frac=<f> governor_barrier_frac=<f>\n\
                 simulate: gpus=<n> profile=<base|think> alpha=<f> steps=<n> [naive=1]\n\
                 inspect:  artifacts=<dir>"
            );
            Ok(())
        }
    }
}

fn train(cli: &Cli) -> Result<()> {
    let cfg = match cli.get("config") {
        Some(path) => RollConfig::from_file(path)?,
        None => RollConfig::default(),
    };
    let model = cli.str_or("model", &cfg.pretrain);
    let alpha: f64 = cli.parse_or("alpha", cfg.async_generation_ratio);
    let variant = match cli.get("variant") {
        Some(v) => PgVariant::parse(v)?,
        None => cfg.pg_variant,
    };
    let steps: usize = cli.parse_or("steps", 20);
    let lr: f32 = cli.parse_or("lr", cfg.actor_train.learning_rate as f32);
    let num_replicas: usize = cli.parse_or("num_replicas", cfg.num_replicas);
    let route_policy = match cli.get("route_policy") {
        Some(s) => RoutePolicy::parse(s)?,
        None => cfg.route_policy,
    };
    let rolling_update = cli.bool_or("rolling_update", cfg.rolling_update);
    let num_workers: usize = cli.parse_or("num_workers", cfg.num_workers);
    let redundancy_factor: f64 = cli.parse_or("redundancy_factor", cfg.redundancy_factor);
    let partial_migration = cli.bool_or("partial_migration", cfg.partial_migration);
    let min_salvage_tokens: usize =
        cli.parse_or("min_salvage_tokens", cfg.min_salvage_tokens).max(1);
    let salvage_timeout: f64 = cli.parse_or("salvage_timeout", cfg.salvage_timeout);
    let reclaim_in_place = cli.bool_or("reclaim_in_place", cfg.reclaim_in_place);
    let autoscale = AutoscaleCfg {
        enabled: cli.bool_or("autoscale", cfg.autoscale.enabled),
        min_replicas: cli.parse_or("min_replicas", cfg.autoscale.min_replicas),
        max_replicas: cli.parse_or("max_replicas", cfg.autoscale.max_replicas),
        target_queue_depth: cli.parse_or("target_queue_depth", cfg.autoscale.target_queue_depth),
        interval: cli.parse_or("autoscale_interval", cfg.autoscale.interval),
        cooldown: cli.parse_or("autoscale_cooldown", cfg.autoscale.cooldown),
        hysteresis: cli.parse_or("autoscale_hysteresis", cfg.autoscale.hysteresis),
        adaptive_target: cli.bool_or("adaptive_target", cfg.autoscale.adaptive_target),
        decode_knee: cli.parse_or("decode_knee", cfg.autoscale.decode_knee),
    };
    let predictor = PredictorCfg {
        ewma_beta: cli.parse_or("predictor_beta", cfg.predictor.ewma_beta),
        sketch_capacity: cli.parse_or("predictor_sketch", cfg.predictor.sketch_capacity),
        long_quantile: cli.parse_or("predictor_quantile", cfg.predictor.long_quantile),
        min_samples: cli.parse_or("predictor_min_samples", cfg.predictor.min_samples),
        default_len: cli.parse_or("predictor_default_len", cfg.predictor.default_len),
    };
    let kv_cache = KvCacheCfg {
        enabled: cli.bool_or("kv_cache", cfg.kv_cache.enabled),
        block_tokens: cli.parse_or("kv_block_tokens", cfg.kv_cache.block_tokens),
        kv_bytes_budget: cli.parse_or("kv_bytes_budget", cfg.kv_cache.kv_bytes_budget),
        bytes_per_token: cli.parse_or("kv_bytes_per_token", cfg.kv_cache.bytes_per_token),
        invalidate_on_weight_sync: cli
            .bool_or("kv_invalidate_on_sync", cfg.kv_cache.invalidate_on_weight_sync),
    };
    let governor = GovernorCfg {
        enabled: cli.bool_or("governor", cfg.governor.enabled),
        gap_budget: cli.parse_or("governor_budget", cfg.governor.gap_budget),
        alpha_max: cli.parse_or("governor_alpha_max", cfg.governor.alpha_max),
        every_k: cli.parse_or("governor_every_k", cfg.governor.every_k),
        relax_frac: cli.parse_or("governor_relax_frac", cfg.governor.relax_frac),
        barrier_frac: cli.parse_or("governor_barrier_frac", cfg.governor.barrier_frac),
        interval: cli.parse_or("governor_interval", cfg.governor.interval),
        cooldown: cli.parse_or("governor_cooldown", cfg.governor.cooldown),
        hysteresis: cli.parse_or("governor_hysteresis", cfg.governor.hysteresis),
        // resolved from the batch shape by controller_governor()
        step_quota: 0,
    };
    // telemetry export paths on the CLI imply the plane, like the
    // YAML block's presence does — and so does the governor, which
    // acts on the plane's closed version-gap windows
    let mut telemetry = cfg.telemetry.clone();
    telemetry.enabled = cli.bool_or(
        "telemetry",
        cfg.telemetry.enabled
            || governor.enabled
            || cli.get("telemetry_prom").is_some()
            || cli.get("telemetry_jsonl").is_some(),
    );
    telemetry.window_secs = cli.parse_or("telemetry_window", cfg.telemetry.window_secs);
    if let Some(p) = cli.get("telemetry_prom") {
        telemetry.prometheus_path = Some(PathBuf::from(p));
    }
    if let Some(p) = cli.get("telemetry_jsonl") {
        telemetry.verdict_path = Some(PathBuf::from(p));
    }
    // a trace_path on the CLI implies tracing, like the YAML block
    let trace = TraceCfg {
        enabled: cli.bool_or("trace", cfg.trace.enabled || cli.get("trace_path").is_some()),
        ring_capacity: cli.parse_or("trace_ring", cfg.trace.ring_capacity),
        export_path: cli.get("trace_path").map(PathBuf::from).or(cfg.trace.export_path.clone()),
    };
    let trace_export = trace.export_path.clone().filter(|_| trace.enabled);

    // resolved against the crate dir (where `make artifacts` writes),
    // not the CWD, so the CLI works from the workspace root too
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(&model);
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` (missing {model})");
    let rt = ModelRuntime::load(&dir)?;
    let weights = rt.load_init_params()?;
    let mut st = rt.train_state(&weights)?;
    let group_size = 4;
    let n_groups = rt.manifest.train_batch / group_size;

    let fleet = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: n_groups,
        env_group_size: group_size,
        consume_groups: n_groups,
        consume_group_size: group_size,
        alpha,
        seed: cfg.seed,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
        num_workers,
        redundancy_factor,
        num_replicas,
        route_policy,
        rolling_update,
        partial_migration,
        min_salvage_tokens,
        salvage_timeout,
        reclaim_in_place,
        autoscale,
        trace,
        predictor,
        kv_cache,
        telemetry,
        governor,
    };
    fleet.validate()?;
    println!(
        "train: model={model} alpha={alpha} variant={} steps={steps} replicas={num_replicas} route={} rolling={rolling_update} workers={num_workers} redundancy={redundancy_factor} partial_migration={partial_migration} governor={} autoscale={}",
        variant.as_str(),
        route_policy.as_str(),
        if governor.enabled {
            format!("[budget={} alpha_max={}]", governor.gap_budget, governor.alpha_max)
        } else {
            "off".into()
        },
        if autoscale.enabled {
            format!(
                "[{}..{}] target={} every {}s",
                autoscale.min_replicas,
                autoscale.max_replicas,
                autoscale.target_queue_depth,
                autoscale.interval
            )
        } else {
            "off".into()
        }
    );
    let system = RolloutSystem::start(&fleet, weights, |_, _| MathEnv::new())?;
    let ctl = ControllerCfg {
        variant,
        steps,
        lr,
        n_groups,
        group_size,
        sync_mode: alpha == 0.0,
        autoscale: fleet.controller_autoscale(),
        telemetry: fleet.controller_telemetry(),
        governor: fleet.controller_governor(),
    };
    let logs = run_training(&rt, &mut st, &system.proxy, &system.buffer, &ctl)?;
    for l in &logs {
        println!("{}", format_log(l));
    }
    let report = system.shutdown()?;
    println!("max version gap {} (alpha {alpha})", report.buffer.max_version_gap);
    println!(
        "engine: {} episodes (peak {} in flight), {} redundant aborts, {} abandoned",
        report.engine.episodes,
        report.engine.peak_inflight,
        report.engine.redundant_aborts,
        report.engine.abandoned
    );
    if num_replicas > 1 || autoscale.enabled {
        println!(
            "fleet: {} migrations ({} resumed, {} reclaimed in place), {} rolling waves, tokens salvaged {} / wasted {}",
            report.pool.migrated,
            report.pool.resumed,
            report.pool.reclaimed_in_place,
            report.pool.sync_waves,
            report.pool.tokens.salvaged_tokens,
            report.pool.tokens.wasted_tokens
        );
        if autoscale.enabled {
            println!(
                "elastic: grew {} / retired {} replicas, {:.1} replica-seconds served",
                report.pool.grown,
                report.pool.retired.len(),
                report.pool.replica_seconds()
            );
        }
        print!("{}", report.pool.format_table());
    }
    if kv_cache.enabled {
        println!(
            "kv cache: {} hits / {} misses, {} prefix tokens reused, {} blocks evicted",
            report.pool.kv_hits,
            report.pool.kv_misses,
            report.pool.kv_hit_tokens,
            report.pool.kv_evictions
        );
    }
    if let Some(p) = &trace_export {
        println!(
            "trace: wrote {0}/trace.json (chrome://tracing), {0}/trace.jsonl, {0}/metrics.txt",
            p.display()
        );
    }
    if fleet.telemetry.enabled {
        if let Some(p) = &fleet.telemetry.prometheus_path {
            println!("telemetry: wrote {} (prometheus text exposition)", p.display());
        }
        if let Some(p) = &fleet.telemetry.verdict_path {
            println!("telemetry: wrote {} (verdict timeline jsonl)", p.display());
        }
    }
    Ok(())
}

fn simulate(cli: &Cli) -> Result<()> {
    let gpus: usize = cli.parse_or("gpus", 64);
    let alpha: f64 = cli.parse_or("alpha", 2.0);
    let steps: usize = cli.parse_or("steps", 3);
    let profile = cli.str_or("profile", "think");
    let (lengths, mean) = match profile.as_str() {
        "base" => (LengthProfile::qwen3_base(), 2000.0),
        _ => (LengthProfile::qwen3_think(), 11000.0),
    };
    let mut c = RlvrSimConfig::paper_default(gpus / 2, gpus - gpus / 2);
    c.lengths = lengths;
    c.train = TrainCost::for_mean_len(mean);
    c.async_ratio = alpha;
    c.steps = steps;
    if cli.parse_or("naive", 0) == 1 {
        c.scheduling = Scheduling::BatchRollout;
        c.replicate = false;
        c.async_ratio = 0.0;
    }
    let r = run_sim(&c);
    println!(
        "profile={profile} gpus={gpus} alpha={} -> {:.0}s/step, {:.0} samples/h, util {:.2}, max gap {}",
        c.async_ratio,
        r.mean_step_time(),
        r.samples_per_hour(),
        r.gen_utilization,
        r.max_version_gap
    );
    Ok(())
}

fn inspect(cli: &Cli) -> Result<()> {
    let default = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    let dir = match cli.get("artifacts") {
        Some(p) => PathBuf::from(p),
        None => default,
    };
    let rt = ModelRuntime::load(&dir)?;
    let m = &rt.manifest;
    println!(
        "model {} | {} params | vocab {} | d_model {} | layers {} | heads {} | seq {}",
        m.model, m.n_params, m.vocab, m.d_model, m.n_layers, m.n_heads, m.max_seq
    );
    for (name, e) in &m.entries {
        println!(
            "  {name}: {} inputs -> {} outputs ({})",
            e.inputs.len(),
            e.outputs.len(),
            e.hlo
        );
    }
    Ok(())
}
