//! Typed configuration, mirroring the paper's appendix config schema
//! (RLVR pipeline, agentic pipeline, redundant-env mode).

pub mod yaml;

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::async_governor::GovernorCfg;
use crate::coordinator::autoscaler::AutoscaleCfg;
use crate::coordinator::kv_index::KvCacheCfg;
use crate::coordinator::length_predictor::PredictorCfg;
use crate::coordinator::routing::RoutePolicy;
use crate::metrics::telemetry::TelemetryCfg;
use crate::metrics::trace::TraceCfg;
use crate::util::json::Json;

/// Off-policy objective selector (`pg_variant` in the paper config).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PgVariant {
    Ppo,
    DecoupledPpo,
    Tis,
    Cispo,
    Topr,
    ToprWeighted,
    Reinforce,
}

impl PgVariant {
    pub const ALL: [PgVariant; 7] = [
        PgVariant::Ppo,
        PgVariant::DecoupledPpo,
        PgVariant::Tis,
        PgVariant::Cispo,
        PgVariant::Topr,
        PgVariant::ToprWeighted,
        PgVariant::Reinforce,
    ];

    /// Artifact entry-point suffix (matches kernels/ref.py VARIANTS).
    pub fn as_str(self) -> &'static str {
        match self {
            PgVariant::Ppo => "ppo",
            PgVariant::DecoupledPpo => "decoupled_ppo",
            PgVariant::Tis => "tis",
            PgVariant::Cispo => "cispo",
            PgVariant::Topr => "topr",
            PgVariant::ToprWeighted => "topr_weighted",
            PgVariant::Reinforce => "reinforce",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Self::ALL
            .into_iter()
            .find(|v| v.as_str() == s)
            .with_context(|| format!("unknown pg_variant {s:?}"))
    }

    /// Variants that use a proximal policy forward pass.
    pub fn needs_prox(self) -> bool {
        matches!(self, PgVariant::DecoupledPpo)
    }
}

/// Per-actor resource + hyperparameter block.
#[derive(Clone, Debug)]
pub struct ActorConfig {
    pub device_mapping: Vec<usize>,
    pub learning_rate: f64,
    pub max_new_tokens: usize,
    pub temperature: f64,
}

impl Default for ActorConfig {
    fn default() -> Self {
        ActorConfig { device_mapping: (0..1).collect(), learning_rate: 1e-3, max_new_tokens: 32, temperature: 1.0 }
    }
}

/// Env-manager block (`train_env_manager` / `val_env_manager`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EnvManagerConfig {
    pub num_env_groups: usize,
    pub group_size: usize,
}

impl EnvManagerConfig {
    pub fn capacity(&self) -> usize {
        self.num_env_groups * self.group_size
    }
}

/// Top-level run configuration (paper Appendix A schema).
#[derive(Clone, Debug)]
pub struct RollConfig {
    pub seed: u64,
    pub pg_variant: PgVariant,
    pub pretrain: String, // artifacts/<model> directory name
    pub rollout_batch_size: usize,
    pub num_return_sequences_in_group: usize,
    pub ppo_epochs: usize,
    pub prompt_length: usize,
    pub response_length: usize,
    /// false => batch rollout; true => queue scheduling (Section 5.1.1)
    pub use_queue_scheduling: bool,
    pub max_additional_running_prompts: usize,
    /// Section 5.1.2 prompt replication
    pub is_num_return_sequences_expand: bool,
    /// asynchronous ratio alpha; 0 => synchronous (Section 4.3)
    pub async_generation_ratio: f64,
    /// rollout engine: env worker pool size (episode state machines
    /// multiplex over these; concurrency is NOT bounded by it)
    pub num_workers: usize,
    /// episodes provisioned per group as a multiple of group size;
    /// > 1 enables redundant env rollout (Section 5.2.2)
    pub redundancy_factor: f64,
    /// inference fleet: LlmProxy replicas behind the routing layer
    pub num_replicas: usize,
    /// request placement across replicas
    pub route_policy: RoutePolicy,
    /// staggered weight sync (at most one replica paused at a time)
    pub rolling_update: bool,
    /// prefix-salvaging migration: a generation moved off a hung/dead
    /// replica resumes from its decoded prefix instead of restarting;
    /// false = the old abort-and-resubmit-from-scratch behavior
    pub partial_migration: bool,
    /// shortest salvaged prefix worth resuming (tokens)
    pub min_salvage_tokens: usize,
    /// seconds the fleet's collectors wait for a RECLAIM answer before
    /// re-dispatching a parked generation from its last salvaged
    /// prefix (bounds a wedged replica; never a caller-path wait)
    pub salvage_timeout: f64,
    /// saturated hang-watchdog migrations salvage + re-enter pool
    /// admission (ReclaimInPlace) instead of being refused
    pub reclaim_in_place: bool,
    /// elastic fleet: queue-driven replica autoscaling (`autoscale:
    /// {min_replicas, max_replicas, target_queue_depth, interval,
    /// cooldown, hysteresis}`; presence of the block enables it)
    pub autoscale: AutoscaleCfg,
    /// flight recorder: per-request lifecycle traces + replica time
    /// attribution (`trace: {enabled, ring_capacity, export_path}`;
    /// presence of the block enables it)
    pub trace: TraceCfg,
    /// generation-length predictor behind tail-aware scheduling
    /// (`length_predictor: {ewma_beta, sketch_capacity, long_quantile,
    /// min_samples, default_len}`; always on — the knobs only shape it)
    pub predictor: PredictorCfg,
    /// fleet-wide KV-prefix index + cache-aware routing (`kv_cache:
    /// {block_tokens, kv_bytes_budget, bytes_per_token,
    /// invalidate_on_weight_sync}`; presence of the block enables it —
    /// absent, placement and accounting stay byte-identical to legacy)
    pub kv_cache: KvCacheCfg,
    /// live telemetry plane (`telemetry: {window_secs, prometheus_path,
    /// verdict_path, <threshold overrides>}`; presence of the block
    /// enables it — absent, every would-be tick is one branch and the
    /// event stream stays byte-identical to legacy)
    pub telemetry: TelemetryCfg,
    /// adaptive asynchrony governor (`async_governor: {gap_budget,
    /// alpha_max, every_k, relax_frac, barrier_frac, interval,
    /// cooldown, hysteresis}`; presence of the block enables it —
    /// requires the telemetry plane, whose closed version-gap windows
    /// drive every mode decision)
    pub governor: GovernorCfg,
    /// virtual-time sim: seconds of replica time one prefill/replay
    /// token costs (`prefill_time_per_token` — sweepable replay-cost
    /// sensitivity for `sim/fleet.rs` and the fig benches)
    pub prefill_time_per_token: f64,
    pub adv_estimator: String,
    pub reward_norm: String,
    pub actor_train: ActorConfig,
    pub actor_infer: ActorConfig,
    pub train_env_manager: EnvManagerConfig,
    pub val_env_manager: EnvManagerConfig,
    pub max_env_steps: usize,
}

impl Default for RollConfig {
    fn default() -> Self {
        RollConfig {
            seed: 42,
            pg_variant: PgVariant::Ppo,
            pretrain: "tiny".into(),
            rollout_batch_size: 8,
            num_return_sequences_in_group: 4,
            ppo_epochs: 1,
            prompt_length: 8,
            response_length: 16,
            use_queue_scheduling: true,
            max_additional_running_prompts: 16,
            is_num_return_sequences_expand: true,
            async_generation_ratio: 0.0,
            num_workers: 4,
            redundancy_factor: 1.0,
            num_replicas: 1,
            route_policy: RoutePolicy::LeastOutstanding,
            rolling_update: true,
            partial_migration: true,
            min_salvage_tokens: 1,
            salvage_timeout: 0.5,
            reclaim_in_place: true,
            autoscale: AutoscaleCfg::disabled(),
            trace: TraceCfg::disabled(),
            predictor: PredictorCfg::default(),
            kv_cache: KvCacheCfg::disabled(),
            telemetry: TelemetryCfg::disabled(),
            governor: GovernorCfg::disabled(),
            prefill_time_per_token: 2e-4,
            adv_estimator: "reinforce".into(),
            reward_norm: "group".into(),
            actor_train: ActorConfig::default(),
            actor_infer: ActorConfig::default(),
            train_env_manager: EnvManagerConfig { num_env_groups: 8, group_size: 16 },
            val_env_manager: EnvManagerConfig { num_env_groups: 128, group_size: 1 },
            max_env_steps: 30,
        }
    }
}

impl RollConfig {
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_yaml(&text)
    }

    pub fn from_yaml(text: &str) -> Result<Self> {
        let j = yaml::parse(text).map_err(|e| anyhow::anyhow!("config: {e}"))?;
        let mut cfg = RollConfig::default();

        let num = |j: &Json, k: &str| j.get(k).and_then(Json::as_f64);
        if let Some(v) = num(&j, "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = j.get("pg_variant").and_then(Json::as_str) {
            cfg.pg_variant = PgVariant::parse(v)?;
        }
        if let Some(v) = j.get("pretrain").and_then(Json::as_str) {
            // accept both HF-style ("Qwen/Qwen3-8B-Base") and local names
            cfg.pretrain = v.rsplit('/').next().unwrap_or(v).to_string();
        }
        if let Some(v) = num(&j, "rollout_batch_size") {
            cfg.rollout_batch_size = v as usize;
        }
        if let Some(v) = num(&j, "num_return_sequences_in_group") {
            cfg.num_return_sequences_in_group = v as usize;
        }
        if let Some(v) = num(&j, "ppo_epochs") {
            cfg.ppo_epochs = v as usize;
        }
        if let Some(v) = num(&j, "prompt_length") {
            cfg.prompt_length = v as usize;
        }
        if let Some(v) = num(&j, "response_length") {
            cfg.response_length = v as usize;
        }
        if let Some(v) = num(&j, "generate_opt_level") {
            cfg.use_queue_scheduling = v as usize > 0;
        }
        if let Some(v) = num(&j, "max_additional_running_prompts") {
            cfg.max_additional_running_prompts = v as usize;
        }
        if let Some(Json::Bool(b)) = j.get("is_num_return_sequences_expand") {
            cfg.is_num_return_sequences_expand = *b;
        }
        if let Some(v) = num(&j, "async_generation_ratio") {
            cfg.async_generation_ratio = v;
        }
        if let Some(v) = num(&j, "num_workers") {
            cfg.num_workers = v as usize;
        }
        if let Some(v) = num(&j, "redundancy_factor") {
            cfg.redundancy_factor = v;
        }
        if let Some(v) = num(&j, "num_replicas") {
            cfg.num_replicas = v as usize;
        }
        if let Some(v) = j.get("route_policy").and_then(Json::as_str) {
            cfg.route_policy = RoutePolicy::parse(v)?;
        }
        if let Some(Json::Bool(b)) = j.get("rolling_update") {
            cfg.rolling_update = *b;
        }
        if let Some(Json::Bool(b)) = j.get("partial_migration") {
            cfg.partial_migration = *b;
        }
        if let Some(v) = num(&j, "min_salvage_tokens") {
            cfg.min_salvage_tokens = v as usize;
        }
        if let Some(v) = num(&j, "salvage_timeout") {
            cfg.salvage_timeout = v;
        }
        if let Some(Json::Bool(b)) = j.get("reclaim_in_place") {
            cfg.reclaim_in_place = *b;
        }
        if let Some(a) = j.get("autoscale") {
            // the block's presence turns the scaler on unless it says
            // `enabled: false` explicitly (a documented off-switch that
            // keeps the bounds in the file)
            cfg.autoscale.enabled = true;
            if let Some(Json::Bool(b)) = a.get("enabled") {
                cfg.autoscale.enabled = *b;
            }
            if let Some(v) = num(a, "min_replicas") {
                cfg.autoscale.min_replicas = v as usize;
            }
            if let Some(v) = num(a, "max_replicas") {
                cfg.autoscale.max_replicas = v as usize;
            }
            if let Some(v) = num(a, "target_queue_depth") {
                cfg.autoscale.target_queue_depth = v;
            }
            if let Some(v) = num(a, "interval") {
                cfg.autoscale.interval = v;
            }
            if let Some(v) = num(a, "cooldown") {
                cfg.autoscale.cooldown = v;
            }
            if let Some(v) = num(a, "hysteresis") {
                cfg.autoscale.hysteresis = v;
            }
            if let Some(Json::Bool(b)) = a.get("adaptive_target") {
                cfg.autoscale.adaptive_target = *b;
            }
            if let Some(v) = num(a, "decode_knee") {
                cfg.autoscale.decode_knee = v;
            }
        }
        if let Some(p) = j.get("length_predictor") {
            if let Some(v) = num(p, "ewma_beta") {
                cfg.predictor.ewma_beta = v;
            }
            if let Some(v) = num(p, "sketch_capacity") {
                cfg.predictor.sketch_capacity = v as usize;
            }
            if let Some(v) = num(p, "long_quantile") {
                cfg.predictor.long_quantile = v;
            }
            if let Some(v) = num(p, "min_samples") {
                cfg.predictor.min_samples = v as usize;
            }
            if let Some(v) = num(p, "default_len") {
                cfg.predictor.default_len = v;
            }
        }
        if let Some(k) = j.get("kv_cache") {
            // like autoscale/trace: the block's presence turns the
            // index on unless it says `enabled: false` explicitly
            cfg.kv_cache.enabled = true;
            if let Some(Json::Bool(b)) = k.get("enabled") {
                cfg.kv_cache.enabled = *b;
            }
            if let Some(v) = num(k, "block_tokens") {
                cfg.kv_cache.block_tokens = v as usize;
            }
            if let Some(v) = num(k, "kv_bytes_budget") {
                cfg.kv_cache.kv_bytes_budget = v as u64;
            }
            if let Some(v) = num(k, "bytes_per_token") {
                cfg.kv_cache.bytes_per_token = v as u64;
            }
            if let Some(Json::Bool(b)) = k.get("invalidate_on_weight_sync") {
                cfg.kv_cache.invalidate_on_weight_sync = *b;
            }
        }
        if let Some(v) = num(&j, "prefill_time_per_token") {
            cfg.prefill_time_per_token = v;
        }
        if let Some(t) = j.get("trace") {
            // like autoscale: the block's presence turns the recorder
            // on unless it says `enabled: false` explicitly
            cfg.trace.enabled = true;
            if let Some(Json::Bool(b)) = t.get("enabled") {
                cfg.trace.enabled = *b;
            }
            if let Some(v) = num(t, "ring_capacity") {
                cfg.trace.ring_capacity = v as usize;
            }
            if let Some(v) = t.get("export_path").and_then(Json::as_str) {
                cfg.trace.export_path = Some(v.into());
            }
        }
        if let Some(t) = j.get("telemetry") {
            // like autoscale/trace/kv_cache: the block's presence
            // turns the plane on unless it says `enabled: false`
            cfg.telemetry = TelemetryCfg::on();
            if let Some(Json::Bool(b)) = t.get("enabled") {
                cfg.telemetry.enabled = *b;
            }
            if let Some(v) = num(t, "window_secs") {
                cfg.telemetry.window_secs = v;
            }
            if let Some(v) = t.get("prometheus_path").and_then(Json::as_str) {
                cfg.telemetry.prometheus_path = Some(v.into());
            }
            if let Some(v) = t.get("verdict_path").and_then(Json::as_str) {
                cfg.telemetry.verdict_path = Some(v.into());
            }
            for (key, slot) in [
                ("sync_stall_frac", &mut cfg.telemetry.sync_stall_frac),
                ("tail_ratio", &mut cfg.telemetry.tail_ratio),
                ("rollout_wait_frac", &mut cfg.telemetry.rollout_wait_frac),
                ("idle_frac", &mut cfg.telemetry.idle_frac),
                ("throughput_sigma", &mut cfg.telemetry.throughput_sigma),
                ("stall_timeout_secs", &mut cfg.telemetry.stall_timeout_secs),
                ("waste_budget", &mut cfg.telemetry.waste_budget),
                ("gap_budget", &mut cfg.telemetry.gap_budget),
            ] {
                if let Some(v) = num(t, key) {
                    *slot = v;
                }
            }
        }
        if let Some(g) = j.get("async_governor") {
            // like telemetry: the block's presence turns the governor
            // on unless it says `enabled: false` explicitly
            cfg.governor = GovernorCfg::on();
            if let Some(Json::Bool(b)) = g.get("enabled") {
                cfg.governor.enabled = *b;
            }
            if let Some(v) = num(g, "every_k") {
                cfg.governor.every_k = v as usize;
            }
            for (key, slot) in [
                ("gap_budget", &mut cfg.governor.gap_budget),
                ("alpha_max", &mut cfg.governor.alpha_max),
                ("relax_frac", &mut cfg.governor.relax_frac),
                ("barrier_frac", &mut cfg.governor.barrier_frac),
                ("interval", &mut cfg.governor.interval),
                ("cooldown", &mut cfg.governor.cooldown),
                ("hysteresis", &mut cfg.governor.hysteresis),
            ] {
                if let Some(v) = num(g, key) {
                    *slot = v;
                }
            }
        }
        if let Some(v) = j.get("adv_estimator").and_then(Json::as_str) {
            cfg.adv_estimator = v.to_string();
        }
        if let Some(v) = j.get("reward_norm").and_then(Json::as_str) {
            cfg.reward_norm = v.to_string();
        }
        for (key, actor) in [("actor_train", &mut cfg.actor_train), ("actor_infer", &mut cfg.actor_infer)] {
            if let Some(a) = j.get(key) {
                if let Some(dm) = a.get("device_mapping").and_then(Json::as_arr) {
                    actor.device_mapping = dm.iter().filter_map(Json::as_usize).collect();
                }
                if let Some(lr) = a
                    .get("training_args")
                    .and_then(|t| t.get("learning_rate"))
                    .and_then(Json::as_f64)
                {
                    actor.learning_rate = lr;
                }
                if let Some(g) = a.get("generating_args") {
                    if let Some(v) = g.get("max_new_tokens").and_then(Json::as_f64) {
                        actor.max_new_tokens = v as usize;
                    }
                    if let Some(v) = g.get("temperature").and_then(Json::as_f64) {
                        actor.temperature = v;
                    }
                }
            }
        }
        for (key, em) in [
            ("train_env_manager", &mut cfg.train_env_manager),
            ("val_env_manager", &mut cfg.val_env_manager),
        ] {
            if let Some(e) = j.get(key) {
                if let Some(v) = e.get("num_env_groups").and_then(Json::as_usize) {
                    em.num_env_groups = v;
                }
                if let Some(v) = e.get("group_size").and_then(Json::as_usize) {
                    em.group_size = v;
                }
            }
        }
        if let Some(envs) = j.get("custom_envs").and_then(Json::as_obj) {
            if let Some((_, e)) = envs.iter().next() {
                if let Some(v) = e.get("max_steps").and_then(Json::as_usize) {
                    cfg.max_env_steps = v;
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.rollout_batch_size > 0, "rollout_batch_size must be positive");
        anyhow::ensure!(self.num_return_sequences_in_group > 0, "group size must be positive");
        anyhow::ensure!(self.async_generation_ratio >= 0.0, "async ratio must be >= 0");
        anyhow::ensure!(self.num_workers > 0, "num_workers must be positive");
        anyhow::ensure!(
            self.redundancy_factor.is_finite() && self.redundancy_factor >= 1.0,
            "redundancy_factor must be >= 1.0"
        );
        anyhow::ensure!(self.num_replicas > 0, "num_replicas must be positive");
        anyhow::ensure!(self.min_salvage_tokens >= 1, "min_salvage_tokens must be >= 1");
        anyhow::ensure!(
            self.salvage_timeout.is_finite() && self.salvage_timeout > 0.0,
            "salvage_timeout must be > 0 seconds"
        );
        anyhow::ensure!(!self.actor_infer.device_mapping.is_empty(), "empty infer devices");
        anyhow::ensure!(
            !self.trace.enabled || self.trace.ring_capacity > 0,
            "trace.ring_capacity must be > 0 when tracing is enabled"
        );
        self.autoscale.validate()?;
        self.predictor.validate()?;
        self.kv_cache.validate()?;
        if let Err(e) = self.telemetry.validate() {
            anyhow::bail!(e);
        }
        self.governor.validate()?;
        anyhow::ensure!(
            !self.governor.enabled || self.telemetry.enabled,
            "async_governor requires the telemetry plane: add a `telemetry:` block \
             (the governor acts on its closed version-gap windows)"
        );
        anyhow::ensure!(
            self.prefill_time_per_token.is_finite() && self.prefill_time_per_token >= 0.0,
            "prefill_time_per_token must be finite and >= 0"
        );
        Ok(())
    }

    /// Synchronous mode? (paper: async_generation_ratio == 0)
    pub fn is_sync(&self) -> bool {
        self.async_generation_ratio == 0.0
    }

    /// Total sequences consumed per training step.
    pub fn sequences_per_step(&self) -> usize {
        self.rollout_batch_size * self.num_return_sequences_in_group
    }

    /// SampleBuffer capacity bound: (1 + alpha) * batch (Section 4.3).
    pub fn buffer_capacity(&self) -> usize {
        ((1.0 + self.async_generation_ratio) * self.sequences_per_step() as f64).ceil() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RollConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_paper_appendix_schema() {
        let cfg = RollConfig::from_yaml(
            r#"
seed: 7
pg_variant: tis
pretrain: Qwen/Qwen3-8B-Base
rollout_batch_size: 256
num_return_sequences_in_group: 16
prompt_length: 2048
response_length: 30720
is_num_return_sequences_expand: true
async_generation_ratio: 2
actor_train:
  training_args:
    learning_rate: 1.0e-6
  device_mapping: list(range(0,16))
actor_infer:
  generating_args:
    max_new_tokens: ${response_length}
    temperature: 1
  device_mapping: list(range(16,40))
train_env_manager:
  num_env_groups: 8
  group_size: 16
"#,
        )
        .unwrap();
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.pg_variant, PgVariant::Tis);
        assert_eq!(cfg.pretrain, "Qwen3-8B-Base");
        assert_eq!(cfg.sequences_per_step(), 4096);
        assert_eq!(cfg.buffer_capacity(), 3 * 4096);
        assert!(!cfg.is_sync());
        assert_eq!(cfg.actor_infer.device_mapping.len(), 24);
        assert_eq!(cfg.actor_infer.max_new_tokens, 30720);
        assert!((cfg.actor_train.learning_rate - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn parses_fleet_keys() {
        let cfg = RollConfig::from_yaml(
            r#"
num_replicas: 4
route_policy: queue
rolling_update: false
"#,
        )
        .unwrap();
        assert_eq!(cfg.num_replicas, 4);
        assert_eq!(cfg.route_policy, RoutePolicy::QueueSched);
        assert!(!cfg.rolling_update);
        // defaults: single replica, least-outstanding, rolling sync
        let d = RollConfig::default();
        assert_eq!(d.num_replicas, 1);
        assert_eq!(d.route_policy, RoutePolicy::LeastOutstanding);
        assert!(d.rolling_update);
        assert!(RollConfig::from_yaml("num_replicas: 0").is_err());
        assert!(RollConfig::from_yaml("route_policy: bogus").is_err());
    }

    #[test]
    fn parses_partial_migration_keys() {
        let cfg = RollConfig::from_yaml(
            r#"
partial_migration: false
min_salvage_tokens: 16
"#,
        )
        .unwrap();
        assert!(!cfg.partial_migration);
        assert_eq!(cfg.min_salvage_tokens, 16);
        // defaults: salvage on, any decoded token worth keeping
        let d = RollConfig::default();
        assert!(d.partial_migration);
        assert_eq!(d.min_salvage_tokens, 1);
        assert!(RollConfig::from_yaml("min_salvage_tokens: 0").is_err());
    }

    #[test]
    fn parses_async_reclaim_keys() {
        let cfg = RollConfig::from_yaml(
            r#"
salvage_timeout: 1.5
reclaim_in_place: false
"#,
        )
        .unwrap();
        assert!((cfg.salvage_timeout - 1.5).abs() < 1e-12);
        assert!(!cfg.reclaim_in_place);
        // defaults: 500ms collector-side resolution, in-place on
        let d = RollConfig::default();
        assert!((d.salvage_timeout - 0.5).abs() < 1e-12);
        assert!(d.reclaim_in_place);
        assert!(RollConfig::from_yaml("salvage_timeout: 0").is_err());
        assert!(RollConfig::from_yaml("salvage_timeout: -1").is_err());
    }

    #[test]
    fn parses_rollout_engine_keys() {
        let cfg = RollConfig::from_yaml(
            r#"
num_workers: 8
redundancy_factor: 1.5
route_policy: ewma
"#,
        )
        .unwrap();
        assert_eq!(cfg.num_workers, 8);
        assert!((cfg.redundancy_factor - 1.5).abs() < 1e-12);
        assert_eq!(cfg.route_policy, RoutePolicy::Ewma);
        // defaults: 4 workers, exact provisioning
        let d = RollConfig::default();
        assert_eq!(d.num_workers, 4);
        assert!((d.redundancy_factor - 1.0).abs() < 1e-12);
        // rejects degenerate values
        assert!(RollConfig::from_yaml("num_workers: 0").is_err());
        assert!(RollConfig::from_yaml("redundancy_factor: 0.5").is_err());
    }

    #[test]
    fn parses_autoscale_block() {
        let cfg = RollConfig::from_yaml(
            r#"
num_replicas: 2
autoscale:
  min_replicas: 2
  max_replicas: 8
  target_queue_depth: 6
  interval: 2
  cooldown: 5
  hysteresis: 0.3
"#,
        )
        .unwrap();
        assert!(cfg.autoscale.enabled, "block presence enables the scaler");
        assert_eq!(cfg.autoscale.min_replicas, 2);
        assert_eq!(cfg.autoscale.max_replicas, 8);
        assert!((cfg.autoscale.target_queue_depth - 6.0).abs() < 1e-12);
        assert!((cfg.autoscale.interval - 2.0).abs() < 1e-12);
        assert!((cfg.autoscale.cooldown - 5.0).abs() < 1e-12);
        assert!((cfg.autoscale.hysteresis - 0.3).abs() < 1e-12);
        // default: off, and the bounds are inert
        assert!(!RollConfig::default().autoscale.enabled);
        // explicit off-switch keeps the bounds in the file
        let off = RollConfig::from_yaml("autoscale:\n  enabled: false\n").unwrap();
        assert!(!off.autoscale.enabled);
    }

    #[test]
    fn parses_trace_block() {
        let cfg = RollConfig::from_yaml(
            r#"
trace:
  ring_capacity: 512
  export_path: /tmp/roll-trace
"#,
        )
        .unwrap();
        assert!(cfg.trace.enabled, "block presence enables the recorder");
        assert_eq!(cfg.trace.ring_capacity, 512);
        assert_eq!(cfg.trace.export_path.as_deref(), Some(Path::new("/tmp/roll-trace")));
        // default: off, in-memory, 4096-deep rings
        let d = RollConfig::default();
        assert!(!d.trace.enabled);
        assert_eq!(d.trace.ring_capacity, 4096);
        assert_eq!(d.trace.export_path, None);
        // explicit off-switch keeps the knobs in the file
        let off = RollConfig::from_yaml("trace:\n  enabled: false\n  ring_capacity: 64\n").unwrap();
        assert!(!off.trace.enabled);
        assert_eq!(off.trace.ring_capacity, 64);
        // a zero-capacity ring cannot hold events
        assert!(RollConfig::from_yaml("trace:\n  ring_capacity: 0\n").is_err());
    }

    #[test]
    fn parses_length_predictor_and_adaptive_autoscale_keys() {
        let cfg = RollConfig::from_yaml(
            r#"
route_policy: tail_aware
length_predictor:
  ewma_beta: 0.5
  sketch_capacity: 128
  long_quantile: 0.9
  min_samples: 4
  default_len: 512
autoscale:
  adaptive_target: true
  decode_knee: 32
"#,
        )
        .unwrap();
        assert_eq!(cfg.route_policy, RoutePolicy::TailAware);
        assert!((cfg.predictor.ewma_beta - 0.5).abs() < 1e-12);
        assert_eq!(cfg.predictor.sketch_capacity, 128);
        assert!((cfg.predictor.long_quantile - 0.9).abs() < 1e-12);
        assert_eq!(cfg.predictor.min_samples, 4);
        assert!((cfg.predictor.default_len - 512.0).abs() < 1e-12);
        assert!(cfg.autoscale.adaptive_target);
        assert!((cfg.autoscale.decode_knee - 32.0).abs() < 1e-12);
        // defaults: FIFO-compatible predictor knobs, fixed-target scaler
        let d = RollConfig::default();
        assert!(!d.autoscale.adaptive_target);
        assert!((d.predictor.ewma_beta - 0.2).abs() < 1e-12);
        // degenerate knobs are rejected at parse time
        assert!(RollConfig::from_yaml("length_predictor:\n  ewma_beta: 0\n").is_err());
        assert!(RollConfig::from_yaml("length_predictor:\n  long_quantile: 1\n").is_err());
        assert!(RollConfig::from_yaml("length_predictor:\n  sketch_capacity: 0\n").is_err());
        assert!(RollConfig::from_yaml("length_predictor:\n  default_len: 0\n").is_err());
        assert!(
            RollConfig::from_yaml("autoscale:\n  adaptive_target: true\n  decode_knee: 0\n")
                .is_err()
        );
    }

    #[test]
    fn parses_kv_cache_block_and_prefill_time() {
        let cfg = RollConfig::from_yaml(
            r#"
kv_cache:
  block_tokens: 32
  kv_bytes_budget: 1048576
  bytes_per_token: 2048
  invalidate_on_weight_sync: false
prefill_time_per_token: 0.001
"#,
        )
        .unwrap();
        assert!(cfg.kv_cache.enabled, "block presence enables the index");
        assert_eq!(cfg.kv_cache.block_tokens, 32);
        assert_eq!(cfg.kv_cache.kv_bytes_budget, 1 << 20);
        assert_eq!(cfg.kv_cache.bytes_per_token, 2048);
        assert!(!cfg.kv_cache.invalidate_on_weight_sync);
        assert!((cfg.prefill_time_per_token - 1e-3).abs() < 1e-12);
        // default: index off, sim replay cost at the historical 2e-4
        let d = RollConfig::default();
        assert!(!d.kv_cache.enabled);
        assert!((d.prefill_time_per_token - 2e-4).abs() < 1e-12);
        // explicit off-switch keeps the knobs in the file
        let off = RollConfig::from_yaml("kv_cache:\n  enabled: false\n  block_tokens: 8\n").unwrap();
        assert!(!off.kv_cache.enabled);
        assert_eq!(off.kv_cache.block_tokens, 8);
        // degenerate knobs rejected only while enabled
        assert!(RollConfig::from_yaml("kv_cache:\n  block_tokens: 0\n").is_err());
        assert!(RollConfig::from_yaml("kv_cache:\n  bytes_per_token: 0\n").is_err());
        assert!(
            RollConfig::from_yaml("kv_cache:\n  kv_bytes_budget: 16\n  block_tokens: 16\n")
                .is_err(),
            "budget below one block is unusable"
        );
        assert!(RollConfig::from_yaml("prefill_time_per_token: -1").is_err());
    }

    #[test]
    fn parses_telemetry_block() {
        let cfg = RollConfig::from_yaml(
            r#"
telemetry:
  window_secs: 2.5
  prometheus_path: /tmp/roll-telemetry/metrics.prom
  verdict_path: /tmp/roll-telemetry/verdicts.jsonl
  sync_stall_frac: 0.25
  tail_ratio: 4
  waste_budget: 0.1
"#,
        )
        .unwrap();
        assert!(cfg.telemetry.enabled, "block presence enables the plane");
        assert!((cfg.telemetry.window_secs - 2.5).abs() < 1e-12);
        assert_eq!(
            cfg.telemetry.prometheus_path.as_deref(),
            Some(Path::new("/tmp/roll-telemetry/metrics.prom"))
        );
        assert_eq!(
            cfg.telemetry.verdict_path.as_deref(),
            Some(Path::new("/tmp/roll-telemetry/verdicts.jsonl"))
        );
        assert!((cfg.telemetry.sync_stall_frac - 0.25).abs() < 1e-12);
        assert!((cfg.telemetry.tail_ratio - 4.0).abs() < 1e-12);
        assert!((cfg.telemetry.waste_budget - 0.1).abs() < 1e-12);
        // unset thresholds keep the `on()` defaults
        assert!((cfg.telemetry.idle_frac - 0.5).abs() < 1e-12);
        assert!((cfg.telemetry.gap_budget - 8.0).abs() < 1e-12);
        // default: plane off
        let d = RollConfig::default();
        assert!(!d.telemetry.enabled);
        // explicit off-switch keeps the knobs in the file
        let off = RollConfig::from_yaml("telemetry:\n  enabled: false\n  window_secs: 9\n").unwrap();
        assert!(!off.telemetry.enabled);
        assert!((off.telemetry.window_secs - 9.0).abs() < 1e-12);
        // degenerate thresholds rejected only while enabled
        assert!(RollConfig::from_yaml("telemetry:\n  window_secs: 0\n").is_err());
        assert!(RollConfig::from_yaml("telemetry:\n  tail_ratio: 1\n").is_err());
        assert!(RollConfig::from_yaml("telemetry:\n  waste_budget: 1.5\n").is_err());
        assert!(
            RollConfig::from_yaml("telemetry:\n  enabled: false\n  window_secs: 0\n").is_ok(),
            "disabled plane skips threshold validation"
        );
    }

    #[test]
    fn parses_async_governor_block() {
        let cfg = RollConfig::from_yaml(
            r#"
telemetry:
  window_secs: 2
async_governor:
  gap_budget: 10
  alpha_max: 3
  every_k: 8
  relax_frac: 0.6
  barrier_frac: 0.85
  interval: 4
  cooldown: 12
  hysteresis: 0.2
"#,
        )
        .unwrap();
        assert!(cfg.governor.enabled, "block presence enables the governor");
        assert!((cfg.governor.gap_budget - 10.0).abs() < 1e-12);
        assert!((cfg.governor.alpha_max - 3.0).abs() < 1e-12);
        assert_eq!(cfg.governor.every_k, 8);
        assert!((cfg.governor.relax_frac - 0.6).abs() < 1e-12);
        assert!((cfg.governor.barrier_frac - 0.85).abs() < 1e-12);
        assert!((cfg.governor.interval - 4.0).abs() < 1e-12);
        assert!((cfg.governor.cooldown - 12.0).abs() < 1e-12);
        assert!((cfg.governor.hysteresis - 0.2).abs() < 1e-12);
        // step_quota is never a YAML knob — it is resolved from the
        // batch shape at wiring time (controller_governor)
        assert_eq!(cfg.governor.step_quota, 0);
        // default: governor off
        assert!(!RollConfig::default().governor.enabled);
        // the governor cannot act without the telemetry plane it reads
        let err = RollConfig::from_yaml("async_governor:\n  gap_budget: 10\n").unwrap_err();
        assert!(err.to_string().contains("telemetry"), "{err}");
        // explicit off-switch keeps the knobs in the file (and lifts
        // the telemetry requirement with them)
        let off =
            RollConfig::from_yaml("async_governor:\n  enabled: false\n  gap_budget: 3\n").unwrap();
        assert!(!off.governor.enabled);
        assert!((off.governor.gap_budget - 3.0).abs() < 1e-12);
        // degenerate knobs rejected only while enabled
        let tele = "telemetry:\n  window_secs: 2\n";
        assert!(RollConfig::from_yaml(&format!("{tele}async_governor:\n  gap_budget: 0\n")).is_err());
        assert!(RollConfig::from_yaml(&format!("{tele}async_governor:\n  every_k: 1\n")).is_err());
        assert!(
            RollConfig::from_yaml(&format!(
                "{tele}async_governor:\n  relax_frac: 0.9\n  barrier_frac: 0.5\n"
            ))
            .is_err(),
            "relax boundary above barrier boundary inverts the ladder"
        );
        assert!(
            RollConfig::from_yaml(&format!(
                "{tele}async_governor:\n  interval: 10\n  cooldown: 5\n"
            ))
            .is_err(),
            "cooldown shorter than the decision interval is meaningless"
        );
    }

    #[test]
    fn rejects_nonsensical_autoscale_bounds() {
        for bad in [
            "autoscale:\n  min_replicas: 0\n",
            "autoscale:\n  min_replicas: 9\n  max_replicas: 2\n",
            "autoscale:\n  interval: 0\n",
            "autoscale:\n  interval: 4\n  cooldown: 1\n",
            "autoscale:\n  target_queue_depth: 0\n",
            "autoscale:\n  hysteresis: 1\n",
        ] {
            assert!(RollConfig::from_yaml(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn variant_roundtrip() {
        for v in PgVariant::ALL {
            assert_eq!(PgVariant::parse(v.as_str()).unwrap(), v);
        }
        assert!(PgVariant::parse("bogus").is_err());
    }
}
