//! YAML-subset parser (std-only substrate). Covers the paper's appendix
//! config schema: nested maps by indentation, `- ` list items, scalars
//! (string/number/bool/null), inline `#` comments, quoted strings,
//! `${var}` references to top-level keys, and the paper's
//! `list(range(a,b))` device-mapping syntax.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Parse a YAML-subset document into the in-tree JSON value model.
pub fn parse(text: &str) -> Result<Json, String> {
    let lines = preprocess(text);
    let (v, consumed) = parse_block(&lines, 0, indent_of(&lines, 0))?;
    if consumed < lines.len() {
        return Err(format!("unparsed content at line {}", lines[consumed].1 + 1));
    }
    let v = resolve_refs(&v)?;
    Ok(v)
}

/// (indent, original line number, content) for non-empty lines.
fn preprocess(text: &str) -> Vec<(usize, usize, String)> {
    let mut out = Vec::new();
    for (num, raw) in text.lines().enumerate() {
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push((indent, num, trimmed.trim_start().to_string()));
    }
    out
}

fn strip_comment(line: &str) -> String {
    let mut out = String::new();
    let mut in_str: Option<char> = None;
    for c in line.chars() {
        match (c, in_str) {
            ('#', None) => break,
            ('"', None) | ('\'', None) => in_str = Some(c),
            ('"', Some('"')) | ('\'', Some('\'')) => in_str = None,
            _ => {}
        }
        out.push(c);
    }
    out
}

fn indent_of(lines: &[(usize, usize, String)], i: usize) -> usize {
    lines.get(i).map(|l| l.0).unwrap_or(0)
}

/// Parse a block starting at `start` whose items sit at `indent`.
fn parse_block(
    lines: &[(usize, usize, String)],
    start: usize,
    indent: usize,
) -> Result<(Json, usize), String> {
    if start >= lines.len() {
        return Ok((Json::Null, start));
    }
    if lines[start].2.starts_with("- ") || lines[start].2 == "-" {
        parse_list(lines, start, indent)
    } else {
        parse_map(lines, start, indent)
    }
}

fn parse_list(
    lines: &[(usize, usize, String)],
    start: usize,
    indent: usize,
) -> Result<(Json, usize), String> {
    let mut items = Vec::new();
    let mut i = start;
    while i < lines.len() && lines[i].0 == indent && (lines[i].2.starts_with("- ") || lines[i].2 == "-") {
        let inline = lines[i].2[1..].trim();
        if inline.is_empty() {
            let (v, next) = parse_block(lines, i + 1, indent_of(lines, i + 1))?;
            items.push(v);
            i = next;
        } else {
            items.push(scalar(inline)?);
            i += 1;
        }
    }
    Ok((Json::Arr(items), i))
}

fn parse_map(
    lines: &[(usize, usize, String)],
    start: usize,
    indent: usize,
) -> Result<(Json, usize), String> {
    let mut map = BTreeMap::new();
    let mut i = start;
    while i < lines.len() && lines[i].0 == indent {
        let line = &lines[i].2;
        if line.starts_with("- ") {
            break;
        }
        let colon = find_key_colon(line)
            .ok_or_else(|| format!("expected `key:` at line {}", lines[i].1 + 1))?;
        let key = unquote(line[..colon].trim());
        let rest = line[colon + 1..].trim();
        if rest.is_empty() {
            // nested block (or empty value if next line is not deeper)
            if i + 1 < lines.len() && lines[i + 1].0 > indent {
                let (v, next) = parse_block(lines, i + 1, lines[i + 1].0)?;
                map.insert(key, v);
                i = next;
            } else {
                map.insert(key, Json::Null);
                i += 1;
            }
        } else {
            map.insert(key, scalar(rest)?);
            i += 1;
        }
    }
    Ok((Json::Obj(map), i))
}

fn find_key_colon(line: &str) -> Option<usize> {
    let mut in_str: Option<char> = None;
    for (idx, c) in line.char_indices() {
        match (c, in_str) {
            ('"', None) | ('\'', None) => in_str = Some(c),
            ('"', Some('"')) | ('\'', Some('\'')) => in_str = None,
            (':', None) => return Some(idx),
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> String {
    let b = s.as_bytes();
    if b.len() >= 2 && ((b[0] == b'"' && b[b.len() - 1] == b'"') || (b[0] == b'\'' && b[b.len() - 1] == b'\'')) {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

/// Scalar values, including flow lists `[a, b]` and `list(range(a,b))`.
fn scalar(s: &str) -> Result<Json, String> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix("list(range(").and_then(|t| t.strip_suffix("))")) {
        let parts: Vec<&str> = inner.split(',').map(str::trim).collect();
        if parts.len() != 2 {
            return Err(format!("bad range: {s}"));
        }
        let a: i64 = parts[0].parse().map_err(|_| format!("bad range: {s}"))?;
        let b: i64 = parts[1].parse().map_err(|_| format!("bad range: {s}"))?;
        return Ok(Json::Arr((a..b).map(|x| Json::Num(x as f64)).collect()));
    }
    if s.starts_with('[') && s.ends_with(']') {
        let inner = &s[1..s.len() - 1];
        if inner.trim().is_empty() {
            return Ok(Json::Arr(vec![]));
        }
        return Ok(Json::Arr(
            inner.split(',').map(|p| scalar(p.trim())).collect::<Result<_, _>>()?,
        ));
    }
    Ok(match s {
        "true" | "True" => Json::Bool(true),
        "false" | "False" => Json::Bool(false),
        "null" | "~" | "None" => Json::Null,
        _ => {
            if let Ok(n) = s.parse::<f64>() {
                Json::Num(n)
            } else {
                Json::Str(unquote(s))
            }
        }
    })
}

/// Resolve `${key}` string references against top-level keys
/// (the appendix config uses e.g. `${response_length}`).
fn resolve_refs(root: &Json) -> Result<Json, String> {
    fn walk(v: &Json, root: &Json) -> Result<Json, String> {
        match v {
            Json::Str(s) if s.starts_with("${") && s.ends_with('}') => {
                let key = &s[2..s.len() - 1];
                root.get(key)
                    .cloned()
                    .ok_or_else(|| format!("unresolved reference {s}"))
            }
            Json::Arr(a) => Ok(Json::Arr(a.iter().map(|x| walk(x, root)).collect::<Result<_, _>>()?)),
            Json::Obj(m) => {
                let mut out = BTreeMap::new();
                for (k, x) in m {
                    out.insert(k.clone(), walk(x, root)?);
                }
                Ok(Json::Obj(out))
            }
            other => Ok(other.clone()),
        }
    }
    walk(root, root)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_paper_style_config() {
        let src = r#"
seed: 42
pg_variant: ppo # can be decoupled_ppo, topr, tis, cispo
rollout_batch_size: 256
is_num_return_sequences_expand: false
async_generation_ratio: 0
response_length: 30720
actor_train:
  training_args:
    learning_rate: 1.0e-6
    per_device_train_batch_size: 1
  device_mapping: list(range(0,16))
actor_infer:
  generating_args:
    max_new_tokens: ${response_length}
    temperature: 1
  device_mapping: list(range(0,16))
custom_envs:
  AlfworldEnv:
    max_steps: 30
"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("seed").unwrap().as_usize(), Some(42));
        assert_eq!(v.get("pg_variant").unwrap().as_str(), Some("ppo"));
        let dm = v.get("actor_train").unwrap().get("device_mapping").unwrap();
        assert_eq!(dm.as_arr().unwrap().len(), 16);
        let mnt = v.get("actor_infer").unwrap().get("generating_args").unwrap().get("max_new_tokens");
        assert_eq!(mnt.unwrap().as_usize(), Some(30720));
        let lr = v.get("actor_train").unwrap().get("training_args").unwrap().get("learning_rate");
        assert!((lr.unwrap().as_f64().unwrap() - 1e-6).abs() < 1e-18);
        assert_eq!(
            v.get("custom_envs").unwrap().get("AlfworldEnv").unwrap().get("max_steps").unwrap().as_usize(),
            Some(30)
        );
    }

    #[test]
    fn lists_parse() {
        let v = parse("xs:\n  - 1\n  - 2\nflow: [3, 4]\n").unwrap();
        assert_eq!(v.get("xs").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(v.get("flow").unwrap().idx(1).unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn bad_reference_errors() {
        assert!(parse("a: ${nope}\n").is_err());
    }

    #[test]
    fn comments_in_strings_survive() {
        let v = parse("a: \"x # y\"\n").unwrap();
        assert_eq!(v.get("a").unwrap().as_str(), Some("x # y"));
    }
}
