//! Adaptive asynchrony governor: close the staleness feedback loop.
//!
//! The paper's throughput claim holds only while off-policy staleness
//! stays inside the alpha/gap budget modeled by [`crate::theory`]
//! (Prop 1 Eq. 7: with `Q = (alpha+1)N` samples outstanding, consumed
//! staleness concentrates at ~alpha versions). PR 9's telemetry plane
//! *measures* that staleness live — the windowed version-gap signal
//! and the `VersionGapBudget` watchdog — but until now the sync/async
//! split was static config (`sync_mode`, `async_ratio`). This module
//! converts the measurement into a control loop, the Periodic
//! Asynchrony recipe: dial between fully-async, one-step-off,
//! periodic-barrier, and fully-sync *at runtime* so the system runs
//! as asynchronously as the measured gap allows and no more.
//!
//! Shape follows `autoscaler.rs` exactly:
//!
//!   * [`decide`] is the *pure* decision rule mapping (cfg, closed
//!     [`TelemetryWindow`]) to an [`AsyncMode`]. It reads the
//!     *measured* window gap (`w.version_gap`) and the watchdog state
//!     (`w.gap_firing`) — never re-derived staleness — and compares
//!     the gap fraction `gap / gap_budget` against the mode ladder.
//!   * [`AsyncGovernor`] adds the temporal policy — decide at most
//!     every `interval` seconds, hold a new mode through `cooldown`,
//!     relax one notch at a time and only once the gap has fallen a
//!     `hysteresis` margin below the notch boundary — in
//!     caller-supplied seconds, so the real `AsyncController` (wall
//!     clock) and `sim/rlvr.rs` / `sim/fleet.rs` (virtual clock) run
//!     the identical logic.
//!
//! Tightening is cheap and urgent (a stale batch is already paid
//! for), so a `Sync` verdict bypasses the cooldown entirely — the
//! emergency brake mirrors the autoscaler's below-min grow path.
//! Relaxing is speculative (it *creates* staleness that only shows up
//! a window later), so it is gated on cooldown + hysteresis and never
//! happens while the gap watchdog is still firing.

use anyhow::Result;

use crate::metrics::telemetry::TelemetryWindow;

/// The asynchrony ladder, loosest first. `rank()` orders the modes by
/// how much staleness they admit; the governor tightens by any number
/// of notches at once but relaxes one notch per decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AsyncMode {
    /// no cap beyond the admission window `(1+alpha)·batch`: rollout
    /// free-runs while the trainer consumes (paper Fig. 4 async arm)
    FullyAsync {
        /// rollout samples allowed in flight + buffered; 0 = keep the
        /// buffer's configured admission window (quota unresolved)
        outstanding_cap: usize,
    },
    /// rollout may run at most one training step ahead (alpha = 1)
    OneStepOff,
    /// async between barriers, full drain-and-sync every k-th step —
    /// the Periodic Asynchrony midpoint
    PeriodicBarrier { every_k: usize },
    /// the paper's synchronous recipe: suspend immediately after
    /// `get_batch`, resume after `model_update`
    Sync,
}

impl AsyncMode {
    /// Position on the ladder: 0 = loosest (FullyAsync) .. 3 = Sync.
    /// Doubles as the `governor.mode` gauge value so a dashboard plots
    /// the mode timeline directly.
    pub fn rank(&self) -> usize {
        match self {
            AsyncMode::FullyAsync { .. } => 0,
            AsyncMode::OneStepOff => 1,
            AsyncMode::PeriodicBarrier { .. } => 2,
            AsyncMode::Sync => 3,
        }
    }

    /// Stable identifier for JSONL / metric labels.
    pub fn as_str(&self) -> &'static str {
        match self {
            AsyncMode::FullyAsync { .. } => "async",
            AsyncMode::OneStepOff => "one_step_off",
            AsyncMode::PeriodicBarrier { .. } => "barrier",
            AsyncMode::Sync => "sync",
        }
    }

    /// Human label carrying the mode's parameter (`async(96)`,
    /// `barrier(4)`).
    pub fn label(&self) -> String {
        match self {
            AsyncMode::FullyAsync { outstanding_cap } => format!("async({outstanding_cap})"),
            AsyncMode::OneStepOff => "one_step_off".to_string(),
            AsyncMode::PeriodicBarrier { every_k } => format!("barrier({every_k})"),
            AsyncMode::Sync => "sync".to_string(),
        }
    }

    /// Whether training step `step` runs the paper's synchronous
    /// recipe (suspend after get_batch) under this mode.
    pub fn sync_step(&self, step: usize) -> bool {
        match self {
            AsyncMode::Sync => true,
            AsyncMode::PeriodicBarrier { every_k } => step % every_k.max(1) == 0,
            _ => false,
        }
    }
}

/// `async_governor:` block (YAML/CLI). Absent block == `disabled()`
/// == the static `sync_mode` branch runs untouched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GovernorCfg {
    /// master switch
    pub enabled: bool,
    /// staleness budget: the window version gap the run must stay
    /// under. Mirrors (and should usually equal) the telemetry
    /// plane's `gap_budget` watchdog threshold.
    pub gap_budget: f64,
    /// the largest async_ratio the governor will ever grant; the
    /// effective alpha is additionally clamped to `gap_budget - 1`
    /// (Prop 1: a cap of `(alpha+1)N` implies ~alpha versions of
    /// staleness, so alpha beyond budget-1 cannot stay in budget)
    pub alpha_max: f64,
    /// barrier period for `PeriodicBarrier` (full sync every k steps)
    pub every_k: usize,
    /// gap fraction (`gap / gap_budget`) at or above which FullyAsync
    /// tightens to OneStepOff
    pub relax_frac: f64,
    /// gap fraction at or above which the governor drops to
    /// PeriodicBarrier (>= relax_frac; 1.0 itself means Sync)
    pub barrier_frac: f64,
    /// seconds between decisions (wall or virtual); align with the
    /// telemetry `window_secs` — the governor only sees closed windows
    pub interval: f64,
    /// seconds a new mode is held before the next change; must be
    /// >= interval so a mode's effect is observed before the next
    /// move. The emergency drop to Sync bypasses this.
    pub cooldown: f64,
    /// relax margin: loosen only once the gap sits below the notch
    /// boundary by this fraction (0.25 = gap must fall below 75% of
    /// the boundary), so a gap oscillating on a threshold cannot flap
    /// the mode
    pub hysteresis: f64,
    /// samples consumed per training step (`n_groups × group_size`) —
    /// the N that `outstanding_cap = (alpha+1)·N` scales from. Not a
    /// user knob: the wiring layer fills it from the controller /
    /// sim batch shape; 0 leaves FullyAsync's cap unresolved (keep
    /// the buffer's configured window).
    pub step_quota: usize,
}

impl GovernorCfg {
    /// The absent-block state: static sync/async split, no governor.
    pub fn disabled() -> Self {
        GovernorCfg { enabled: false, ..Self::on() }
    }

    /// Enabled with default thresholds (the values the YAML block
    /// starts from before per-key overrides).
    pub fn on() -> Self {
        GovernorCfg {
            enabled: true,
            gap_budget: 8.0,
            alpha_max: 4.0,
            every_k: 4,
            relax_frac: 0.7,
            barrier_frac: 0.9,
            interval: 5.0,
            cooldown: 10.0,
            hysteresis: 0.25,
            step_quota: 0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(
            self.gap_budget.is_finite() && self.gap_budget >= 1.0,
            "async_governor.gap_budget must be >= 1, got {}",
            self.gap_budget
        );
        anyhow::ensure!(
            self.alpha_max.is_finite() && self.alpha_max >= 0.0,
            "async_governor.alpha_max must be >= 0, got {}",
            self.alpha_max
        );
        anyhow::ensure!(
            self.every_k >= 2,
            "async_governor.every_k must be >= 2 (1 is just Sync), got {}",
            self.every_k
        );
        anyhow::ensure!(
            self.relax_frac > 0.0 && self.relax_frac < 1.0,
            "async_governor.relax_frac must be in (0, 1), got {}",
            self.relax_frac
        );
        anyhow::ensure!(
            self.barrier_frac >= self.relax_frac && self.barrier_frac <= 1.0,
            "async_governor.barrier_frac ({}) must be in [relax_frac ({}), 1]",
            self.barrier_frac,
            self.relax_frac
        );
        anyhow::ensure!(
            self.interval.is_finite() && self.interval > 0.0,
            "async_governor.interval must be > 0"
        );
        anyhow::ensure!(
            self.cooldown.is_finite() && self.cooldown >= self.interval,
            "async_governor.cooldown ({}) must be >= interval ({}): a mode's effect must be \
             observed at least once before the next change",
            self.cooldown,
            self.interval
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.hysteresis),
            "async_governor.hysteresis must be in [0, 1)"
        );
        Ok(())
    }

    /// The async_ratio FullyAsync actually runs at: `alpha_max`
    /// clamped to `gap_budget - 1` per Prop 1 — an admission window of
    /// `(alpha+1)N` implies ~alpha versions of consumed staleness, so
    /// any alpha above budget-1 is throughput the budget can never
    /// accept.
    pub fn effective_alpha(&self) -> f64 {
        self.alpha_max.min((self.gap_budget - 1.0).max(0.0))
    }

    /// FullyAsync's outstanding cap, `ceil((1+alpha)·step_quota)`;
    /// 0 while the step quota is unresolved.
    pub fn outstanding_cap(&self) -> usize {
        ((1.0 + self.effective_alpha()) * self.step_quota as f64).ceil() as usize
    }

    /// The admission async_ratio each mode corresponds to — what the
    /// wiring layer feeds `SampleBuffer::set_async_ratio` on a
    /// transition. Barriers keep the full window (the periodic drain
    /// is what bounds their staleness).
    pub fn admission_alpha(&self, mode: AsyncMode) -> f64 {
        match mode {
            AsyncMode::Sync => 0.0,
            AsyncMode::OneStepOff => self.effective_alpha().min(1.0),
            AsyncMode::PeriodicBarrier { .. } | AsyncMode::FullyAsync { .. } => {
                self.effective_alpha()
            }
        }
    }

    /// The mode at ladder position `rank` (parameters filled from
    /// this cfg) — the relax path steps down through these.
    fn mode_at(&self, rank: usize) -> AsyncMode {
        match rank {
            0 => AsyncMode::FullyAsync { outstanding_cap: self.outstanding_cap() },
            1 => AsyncMode::OneStepOff,
            2 => AsyncMode::PeriodicBarrier { every_k: self.every_k },
            _ => AsyncMode::Sync,
        }
    }

    /// Gap fraction at which ladder position `rank` is entered from
    /// below (the tightening threshold) — also the line the relax
    /// path must clear (with hysteresis margin) to leave `rank`
    /// downward.
    fn boundary(&self, rank: usize) -> f64 {
        match rank {
            0 => 0.0,
            1 => self.relax_frac,
            2 => self.barrier_frac,
            _ => 1.0,
        }
    }
}

impl Default for GovernorCfg {
    fn default() -> Self {
        Self::disabled()
    }
}

/// The pure decision rule, shared verbatim by the real controller and
/// both virtual-time sims. Reads only the *measured* staleness the
/// telemetry plane put in the window:
///
/// 1. watchdog firing, or gap at/over budget -> `Sync` (emergency)
/// 2. `gap/budget >= barrier_frac`          -> `PeriodicBarrier`
/// 3. `gap/budget >= relax_frac`            -> `OneStepOff`
/// 4. otherwise                             -> `FullyAsync` at the
///    Prop-1-clamped cap
pub fn decide(cfg: &GovernorCfg, w: &TelemetryWindow) -> AsyncMode {
    let frac = w.version_gap / cfg.gap_budget;
    if w.gap_firing || frac >= 1.0 {
        return AsyncMode::Sync;
    }
    if frac >= cfg.barrier_frac {
        return cfg.mode_at(2);
    }
    if frac >= cfg.relax_frac {
        return cfg.mode_at(1);
    }
    cfg.mode_at(0)
}

/// Stateful wrapper around [`decide`]: interval sampling, post-change
/// cooldown, one-notch-at-a-time relaxation with hysteresis, in
/// caller-supplied seconds so the wall-clock controller and the
/// virtual-time sims share one clock policy.
#[derive(Clone, Debug)]
pub struct AsyncGovernor {
    pub cfg: GovernorCfg,
    mode: AsyncMode,
    last_tick: Option<f64>,
    last_change: Option<f64>,
    transitions: u64,
}

impl AsyncGovernor {
    /// Starts fully async — the optimistic default the paper's async
    /// arm runs at; the first over-budget window pulls it back.
    pub fn new(cfg: GovernorCfg) -> Self {
        let mode = cfg.mode_at(0);
        AsyncGovernor { cfg, mode, last_tick: None, last_change: None, transitions: 0 }
    }

    pub fn mode(&self) -> AsyncMode {
        self.mode
    }

    /// Mode changes applied so far.
    pub fn transitions(&self) -> u64 {
        self.transitions
    }

    /// Gate + decide at `now` seconds against the latest closed
    /// window. Returns `Some(new_mode)` only when the mode actually
    /// changed (the caller applies suspend/resume + cap side effects
    /// exactly once per transition), `None` on hold.
    pub fn decide_at(&mut self, now: f64, w: &TelemetryWindow) -> Option<AsyncMode> {
        if let Some(t) = self.last_tick {
            if now - t < self.cfg.interval {
                return None;
            }
        }
        self.last_tick = Some(now);
        let target = decide(&self.cfg, w);
        let (cur, tgt) = (self.mode.rank(), target.rank());
        let cooled = match self.last_change {
            Some(t) => now - t >= self.cfg.cooldown,
            None => true,
        };
        let next = if tgt > cur {
            // tightening: staleness already over a line. The full drop
            // to Sync is the emergency brake and skips the cooldown;
            // intermediate tightening waits it out.
            if target == AsyncMode::Sync || cooled {
                target
            } else {
                return None;
            }
        } else if tgt < cur {
            // relaxing is speculative: one notch at a time, only after
            // the cooldown, never while the gap watchdog still fires,
            // and only once the gap has cleared the current notch's
            // boundary by the hysteresis margin.
            let frac = w.version_gap / self.cfg.gap_budget;
            let cleared = frac <= self.cfg.boundary(cur) * (1.0 - self.cfg.hysteresis);
            if !cooled || w.gap_firing || !cleared {
                return None;
            }
            self.cfg.mode_at(cur - 1)
        } else {
            // same rank: refresh parameters (e.g. a resolved step
            // quota changes FullyAsync's cap) without a transition
            self.mode = target;
            return None;
        };
        self.mode = next;
        self.last_change = Some(now);
        self.transitions += 1;
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::Prop1;

    fn cfg() -> GovernorCfg {
        GovernorCfg {
            gap_budget: 8.0,
            alpha_max: 4.0,
            step_quota: 16,
            interval: 1.0,
            cooldown: 3.0,
            ..GovernorCfg::on()
        }
    }

    fn win(gap: f64, firing: bool) -> TelemetryWindow {
        TelemetryWindow::probe(1.0, gap, firing)
    }

    #[test]
    fn validation_rejects_nonsense() {
        assert!(cfg().validate().is_ok());
        assert!(GovernorCfg::disabled().validate().is_ok(), "disabled cfg is always fine");
        for mutate in [
            (|c: &mut GovernorCfg| c.gap_budget = 0.5) as fn(&mut GovernorCfg),
            |c| c.gap_budget = f64::NAN,
            |c| c.alpha_max = -1.0,
            |c| c.every_k = 1,
            |c| c.relax_frac = 0.0,
            |c| c.relax_frac = 1.0,
            |c| c.barrier_frac = c.relax_frac / 2.0,
            |c| c.barrier_frac = 1.5,
            |c| c.interval = 0.0,
            |c| c.cooldown = c.interval / 2.0,
            |c| c.hysteresis = 1.0,
            |c| c.hysteresis = -0.1,
        ] {
            let mut c = cfg();
            mutate(&mut c);
            assert!(c.validate().is_err(), "{c:?} should be rejected");
            c.enabled = false;
            assert!(c.validate().is_ok(), "disabled cfg must not be validated: {c:?}");
        }
    }

    #[test]
    fn budget_breach_is_sync() {
        // at or over budget -> Sync, regardless of watchdog state
        assert_eq!(decide(&cfg(), &win(8.0, false)), AsyncMode::Sync);
        assert_eq!(decide(&cfg(), &win(20.0, true)), AsyncMode::Sync);
        // watchdog still firing inside the hysteresis band -> Sync too
        assert_eq!(decide(&cfg(), &win(5.0, true)), AsyncMode::Sync);
    }

    #[test]
    fn ladder_thresholds() {
        let c = cfg(); // budget 8: relax at 5.6, barrier at 7.2
        assert_eq!(decide(&c, &win(7.5, false)), AsyncMode::PeriodicBarrier { every_k: 4 });
        assert_eq!(decide(&c, &win(6.0, false)), AsyncMode::OneStepOff);
        assert_eq!(
            decide(&c, &win(2.0, false)),
            AsyncMode::FullyAsync { outstanding_cap: 80 },
            "cap = (1 + min(4, 8-1)) * 16"
        );
    }

    #[test]
    fn monotone_response_to_rising_gap() {
        let c = cfg();
        let mut last_rank = 0;
        for k in 0..=40 {
            let gap = k as f64 * 0.25; // 0 .. 10
            let rank = decide(&c, &win(gap, false)).rank();
            assert!(rank >= last_rank, "rank must not loosen as the gap rises (gap {gap})");
            last_rank = rank;
        }
        assert_eq!(last_rank, 3, "over budget ends at Sync");
    }

    #[test]
    fn cap_respects_theory_alpha_gap_bound() {
        // effective alpha is clamped so the Prop-1 implied staleness
        // (~alpha versions at cap (alpha+1)N) stays inside the budget
        let mut c = cfg();
        c.alpha_max = 100.0;
        assert_eq!(c.effective_alpha(), 7.0, "clamped to gap_budget - 1");
        assert_eq!(c.outstanding_cap(), 128);
        // and the clamped alpha still sits on the profitable side of
        // Eq. 7: strictly better than sync, no better than the
        // unclamped fantasy the budget cannot accept
        let p = Prop1 { k_workers: 16, mu_gen: 10.0, l_gen: 100.0 };
        let n = c.step_quota;
        assert!(p.async_bound(n, c.effective_alpha()) < p.sync_bound(n));
        assert!(p.async_bound(n, c.effective_alpha()) >= p.async_bound(n, c.alpha_max));
    }

    #[test]
    fn unresolved_quota_leaves_cap_zero() {
        let mut c = cfg();
        c.step_quota = 0;
        assert_eq!(decide(&c, &win(0.0, false)), AsyncMode::FullyAsync { outstanding_cap: 0 });
    }

    #[test]
    fn admission_alpha_per_mode() {
        let c = cfg();
        assert_eq!(c.admission_alpha(AsyncMode::Sync), 0.0);
        assert_eq!(c.admission_alpha(AsyncMode::OneStepOff), 1.0);
        assert_eq!(c.admission_alpha(AsyncMode::PeriodicBarrier { every_k: 4 }), 4.0);
        assert_eq!(c.admission_alpha(AsyncMode::FullyAsync { outstanding_cap: 80 }), 4.0);
    }

    #[test]
    fn sync_step_schedule() {
        assert!(AsyncMode::Sync.sync_step(17));
        let b = AsyncMode::PeriodicBarrier { every_k: 4 };
        assert!(b.sync_step(0) && b.sync_step(4) && !b.sync_step(3));
        assert!(!AsyncMode::OneStepOff.sync_step(4));
        assert!(!AsyncMode::FullyAsync { outstanding_cap: 9 }.sync_step(4));
    }

    #[test]
    fn emergency_sync_bypasses_cooldown_and_relax_does_not() {
        let mut g = AsyncGovernor::new(cfg());
        assert_eq!(g.mode().rank(), 0, "starts fully async");
        // t=0: healthy -> already at target, no transition
        assert!(g.decide_at(0.0, &win(1.0, false)).is_none());
        // t=1: gap blows through the budget -> immediate Sync, no
        // cooldown to wait out
        assert_eq!(g.decide_at(1.0, &win(12.0, true)), Some(AsyncMode::Sync));
        // t=2: gap collapses, but cooldown (3s since t=1) holds Sync
        assert!(g.decide_at(2.0, &win(0.0, false)).is_none());
        assert_eq!(g.mode(), AsyncMode::Sync);
        // t=4.5: cooled -> relaxes exactly one notch, not to the target
        assert_eq!(
            g.decide_at(4.5, &win(0.0, false)),
            Some(AsyncMode::PeriodicBarrier { every_k: 4 })
        );
        assert_eq!(g.transitions(), 2);
    }

    #[test]
    fn relax_descends_one_notch_per_cooldown() {
        let mut g = AsyncGovernor::new(cfg());
        g.decide_at(0.0, &win(12.0, true)); // -> Sync
        let mut t = 0.0;
        let mut ranks = vec![g.mode().rank()];
        for _ in 0..20 {
            t += 1.0;
            if g.decide_at(t, &win(0.5, false)).is_some() {
                ranks.push(g.mode().rank());
            }
        }
        assert_eq!(ranks, vec![3, 2, 1, 0], "Sync -> barrier -> one-step-off -> fully async");
    }

    #[test]
    fn hysteresis_prevents_flap_on_the_boundary() {
        // gap oscillating right around the relax threshold (5.6):
        // tightens once, then the relax margin (must fall below
        // 5.6 * 0.75 = 4.2) refuses to loosen again
        let mut g = AsyncGovernor::new(cfg());
        let mut t = 0.0;
        g.decide_at(t, &win(6.0, false)); // not cooled? first change: allowed
        assert_eq!(g.mode(), AsyncMode::OneStepOff);
        for k in 0..12 {
            t += 1.0;
            let gap = if k % 2 == 0 { 5.4 } else { 6.0 }; // straddles 5.6
            assert!(
                g.decide_at(t, &win(gap, false)).is_none(),
                "gap hovering on the boundary must not flap the mode"
            );
        }
        // a real improvement clears the margin and relaxes
        t += 1.0;
        assert!(g.decide_at(t, &win(2.0, false)).is_some());
        assert_eq!(g.mode().rank(), 0);
    }

    #[test]
    fn never_relaxes_while_watchdog_fires() {
        let mut g = AsyncGovernor::new(cfg());
        g.decide_at(0.0, &win(12.0, true)); // -> Sync
        // gap numerically low but the watchdog has not cleared yet
        // (hysteresis band): decide says Sync, the governor holds
        for k in 1..8 {
            assert!(g.decide_at(k as f64, &win(4.5, true)).is_none());
            assert_eq!(g.mode(), AsyncMode::Sync);
        }
    }

    #[test]
    fn interval_gates_decisions() {
        let mut g = AsyncGovernor::new(cfg());
        assert!(g.decide_at(0.0, &win(12.0, true)).is_some());
        // inside the interval: not even looked at
        assert!(g.decide_at(0.5, &win(0.0, false)).is_none());
        assert!(g.last_tick == Some(0.0));
    }

    #[test]
    fn same_rank_refreshes_cap_without_transition() {
        let mut g = AsyncGovernor::new(GovernorCfg { step_quota: 0, ..cfg() });
        assert_eq!(g.mode(), AsyncMode::FullyAsync { outstanding_cap: 0 });
        g.cfg.step_quota = 16; // quota resolved after construction
        assert!(g.decide_at(0.0, &win(1.0, false)).is_none(), "no visible transition");
        assert_eq!(g.mode(), AsyncMode::FullyAsync { outstanding_cap: 80 });
        assert_eq!(g.transitions(), 0);
    }
}
