//! The ROLL Flash coordinator (Layer 3) — the paper's system
//! contribution, running the *real* PJRT engine: LLMProxy (step-wise
//! inference event loop), EnvManager workers, the freshness-bounded
//! SampleBuffer, and the AsyncController training loop (Figure 5).
//!
//! The same policies (queue scheduling, prompt replication via
//! independent per-sequence requests, redundant env rollout, async
//! ratio) are mirrored in `sim/` for the virtual-time scale benches;
//! here they execute against real decode/train steps.

pub mod async_controller;
pub mod env_manager;
pub mod llm_proxy;
pub mod sample_buffer;

pub use async_controller::{format_log, run_training, ControllerCfg, StepLog};
pub use env_manager::{spawn_env_manager, EnvManagerCfg, GroupTasks};
pub use llm_proxy::{GenResult, LlmProxy, ProxyReport};
pub use sample_buffer::{BufferStats, SampleBuffer};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::env::BaseEnv;

/// Rollout-fleet configuration (paper Appendix A schema): the env
/// fleet may exceed the consumption quota (redundant env rollout).
#[derive(Clone, Debug)]
pub struct RolloutSystemCfg {
    pub artifacts_dir: PathBuf,
    /// env fleet: groups x members
    pub num_env_groups: usize,
    pub env_group_size: usize,
    /// consumption quota per training step: groups x group size
    pub consume_groups: usize,
    pub consume_group_size: usize,
    /// asynchronous ratio alpha (0 => sync admission)
    pub alpha: f64,
    pub seed: u64,
    /// scale env latency into real sleeps (0 = logical time only)
    pub latency_scale: f64,
    pub hang_timeout: f64,
}

impl RolloutSystemCfg {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_env_groups >= self.consume_groups, "fleet < quota groups");
        anyhow::ensure!(self.env_group_size >= self.consume_group_size, "group < quota size");
        anyhow::ensure!(self.alpha >= 0.0, "alpha must be >= 0");
        Ok(())
    }
}

/// A running rollout fleet: proxy + env managers + buffer.
pub struct RolloutSystem {
    pub proxy: Arc<LlmProxy>,
    pub buffer: Arc<SampleBuffer>,
    stop: Arc<AtomicBool>,
    managers: Vec<JoinHandle<usize>>,
}

/// Final fleet statistics after shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct FleetReport {
    pub proxy: ProxyReport,
    pub buffer: BufferStats,
    pub episodes: usize,
}

impl RolloutSystem {
    /// Start the fleet. `env_factory(group, member)` builds each
    /// manager's environment (enabling per-group heterogeneity).
    pub fn start<E, F>(cfg: &RolloutSystemCfg, init_weights: Vec<f32>, env_factory: F) -> Result<Self>
    where
        E: BaseEnv + 'static,
        F: Fn(usize, usize) -> E,
    {
        cfg.validate()?;
        let batch = cfg.consume_groups * cfg.consume_group_size;
        let buffer = Arc::new(SampleBuffer::new(batch, cfg.consume_group_size, cfg.alpha));
        let proxy = Arc::new(LlmProxy::spawn(
            cfg.artifacts_dir.clone(),
            init_weights,
            crate::env::vocab::EOS,
            cfg.seed,
        ));
        let tasks = Arc::new(GroupTasks::new(cfg.num_env_groups, cfg.env_group_size, cfg.seed));
        let stop = Arc::new(AtomicBool::new(false));
        let mut managers = Vec::new();
        for grp in 0..cfg.num_env_groups {
            for member in 0..cfg.env_group_size {
                let mcfg = EnvManagerCfg {
                    group: grp,
                    member,
                    latency_scale: cfg.latency_scale,
                    hang_timeout: cfg.hang_timeout,
                };
                managers.push(spawn_env_manager(
                    env_factory(grp, member),
                    mcfg,
                    tasks.clone(),
                    proxy.clone(),
                    buffer.clone(),
                    stop.clone(),
                ));
            }
        }
        Ok(RolloutSystem { proxy, buffer, stop, managers })
    }

    /// Stop producers, drain threads, and collect reports.
    pub fn shutdown(self) -> Result<FleetReport> {
        self.stop.store(true, Ordering::Relaxed);
        self.buffer.shutdown();
        let mut episodes = 0usize;
        for h in self.managers {
            episodes += h.join().map_err(|_| anyhow::anyhow!("env manager panicked"))?;
        }
        let buffer = self.buffer.stats();
        let proxy = match Arc::try_unwrap(self.proxy) {
            Ok(p) => p.shutdown()?,
            Err(_) => anyhow::bail!("proxy handle still shared at shutdown"),
        };
        Ok(FleetReport { proxy, buffer, episodes })
    }
}
