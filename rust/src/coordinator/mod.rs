//! The ROLL Flash coordinator (Layer 3) — the paper's system
//! contribution, running the *real* PJRT engine: the inference fleet
//! (an `LlmProxyPool` of step-wise-inference `LlmProxy` replicas behind
//! load-balanced routing and staggered weight sync), the event-driven
//! rollout layer, the freshness-bounded SampleBuffer, and the
//! AsyncController training loop (Figure 5).
//!
//! Fleet layer (`fleet.rs` + `routing.rs`): the paper's LLMProxy
//! abstracts a *pool* of inference workers. `RolloutSystem` spawns
//! `num_replicas` proxy event loops; every [`GenerationTask`] is
//! placed by a pluggable `RoutePolicy` (round-robin,
//! least-outstanding, queue scheduling with pool-side backpressure, or
//! EWMA latency-aware), `update_weights` rolls across replicas one at
//! a time so at least N-1 keep decoding during a model update, and
//! requests hung on a fail-slow replica are migrated elsewhere
//! (`hang_timeout`) — with `partial_migration` the decoded prefix is
//! salvaged and the generation *resumes* on the target instead of
//! restarting from scratch; salvaged/wasted decode work is tracked in
//! a fleet-wide `TokenLedger`.
//!
//! Rollout layer (`rollout/`): a single `RolloutEngine` thread
//! multiplexes every episode as a state machine over a fixed pool of
//! `num_workers` env threads — completion events from the fleet arrive
//! on one shared reply channel, env latency runs on a timer wheel
//! instead of real sleeps, and SampleBuffer hooks drive admission and
//! redundant-rollout cancellation (`redundancy_factor`). Concurrency
//! scales with episode count, not OS threads.
//!
//! The same policies (queue scheduling, prompt replication via
//! independent per-sequence requests, redundant env rollout, async
//! ratio, replica routing) are mirrored in `sim/` for the virtual-time
//! scale benches; here they execute against real decode/train steps.

pub mod async_controller;
pub mod async_governor;
pub mod autoscaler;
pub mod fleet;
pub mod kv_index;
pub mod length_predictor;
pub mod llm_proxy;
#[cfg(test)]
mod reclaim_races;
pub mod rollout;
pub mod routing;
pub mod sample_buffer;

pub use async_controller::{format_log, run_training, steplog_jsonl, ControllerCfg, StepLog};
// the governor's pure `decide` stays path-qualified
// (`async_governor::decide`) — the autoscaler already exports the
// unqualified name below
pub use async_governor::{AsyncGovernor, AsyncMode, GovernorCfg};
pub use autoscaler::{decide, AutoscaleCfg, Autoscaler, PoolSignals, ScaleDecision};
pub use fleet::{LlmProxyPool, PoolCfg, PoolReport, ReplicaReport};
pub use kv_index::{KvCacheCfg, KvIndexStats, KvPrefixIndex};
pub use length_predictor::{LengthPredictor, LengthSnapshot, PredictorCfg, QuantileSketch};
pub use llm_proxy::{
    GenResult, GenerationTask, LlmProxy, ProgressGossip, ProxyClient, ProxyEvent, ProxyReport,
    Salvage, TokenLedger, TokenStats,
};
pub use rollout::{EngineCfg, EngineReport, GenBackend, GroupTasks, RolloutEngine};
pub use routing::{ReplicaLoad, RouteHint, RoutePolicy, Router};
pub use sample_buffer::{Admission, BufferStats, SampleBuffer};

// the trace knobs ride along with the fleet cfg, so surface them here
pub use crate::metrics::trace::{FlightRecorder, TraceCfg};
// the telemetry plane rides the controller cfg the same way
pub use crate::metrics::telemetry::{
    BottleneckVerdict, TelemetryAlert, TelemetryCfg, TelemetryPlane, TelemetrySignals,
    TelemetryStatus, TelemetryWindow,
};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::Result;

use crate::env::BaseEnv;

/// Rollout-fleet configuration (paper Appendix A schema): the env
/// fleet may exceed the consumption quota (redundant env rollout), and
/// the inference side is a pool of `num_replicas` proxy engines.
#[derive(Clone, Debug)]
pub struct RolloutSystemCfg {
    pub artifacts_dir: PathBuf,
    /// env fleet: groups x members
    pub num_env_groups: usize,
    pub env_group_size: usize,
    /// consumption quota per training step: groups x group size
    pub consume_groups: usize,
    pub consume_group_size: usize,
    /// asynchronous ratio alpha (0 => sync admission)
    pub alpha: f64,
    pub seed: u64,
    /// scale env latency into real timer deadlines (0 = ready now)
    pub latency_scale: f64,
    pub hang_timeout: f64,
    /// env worker pool size (the engine's only env-executing threads)
    pub num_workers: usize,
    /// episodes provisioned per group as a multiple of group size;
    /// > 1.0 enables redundant env rollout with surplus abortion
    pub redundancy_factor: f64,
    /// inference fleet: LlmProxy replicas behind the routing layer
    pub num_replicas: usize,
    pub route_policy: RoutePolicy,
    /// staggered weight sync (>= N-1 replicas keep decoding); false =
    /// broadcast to every replica at once
    pub rolling_update: bool,
    /// salvage the decoded prefix across migration / dead-replica
    /// resubmission so moved generations resume instead of restarting;
    /// false = the old abort-and-resubmit-from-scratch arm
    pub partial_migration: bool,
    /// shortest salvaged prefix worth resuming (shorter ones are
    /// dropped and counted as wasted)
    pub min_salvage_tokens: usize,
    /// seconds the per-replica collectors wait for a RECLAIM answer
    /// before re-dispatching a parked task from its last salvaged
    /// prefix (bounds a wedged replica's hold on a PendingSalvage
    /// entry; never a caller-path wait)
    pub salvage_timeout: f64,
    /// saturated hang-watchdog migrations salvage + re-enter pool
    /// admission (ReclaimInPlace) instead of being refused
    pub reclaim_in_place: bool,
    /// elastic fleet: queue-driven replica autoscaling bounds and
    /// cadence (`autoscale: {…}` in YAML; disabled by default, in
    /// which case the pool stays at `num_replicas`). The control loop
    /// itself runs on the training thread — thread this into
    /// `ControllerCfg::autoscale` via `Self::controller_autoscale` so
    /// it is configured in exactly one place.
    pub autoscale: AutoscaleCfg,
    /// flight recorder: per-request lifecycle spans in bounded
    /// per-replica rings plus replica time-attribution, exported as
    /// JSONL + Chrome `trace_event` JSON at shutdown (`trace: {…}` in
    /// YAML, `trace=`/`trace_path=` on the CLI; disabled by default —
    /// off, the recorder is a single branch per call site)
    pub trace: TraceCfg,
    /// generation-length predictor shape (`length_predictor: {…}` in
    /// YAML / CLI): feeds TailAware routing, the proxy's two-class
    /// admission, and the autoscaler's adaptive target
    pub predictor: PredictorCfg,
    /// fleet-wide KV-prefix index + cache-aware routing (`kv_cache:
    /// {…}` in YAML / CLI; disabled by default — placement, admission,
    /// and accounting stay byte-identical to the legacy stack)
    pub kv_cache: KvCacheCfg,
    /// live telemetry plane (`telemetry: {…}` in YAML / CLI; disabled
    /// by default): windowed bottleneck verdicts, anomaly watchdogs,
    /// episode critical-path percentiles, Prometheus + verdict-JSONL
    /// exports. The tick runs on the training thread — thread this
    /// into `ControllerCfg::telemetry` via `Self::controller_telemetry`
    /// so a configured block cannot be silently inert.
    pub telemetry: TelemetryCfg,
    /// adaptive asynchrony governor (`async_governor: {…}` in YAML /
    /// CLI; disabled by default — the static `alpha`/sync split runs
    /// untouched): dials sync / periodic-barrier / one-step-off /
    /// fully-async at runtime off the telemetry plane's measured
    /// version-gap windows. Requires `telemetry.enabled`. Thread this
    /// into `ControllerCfg::governor` via `Self::controller_governor`.
    pub governor: GovernorCfg,
}

impl RolloutSystemCfg {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_env_groups > 0, "num_env_groups must be > 0 (empty env fleet)");
        anyhow::ensure!(self.env_group_size > 0, "env_group_size must be > 0 (empty env groups)");
        anyhow::ensure!(self.consume_groups > 0, "consume_groups must be > 0 (empty quota)");
        anyhow::ensure!(
            self.consume_group_size > 0,
            "consume_group_size must be > 0 (empty quota groups)"
        );
        anyhow::ensure!(self.num_env_groups >= self.consume_groups, "fleet < quota groups");
        anyhow::ensure!(self.env_group_size >= self.consume_group_size, "group < quota size");
        anyhow::ensure!(self.alpha >= 0.0, "alpha must be >= 0");
        anyhow::ensure!(self.num_workers > 0, "num_workers must be > 0 (empty worker pool)");
        anyhow::ensure!(
            self.redundancy_factor.is_finite() && self.redundancy_factor >= 1.0,
            "redundancy_factor must be >= 1.0"
        );
        anyhow::ensure!(self.num_replicas > 0, "num_replicas must be > 0 (empty inference fleet)");
        anyhow::ensure!(
            self.salvage_timeout.is_finite() && self.salvage_timeout > 0.0,
            "salvage_timeout must be > 0 seconds"
        );
        self.autoscale.validate()?;
        self.predictor.validate()?;
        self.kv_cache.validate()?;
        anyhow::ensure!(
            !self.trace.enabled || self.trace.ring_capacity > 0,
            "trace.ring_capacity must be > 0 when tracing is enabled"
        );
        if let Err(e) = self.telemetry.validate() {
            anyhow::bail!(e);
        }
        self.governor.validate()?;
        anyhow::ensure!(
            !self.governor.enabled || self.telemetry.enabled,
            "async_governor requires the telemetry plane: enable the telemetry: block \
             (the governor acts on its closed version-gap windows)"
        );
        Ok(())
    }

    /// The AsyncController's view of this cfg's autoscale knob: `Some`
    /// only when enabled. Call sites hand this to
    /// `ControllerCfg::autoscale` so a YAML/CLI `autoscale:` block
    /// configured here cannot be silently inert.
    pub fn controller_autoscale(&self) -> Option<AutoscaleCfg> {
        self.autoscale.enabled.then_some(self.autoscale)
    }

    /// The AsyncController's view of this cfg's telemetry knob:
    /// `Some` only when enabled. Hand this to
    /// `ControllerCfg::telemetry` so a YAML/CLI `telemetry:` block
    /// configured here cannot be silently inert.
    pub fn controller_telemetry(&self) -> Option<TelemetryCfg> {
        self.telemetry.enabled.then(|| self.telemetry.clone())
    }

    /// The AsyncController's view of this cfg's governor knob: `Some`
    /// only when enabled, with the step quota (the N its outstanding
    /// cap scales from) resolved from the consumption shape when the
    /// block left it open. Hand this to `ControllerCfg::governor`.
    pub fn controller_governor(&self) -> Option<GovernorCfg> {
        self.governor.enabled.then(|| {
            let mut g = self.governor;
            if g.step_quota == 0 {
                g.step_quota = self.consume_groups * self.consume_group_size;
            }
            g
        })
    }

    fn engine_cfg(&self) -> EngineCfg {
        EngineCfg {
            num_env_groups: self.num_env_groups,
            env_group_size: self.env_group_size,
            num_workers: self.num_workers,
            redundancy_factor: self.redundancy_factor,
            latency_scale: self.latency_scale,
            hang_timeout: self.hang_timeout,
            seed: self.seed,
        }
    }
}

/// A running rollout fleet: inference pool + rollout engine + buffer.
pub struct RolloutSystem {
    pub proxy: Arc<LlmProxyPool>,
    pub buffer: Arc<SampleBuffer>,
    stop: Arc<AtomicBool>,
    engine: RolloutEngine,
}

/// Final fleet statistics after shutdown. `proxy` is the aggregate of
/// the per-replica loop reports; `pool` carries the per-replica
/// breakdown (routing counts, utilization/queue-depth histograms,
/// migrations, rolling-sync waves); `engine` is the rollout engine's
/// episode/abort accounting.
#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    pub proxy: ProxyReport,
    pub pool: PoolReport,
    pub buffer: BufferStats,
    pub engine: EngineReport,
    pub episodes: usize,
}

impl RolloutSystem {
    /// Start the fleet. `env_factory(group, member)` builds each lane's
    /// environment (enabling per-group heterogeneity); with
    /// `redundancy_factor > 1` it is also called for the spare members
    /// (`member >= env_group_size`).
    pub fn start<E, F>(cfg: &RolloutSystemCfg, init_weights: Vec<f32>, env_factory: F) -> Result<Self>
    where
        E: BaseEnv + 'static,
        F: Fn(usize, usize) -> E,
    {
        cfg.validate()?;
        let batch = cfg.consume_groups * cfg.consume_group_size;
        let buffer = Arc::new(SampleBuffer::new(batch, cfg.consume_group_size, cfg.alpha));
        // the routing layer's admission cap is the engine's decode batch
        let manifest =
            crate::runtime::Manifest::load(&cfg.artifacts_dir.join("manifest.json"))?;
        let pool_cfg = PoolCfg {
            num_replicas: cfg.num_replicas,
            route_policy: cfg.route_policy,
            rolling_update: cfg.rolling_update,
            replica_slots: manifest.decode_batch,
            partial_migration: cfg.partial_migration,
            min_salvage_tokens: cfg.min_salvage_tokens,
            salvage_timeout: cfg.salvage_timeout,
            reclaim_in_place: cfg.reclaim_in_place,
            trace: cfg.trace.clone(),
            predictor: cfg.predictor,
            kv_cache: cfg.kv_cache,
        };
        let proxy = Arc::new(LlmProxyPool::spawn(
            &pool_cfg,
            cfg.artifacts_dir.clone(),
            init_weights,
            crate::env::vocab::EOS,
            cfg.seed,
        )?);
        let engine_cfg = cfg.engine_cfg();
        let lanes_per_group = engine_cfg.lanes_per_group();
        let mut envs: Vec<Box<dyn BaseEnv>> = Vec::with_capacity(engine_cfg.total_lanes());
        for grp in 0..cfg.num_env_groups {
            for member in 0..lanes_per_group {
                envs.push(Box::new(env_factory(grp, member)));
            }
        }
        let stop = Arc::new(AtomicBool::new(false));
        let backend: Arc<dyn GenBackend> = proxy.clone();
        // one registry covers both layers: the engine's counters land
        // in the pool's shutdown metrics export
        let engine = RolloutEngine::start_with_metrics(
            engine_cfg,
            backend,
            buffer.clone(),
            stop.clone(),
            envs,
            Some(proxy.metrics()),
        )?;
        Ok(RolloutSystem { proxy, buffer, stop, engine })
    }

    /// Stop producers, drain the engine, and collect reports.
    pub fn shutdown(self) -> Result<FleetReport> {
        self.stop.store(true, Ordering::Relaxed);
        self.buffer.shutdown();
        let engine = self.engine.shutdown()?;
        let buffer = self.buffer.stats();
        let pool = match Arc::try_unwrap(self.proxy) {
            Ok(p) => p.shutdown()?,
            Err(_) => anyhow::bail!("proxy pool handle still shared at shutdown"),
        };
        Ok(FleetReport {
            proxy: pool.aggregate(),
            pool,
            buffer,
            engine,
            episodes: engine.episodes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> RolloutSystemCfg {
        RolloutSystemCfg {
            artifacts_dir: PathBuf::from("artifacts/tiny"),
            num_env_groups: 4,
            env_group_size: 4,
            consume_groups: 2,
            consume_group_size: 4,
            alpha: 1.0,
            seed: 1,
            latency_scale: 0.0,
            hang_timeout: f64::INFINITY,
            num_workers: 4,
            redundancy_factor: 1.0,
            num_replicas: 2,
            route_policy: RoutePolicy::LeastOutstanding,
            rolling_update: true,
            partial_migration: true,
            min_salvage_tokens: 1,
            salvage_timeout: 0.5,
            reclaim_in_place: true,
            autoscale: AutoscaleCfg::disabled(),
            trace: TraceCfg::disabled(),
            predictor: PredictorCfg::default(),
            kv_cache: KvCacheCfg::disabled(),
            telemetry: TelemetryCfg::disabled(),
            governor: GovernorCfg::disabled(),
        }
    }

    #[test]
    fn valid_cfg_passes() {
        cfg().validate().unwrap();
        let mut c = cfg();
        c.autoscale = AutoscaleCfg { enabled: true, ..AutoscaleCfg::disabled() };
        c.validate().unwrap();
    }

    #[test]
    fn nonsensical_autoscale_bounds_rejected() {
        for mutate in [
            (|a: &mut AutoscaleCfg| a.min_replicas = 0) as fn(&mut AutoscaleCfg),
            |a| a.min_replicas = a.max_replicas + 1,
            |a| a.interval = 0.0,
            |a| a.cooldown = a.interval / 2.0,
            |a| a.target_queue_depth = 0.0,
            |a| a.hysteresis = 1.5,
        ] {
            let mut c = cfg();
            c.autoscale.enabled = true;
            mutate(&mut c.autoscale);
            assert!(c.validate().is_err(), "{:?} should be rejected", c.autoscale);
            // the same bounds pass while autoscaling is off: the knobs
            // are inert and must not block a static-fleet run
            c.autoscale.enabled = false;
            assert!(c.validate().is_ok());
        }
    }

    #[test]
    fn zero_sized_fleets_and_quotas_rejected() {
        for mutate in [
            (|c: &mut RolloutSystemCfg| c.num_env_groups = 0) as fn(&mut RolloutSystemCfg),
            |c| c.env_group_size = 0,
            |c| c.consume_groups = 0,
            |c| c.consume_group_size = 0,
            |c| c.num_replicas = 0,
            |c| c.num_workers = 0,
            |c| c.redundancy_factor = 0.5,
            |c| c.redundancy_factor = f64::NAN,
            |c| c.alpha = -1.0,
            |c| c.salvage_timeout = 0.0,
            |c| c.salvage_timeout = f64::NAN,
        ] {
            let mut c = cfg();
            mutate(&mut c);
            assert!(c.validate().is_err(), "{c:?} should be rejected");
        }
    }

    #[test]
    fn zero_capacity_trace_ring_rejected_only_when_enabled() {
        let mut c = cfg();
        c.trace = TraceCfg { enabled: true, ring_capacity: 0, export_path: None };
        assert!(c.validate().is_err());
        c.trace.enabled = false;
        assert!(c.validate().is_ok(), "inert trace knobs must not block a run");
        c.trace = TraceCfg { enabled: true, ring_capacity: 64, export_path: None };
        c.validate().unwrap();
    }

    #[test]
    fn bad_telemetry_thresholds_rejected_only_when_enabled() {
        let mut c = cfg();
        c.telemetry = TelemetryCfg { window_secs: 0.0, ..TelemetryCfg::on() };
        assert!(c.validate().is_err());
        // inert knobs must not block a legacy run
        c.telemetry.enabled = false;
        assert!(c.validate().is_ok());
        c.telemetry = TelemetryCfg::on();
        c.validate().unwrap();
        assert!(c.controller_telemetry().is_some());
        c.telemetry = TelemetryCfg::disabled();
        assert!(c.controller_telemetry().is_none());
    }

    #[test]
    fn governor_requires_telemetry_and_validates_only_when_enabled() {
        let mut c = cfg();
        // enabled governor without the plane: rejected with a pointer
        c.governor = GovernorCfg::on();
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("telemetry"), "error must name the missing plane: {err}");
        // with the plane: fine, and the controller view resolves the
        // step quota from the consumption shape (2 groups x 4)
        c.telemetry = TelemetryCfg::on();
        c.validate().unwrap();
        let g = c.controller_governor().expect("enabled block must reach the controller");
        assert_eq!(g.step_quota, 8);
        // an explicit quota is left alone
        c.governor.step_quota = 32;
        assert_eq!(c.controller_governor().unwrap().step_quota, 32);
        // degenerate knobs rejected only while enabled
        c.governor = GovernorCfg { every_k: 1, ..GovernorCfg::on() };
        assert!(c.validate().is_err());
        c.governor.enabled = false;
        assert!(c.validate().is_ok(), "inert governor knobs must not block a run");
        assert!(c.controller_governor().is_none());
    }

    #[test]
    fn fleet_smaller_than_quota_rejected() {
        let mut c = cfg();
        c.consume_groups = c.num_env_groups + 1;
        assert!(c.validate().is_err());
        let mut c = cfg();
        c.consume_group_size = c.env_group_size + 1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn engine_cfg_inherits_redundancy_shape() {
        let mut c = cfg();
        c.redundancy_factor = 1.5;
        let e = c.engine_cfg();
        assert_eq!(e.lanes_per_group(), 6);
        assert_eq!(e.total_lanes(), 24);
    }
}
