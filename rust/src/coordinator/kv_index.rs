//! Fleet-wide KV-prefix index (the Laminar direction, arxiv
//! 2510.12633): a pool-level map of which token-id prefixes are
//! resident in each serving replica's KV cache, so the router can send
//! work *to its state* — a salvaged task resumes where its prefix
//! already lives, a multi-turn episode returns to the replica holding
//! its conversation — instead of paying full prefill replay on
//! whichever replica load-balancing happens to pick.
//!
//! The index is a hashed block-chain (the radix-tree equivalent vLLM
//! uses for prefix caching, flattened into hash space): token streams
//! are chunked into fixed `block_tokens` blocks and each block's key is
//! the running hash of *everything up to and including it*, so a key at
//! depth d identifies one exact prefix of d blocks. Lookup walks the
//! chain until the first missing block; the match length is exact (no
//! false positives beyond 64-bit hash collisions). Parent/children
//! links make eviction structural: only chain *leaves* are evictable,
//! oldest-touched first, under a per-replica `kv_bytes_budget`.
//!
//! Maintenance is event-driven from the fleet's existing lifecycle
//! flow (`coordinator/fleet.rs`): insert on completion/salvage,
//! invalidate the whole replica on kill/retire/slot-reuse, and — per
//! `invalidate_on_weight_sync` — whenever the replica acknowledges a
//! new weight version (stale-version KV must never be advertised as
//! reusable). The index itself is policy-free bookkeeping; the routing
//! preference lives in `Router` (`RouteHint::cached`), and the proxy
//! charges only the *uncovered* portion of a resume to
//! prefill/prefill_replay (`TokenLedger::prefix_hit_tokens`).

use std::collections::HashMap;

use anyhow::Result;

/// `kv_cache:` config block (YAML / CLI), validated. Disabled by
/// default: every routing decision and attribution bill is
/// byte-identical to the pre-index behavior until the block is present.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvCacheCfg {
    pub enabled: bool,
    /// tokens per hashed index block; a prefix match is resolved at
    /// this granularity (smaller = finer matches, more index entries)
    pub block_tokens: usize,
    /// per-replica budget for *indexed* KV bytes; LRU leaf eviction
    /// keeps the advertised state under it
    pub kv_bytes_budget: u64,
    /// KV bytes one cached token occupies (model-dependent; prices
    /// `block_tokens` blocks against the budget)
    pub bytes_per_token: u64,
    /// drop a replica's whole index when it acknowledges a new weight
    /// version (KV computed under old weights is not reusable for
    /// exact resume; `false` keeps it — the approximate-reuse stance)
    pub invalidate_on_weight_sync: bool,
}

impl KvCacheCfg {
    /// The inert default: no index maintained, no routing preference,
    /// no accounting — the legacy placement stack, byte for byte.
    pub fn disabled() -> Self {
        KvCacheCfg {
            enabled: false,
            block_tokens: 16,
            // 64 MiB of KV per replica at 4 KiB/token = 16k tokens
            kv_bytes_budget: 64 << 20,
            bytes_per_token: 4096,
            invalidate_on_weight_sync: true,
        }
    }

    /// Tokens the per-replica budget can hold (floor at one block so a
    /// tiny budget still caches something).
    pub fn budget_tokens(&self) -> u64 {
        (self.kv_bytes_budget / self.bytes_per_token.max(1)).max(self.block_tokens as u64)
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(()); // inert knobs are never rejected
        }
        anyhow::ensure!(self.block_tokens >= 1, "kv_cache.block_tokens must be >= 1");
        anyhow::ensure!(self.bytes_per_token >= 1, "kv_cache.bytes_per_token must be >= 1");
        anyhow::ensure!(
            self.kv_bytes_budget >= self.block_tokens as u64 * self.bytes_per_token,
            "kv_cache.kv_bytes_budget must hold at least one block \
             ({} tokens x {} bytes)",
            self.block_tokens,
            self.bytes_per_token
        );
        Ok(())
    }
}

impl Default for KvCacheCfg {
    fn default() -> Self {
        Self::disabled()
    }
}

/// One indexed block: a node in the per-replica prefix chain.
#[derive(Clone, Copy, Debug)]
struct Block {
    /// key of the previous block in this prefix (None at depth 1)
    parent: Option<u64>,
    /// chains extending through this block; only leaves (0) may evict
    children: u32,
    /// logical LRU clock value of the last insert/touch
    touch: u64,
}

/// Counters the index feeds back to `FleetMetrics`/`PoolReport`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvIndexStats {
    pub blocks: usize,
    pub evictions: u64,
}

/// The pool-level prefix index. Not internally locked: it lives inside
/// `PoolState` (the fleet) or a sim local, under their existing
/// synchronization, and uses a deterministic logical tick for LRU so
/// virtual-time runs replay exactly.
#[derive(Debug)]
pub struct KvPrefixIndex {
    cfg: KvCacheCfg,
    /// per replica slot: block key -> node
    blocks: Vec<HashMap<u64, Block>>,
    /// weight version the slot's index was built under
    version: Vec<u64>,
    /// logical LRU clock (monotone per mutation, never wall time)
    tick: u64,
    evictions: u64,
}

/// FNV-1a 64-bit step over one token, chained: the running hash after
/// block d is the identity of the d-block prefix.
#[inline]
fn fnv_step(mut h: u64, tok: i32) -> u64 {
    for b in tok.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

const FNV_OFFSET: u64 = 0xcbf29ce484222325;

impl KvPrefixIndex {
    pub fn new(cfg: KvCacheCfg, num_replicas: usize) -> Self {
        KvPrefixIndex {
            cfg,
            blocks: (0..num_replicas).map(|_| HashMap::new()).collect(),
            version: vec![0; num_replicas],
            tick: 0,
            evictions: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn cfg(&self) -> &KvCacheCfg {
        &self.cfg
    }

    fn ensure_replica(&mut self, r: usize) {
        while self.blocks.len() <= r {
            self.blocks.push(HashMap::new());
            self.version.push(0);
        }
    }

    /// Record that `tokens` (the full `prompt ++ decoded` stream) is
    /// now KV-resident on replica `r`. Only whole blocks are indexed;
    /// the sub-block tail is simply not advertised. Touches the whole
    /// chain (LRU refresh) and evicts leaves if `r` runs over budget.
    pub fn insert(&mut self, r: usize, tokens: &[i32]) {
        if !self.cfg.enabled || tokens.len() < self.cfg.block_tokens {
            return;
        }
        self.ensure_replica(r);
        self.tick += 1;
        let tick = self.tick;
        let mut h = FNV_OFFSET;
        let mut parent: Option<u64> = None;
        for chunk in tokens.chunks_exact(self.cfg.block_tokens) {
            for &t in chunk {
                h = fnv_step(h, t);
            }
            let map = &mut self.blocks[r];
            match map.get_mut(&h) {
                Some(b) => b.touch = tick,
                None => {
                    map.insert(h, Block { parent, children: 0, touch: tick });
                    if let Some(p) = parent {
                        if let Some(pb) = map.get_mut(&p) {
                            pb.children += 1;
                        }
                    }
                }
            }
            parent = Some(h);
        }
        self.evict_over_budget(r);
    }

    /// Longest indexed prefix of `tokens` resident on replica `r`, in
    /// tokens (a multiple of `block_tokens`). Pure: routing probes
    /// every replica without perturbing LRU order.
    pub fn lookup(&self, r: usize, tokens: &[i32]) -> usize {
        if !self.cfg.enabled || r >= self.blocks.len() {
            return 0;
        }
        let map = &self.blocks[r];
        if map.is_empty() {
            return 0;
        }
        let mut h = FNV_OFFSET;
        let mut matched = 0usize;
        for chunk in tokens.chunks_exact(self.cfg.block_tokens) {
            for &t in chunk {
                h = fnv_step(h, t);
            }
            if !map.contains_key(&h) {
                break;
            }
            matched += self.cfg.block_tokens;
        }
        matched
    }

    /// LRU-refresh the matched chain after the router actually placed
    /// work on it (a hit that is never touched would be the first
    /// evicted despite being the hottest state in the pool).
    pub fn touch(&mut self, r: usize, tokens: &[i32]) {
        if !self.cfg.enabled || r >= self.blocks.len() {
            return;
        }
        self.tick += 1;
        let tick = self.tick;
        let mut h = FNV_OFFSET;
        for chunk in tokens.chunks_exact(self.cfg.block_tokens) {
            for &t in chunk {
                h = fnv_step(h, t);
            }
            match self.blocks[r].get_mut(&h) {
                Some(b) => b.touch = tick,
                None => break,
            }
        }
    }

    /// Drop everything advertised for replica `r` (kill, retire, slot
    /// reuse: the KV state is gone or belongs to a previous occupant).
    pub fn invalidate_replica(&mut self, r: usize) {
        if r < self.blocks.len() {
            self.blocks[r].clear();
        }
    }

    /// The replica acknowledged weight version `v`. Under
    /// `invalidate_on_weight_sync` a version change drops its index —
    /// prefixes decoded under old weights are not exact-resume state.
    pub fn set_version(&mut self, r: usize, v: u64) {
        if !self.cfg.enabled {
            return;
        }
        self.ensure_replica(r);
        if self.version[r] != v {
            self.version[r] = v;
            if self.cfg.invalidate_on_weight_sync {
                self.blocks[r].clear();
            }
        }
    }

    /// Weight version the slot's surviving index was built under.
    pub fn version(&self, r: usize) -> u64 {
        self.version.get(r).copied().unwrap_or(0)
    }

    /// Indexed KV bytes currently advertised for replica `r`.
    pub fn replica_bytes(&self, r: usize) -> u64 {
        let blocks = self.blocks.get(r).map(|m| m.len()).unwrap_or(0) as u64;
        blocks * self.cfg.block_tokens as u64 * self.cfg.bytes_per_token
    }

    pub fn replica_blocks(&self, r: usize) -> usize {
        self.blocks.get(r).map(|m| m.len()).unwrap_or(0)
    }

    pub fn stats(&self) -> KvIndexStats {
        KvIndexStats {
            blocks: self.blocks.iter().map(|m| m.len()).sum(),
            evictions: self.evictions,
        }
    }

    /// Evict least-recently-touched *leaves* until `r` fits its byte
    /// budget. Leaves-only keeps every surviving key's full chain
    /// intact, so `lookup` lengths stay exact.
    fn evict_over_budget(&mut self, r: usize) {
        let budget_blocks =
            (self.cfg.budget_tokens() / self.cfg.block_tokens.max(1) as u64).max(1) as usize;
        while self.blocks[r].len() > budget_blocks {
            let victim = self.blocks[r]
                .iter()
                .filter(|(_, b)| b.children == 0)
                .min_by_key(|(&k, b)| (b.touch, k))
                .map(|(&k, b)| (k, b.parent));
            let Some((key, parent)) = victim else { break };
            self.blocks[r].remove(&key);
            if let Some(p) = parent {
                if let Some(pb) = self.blocks[r].get_mut(&p) {
                    pb.children = pb.children.saturating_sub(1);
                }
            }
            self.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(block: usize, budget_tokens: u64) -> KvCacheCfg {
        KvCacheCfg {
            enabled: true,
            block_tokens: block,
            kv_bytes_budget: budget_tokens * 4096,
            bytes_per_token: 4096,
            invalidate_on_weight_sync: true,
        }
    }

    fn toks(n: usize, salt: i32) -> Vec<i32> {
        (0..n as i32).map(|i| i * 7 + salt).collect()
    }

    #[test]
    fn validation() {
        assert!(KvCacheCfg::disabled().validate().is_ok());
        assert!(cfg(16, 1024).validate().is_ok());
        let mut bad = cfg(0, 1024);
        assert!(bad.validate().is_err());
        bad = cfg(16, 1024);
        bad.bytes_per_token = 0;
        assert!(bad.validate().is_err());
        bad = cfg(16, 1024);
        bad.kv_bytes_budget = 8 * 4096; // < one 16-token block
        assert!(bad.validate().is_err());
        // disabled knobs are inert even when degenerate
        let mut off = bad;
        off.enabled = false;
        assert!(off.validate().is_ok());
    }

    #[test]
    fn lookup_matches_longest_inserted_prefix() {
        let mut ix = KvPrefixIndex::new(cfg(4, 1024), 2);
        let stream = toks(19, 0); // 4 full blocks + 3-token tail
        ix.insert(0, &stream);
        assert_eq!(ix.lookup(0, &stream), 16, "whole blocks only, tail unadvertised");
        // a shorter probe of the same prefix matches its own length
        assert_eq!(ix.lookup(0, &stream[..8]), 8);
        // a probe diverging inside block 3 matches the shared 2 blocks
        let mut fork = stream.clone();
        fork[9] = -1;
        assert_eq!(ix.lookup(0, &fork), 8);
        // nothing was ever inserted on replica 1 (or an unknown slot)
        assert_eq!(ix.lookup(1, &stream), 0);
        assert_eq!(ix.lookup(7, &stream), 0);
        // disabled index never matches
        let off = KvPrefixIndex::new(KvCacheCfg::disabled(), 2);
        assert_eq!(off.lookup(0, &stream), 0);
    }

    #[test]
    fn shared_prefixes_share_blocks() {
        let mut ix = KvPrefixIndex::new(cfg(4, 1024), 1);
        let a = toks(16, 0);
        let mut b = a.clone();
        b.extend(toks(8, 100)); // same 4 blocks, then 2 more
        ix.insert(0, &a);
        let after_a = ix.replica_blocks(0);
        ix.insert(0, &b);
        assert_eq!(after_a, 4);
        assert_eq!(ix.replica_blocks(0), 6, "the shared prefix is not duplicated");
        assert_eq!(ix.lookup(0, &a), 16);
        assert_eq!(ix.lookup(0, &b), 24);
    }

    #[test]
    fn invalidation_clears_the_replica() {
        let mut ix = KvPrefixIndex::new(cfg(4, 1024), 2);
        ix.insert(0, &toks(16, 0));
        ix.insert(1, &toks(16, 1));
        ix.invalidate_replica(0);
        assert_eq!(ix.lookup(0, &toks(16, 0)), 0);
        assert_eq!(ix.lookup(1, &toks(16, 1)), 16, "peers unaffected");
        assert_eq!(ix.replica_bytes(0), 0);
    }

    #[test]
    fn weight_sync_invalidates_per_cfg() {
        let mut ix = KvPrefixIndex::new(cfg(4, 1024), 1);
        ix.insert(0, &toks(16, 0));
        ix.set_version(0, 1);
        assert_eq!(ix.lookup(0, &toks(16, 0)), 0, "new weights drop the index");
        assert_eq!(ix.version(0), 1);
        // same version again: no-op
        ix.insert(0, &toks(16, 0));
        ix.set_version(0, 1);
        assert_eq!(ix.lookup(0, &toks(16, 0)), 16);
        // the approximate-reuse stance keeps the index across versions
        let mut keep = cfg(4, 1024);
        keep.invalidate_on_weight_sync = false;
        let mut ix = KvPrefixIndex::new(keep, 1);
        ix.insert(0, &toks(16, 0));
        ix.set_version(0, 3);
        assert_eq!(ix.lookup(0, &toks(16, 0)), 16);
        assert_eq!(ix.version(0), 3);
    }

    #[test]
    fn lru_evicts_leaves_and_respects_budget() {
        // budget: 3 blocks of 4 tokens
        let mut ix = KvPrefixIndex::new(cfg(4, 12), 1);
        let long = toks(12, 0); // 3 blocks, one chain
        ix.insert(0, &long);
        assert_eq!(ix.replica_blocks(0), 3);
        // a new unrelated chain forces eviction of the *leaf* (deepest
        // block) of the oldest chain, never a middle block
        ix.insert(0, &toks(4, 500));
        assert!(ix.replica_blocks(0) <= 3, "budget enforced");
        assert!(ix.stats().evictions >= 1);
        // the survivor's remaining match length is a clean prefix
        let m = ix.lookup(0, &long);
        assert!(m == 8 || m == 4, "leaf-first eviction truncates, never holes: {m}");
        assert_eq!(ix.lookup(0, &toks(4, 500)), 4, "the fresh insert survives");
        // budget is never exceeded under sustained churn
        for salt in 0..50 {
            ix.insert(0, &toks(8, 1000 + salt));
            assert!(
                ix.replica_bytes(0) <= ix.cfg().kv_bytes_budget,
                "over budget: {} > {}",
                ix.replica_bytes(0),
                ix.cfg().kv_bytes_budget
            );
        }
    }

    #[test]
    fn touch_refreshes_lru_order() {
        let mut ix = KvPrefixIndex::new(cfg(4, 8), 1); // 2-block budget
        let hot = toks(4, 0);
        let cold = toks(4, 100);
        ix.insert(0, &hot);
        ix.insert(0, &cold);
        ix.touch(0, &hot); // hot is now newest despite older insert
        ix.insert(0, &toks(4, 200)); // evicts one: must be cold
        assert_eq!(ix.lookup(0, &hot), 4, "touched chain survives eviction");
        assert_eq!(ix.lookup(0, &cold), 0);
    }

    #[test]
    fn deterministic_across_runs() {
        let run = || {
            let mut ix = KvPrefixIndex::new(cfg(4, 16), 2);
            for i in 0..30 {
                ix.insert(i % 2, &toks(8 + (i % 3) * 4, i as i32));
            }
            (ix.stats(), ix.replica_blocks(0), ix.replica_blocks(1))
        };
        assert_eq!(run(), run(), "logical-tick LRU must replay identically");
    }

    #[test]
    fn disabled_index_is_inert_and_free() {
        let mut ix = KvPrefixIndex::new(KvCacheCfg::disabled(), 4);
        ix.insert(0, &toks(64, 0));
        ix.set_version(0, 9);
        ix.touch(0, &toks(64, 0));
        assert_eq!(ix.stats(), KvIndexStats::default());
        assert_eq!(ix.replica_bytes(0), 0);
    }
}
