//! EnvManager (paper Section 4.2): the basic execution worker. Each
//! manager owns one BaseEnv, acquires an admission ticket from the
//! SampleBuffer (the per-sample freshness bound), then runs the
//! reset/step loop against the shared inference fleet: receive an
//! action, apply it via `step`, repeat until termination, trigger
//! reward, and enqueue the trajectory.
//!
//! Environment-level asynchronous rollout (Section 5.2.1) falls out of
//! the architecture: while one manager waits on its environment, the
//! fleet's decode slots serve other managers' requests.
//!
//! Fail-slow inference replicas are handled here too: a generation
//! that exceeds `hang_timeout` wall seconds is abort-and-resubmit
//! migrated to another replica (the reply channel is preserved, so the
//! manager just keeps waiting); after `MAX_GEN_MIGRATIONS` strikes the
//! episode is abandoned and its admission ticket reclaimed.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::coordinator::fleet::LlmProxyPool;
use crate::coordinator::llm_proxy::GenResult;
use crate::coordinator::sample_buffer::SampleBuffer;
use crate::env::BaseEnv;
use crate::rl::Trajectory;

/// Give up on an episode after this many generation-hang migrations.
const MAX_GEN_MIGRATIONS: u32 = 3;

/// Shared episode numbering: members of a group must roll the same
/// task (GRPO needs multiple candidates per prompt), so the task seed
/// is derived from (group, episode-index-within-group).
pub struct GroupTasks {
    base_seed: u64,
    group_size: usize,
    counters: Vec<AtomicU64>,
}

impl GroupTasks {
    pub fn new(num_groups: usize, group_size: usize, base_seed: u64) -> Self {
        GroupTasks {
            base_seed,
            group_size,
            counters: (0..num_groups * group_size).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Next (group_key, task_seed) for manager `slot` in group `grp`.
    /// The member's local episode counter picks the episode; all
    /// members at episode e of group g share a task seed.
    pub fn next(&self, grp: usize, member: usize) -> (u64, u64) {
        let idx = grp * self.group_size + member;
        let episode = self.counters[idx].fetch_add(1, Ordering::Relaxed);
        let key = (grp as u64) << 32 | episode;
        let seed = self
            .base_seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(key.wrapping_mul(0xd1342543de82ef95));
        (key, seed)
    }
}

/// EnvManager runtime options.
#[derive(Clone, Copy, Debug)]
pub struct EnvManagerCfg {
    pub group: usize,
    pub member: usize,
    /// scale simulated env latency into real sleeps (0.0 = don't sleep)
    pub latency_scale: f64,
    /// give up on an episode whose env hangs longer than this
    pub hang_timeout: f64,
}

/// Spawn one EnvManager thread.
pub fn spawn_env_manager<E: BaseEnv + 'static>(
    mut env: E,
    cfg: EnvManagerCfg,
    tasks: Arc<GroupTasks>,
    proxy: Arc<LlmProxyPool>,
    buffer: Arc<SampleBuffer>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<usize> {
    std::thread::Builder::new()
        .name(format!("env-{}-{}", cfg.group, cfg.member))
        .spawn(move || {
            let mut episodes = 0usize;
            while !stop.load(Ordering::Relaxed) {
                // admission ticket = freshness bound (Section 4.3)
                let Some(init_version) = buffer.begin_sample() else { break };
                if stop.load(Ordering::Relaxed) {
                    buffer.cancel();
                    break;
                }
                match run_episode(&mut env, &cfg, &tasks, &proxy, init_version) {
                    Some(traj) => {
                        buffer.push(traj);
                        episodes += 1;
                    }
                    None => buffer.cancel(),
                }
            }
            episodes
        })
        .expect("spawn env manager")
}

/// One reset/step loop. Returns None if the episode must be abandoned
/// (proxy gone, env hang, context overflow) — the ticket is cancelled.
fn run_episode<E: BaseEnv>(
    env: &mut E,
    cfg: &EnvManagerCfg,
    tasks: &GroupTasks,
    proxy: &LlmProxyPool,
    init_version: u64,
) -> Option<Trajectory> {
    let (group_key, task_seed) = tasks.next(cfg.group, cfg.member);
    let prompt = env.reset(task_seed);
    let mut context = prompt.clone();
    let mut response: Vec<i32> = Vec::new();
    let mut response_mask: Vec<f32> = Vec::new();
    let mut logps: Vec<f32> = Vec::new();
    let mut reward = 0.0f32;

    for _turn in 0..env.max_steps() {
        let (id, rx) = proxy.generate(context.clone(), env.max_new_tokens());
        let result = recv_with_migration(proxy, id, &rx, cfg.hang_timeout)?;
        // action tokens are trainable
        for (t, lp) in result.tokens.iter().zip(&result.logps) {
            response.push(*t);
            response_mask.push(1.0);
            logps.push(*lp);
        }
        let step = env.step(&result.tokens);
        if step.latency > cfg.hang_timeout {
            return None; // fail-stop: timeout, reclaim the ticket
        }
        if cfg.latency_scale > 0.0 && step.latency > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(
                step.latency * cfg.latency_scale,
            ));
        }
        if step.done {
            reward = step.reward.unwrap_or(0.0);
            break;
        }
        // observation tokens join the context, untrained
        for &t in &step.obs {
            response.push(t);
            response_mask.push(0.0);
            logps.push(0.0);
        }
        context.extend(&result.tokens);
        context.extend(&step.obs);
    }

    Some(Trajectory {
        prompt,
        response,
        response_mask,
        behavior_logps: logps,
        reward,
        group: group_key,
        init_version,
    })
}

/// Wait for a generation, migrating it off its replica each time
/// `hang_timeout` wall seconds elapse without a result. Returns None
/// when the fleet shut down or the request kept hanging after
/// `MAX_GEN_MIGRATIONS` strikes (the episode is abandoned; the caller
/// reclaims the admission ticket).
fn recv_with_migration(
    proxy: &LlmProxyPool,
    id: u64,
    rx: &std::sync::mpsc::Receiver<GenResult>,
    hang_timeout: f64,
) -> Option<GenResult> {
    if !(hang_timeout.is_finite() && hang_timeout > 0.0) {
        return rx.recv().ok(); // fleet shut down => abandon
    }
    let timeout = Duration::from_secs_f64(hang_timeout);
    let mut strikes = 0u32;
    loop {
        match rx.recv_timeout(timeout) {
            Ok(r) => return Some(r),
            Err(RecvTimeoutError::Disconnected) => return None,
            Err(RecvTimeoutError::Timeout) => {
                strikes += 1;
                if strikes > MAX_GEN_MIGRATIONS {
                    proxy.abort(id);
                    return None;
                }
                // migrate() is false when there is nowhere to move the
                // request (single replica, all peers suspended) or it
                // raced a completion: grant one grace window for the
                // racing result, then abandon.
                if !proxy.migrate(id) {
                    match rx.recv_timeout(timeout) {
                        Ok(r) => return Some(r),
                        Err(_) => {
                            proxy.abort(id);
                            return None;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_tasks_share_seeds_within_group_episode() {
        let t = GroupTasks::new(2, 4, 42);
        let (k0, s0) = t.next(0, 0);
        let (k1, s1) = t.next(0, 1);
        // same group, same episode index => same key and seed
        assert_eq!(k0, k1);
        assert_eq!(s0, s1);
        // next episode for member 0 differs
        let (k2, s2) = t.next(0, 0);
        assert_ne!(k0, k2);
        assert_ne!(s0, s2);
        // other group differs
        let (k3, s3) = t.next(1, 0);
        assert_ne!(k0, k3);
        assert_ne!(s0, s3);
    }
}
