//! Elastic-fleet autoscaler: a control loop that grows and shrinks the
//! `LlmProxyPool` from observed queue pressure instead of a static
//! `num_replicas` knob.
//!
//! ROLL Flash's utilization claim is about a *fixed* GPU budget; the
//! dual of that claim is that for a fixed workload the budget itself
//! should track demand. A pool provisioned for the peak of a collection
//! step idles through its long tail — exactly the bubble the paper's
//! decoupling attacks. This module closes the loop:
//!
//!   * [`PoolSignals`] is the per-interval observation: serving replica
//!     count, windowed pool-queue depth (p90 of the interval's
//!     submissions — see `Histogram::reset`), and total in-flight work.
//!   * [`decide`] is the *pure* decision function mapping (cfg,
//!     signals) to a [`ScaleDecision`]: grow when the per-replica load
//!     exceeds `target_queue_depth` by more than the hysteresis band,
//!     shrink when it falls below it, clamp to `[min_replicas,
//!     max_replicas]`. The same function runs against the real pool and
//!     inside `sim/fleet.rs` virtual time, so the bench sweeps exercise
//!     the exact decision logic that ships.
//!   * [`Autoscaler`] adds the temporal policy — sample every
//!     `interval`, back off `cooldown` seconds after any scale action
//!     (growth must not flap into the drain it just triggered) — in
//!     caller-supplied seconds, so wall time (the `tick` path the
//!     AsyncController drives between training steps) and virtual time
//!     (the sim) share one implementation.
//!
//! Scale-*down* is safe — and free on the control path — because of
//! the asynchronous salvage machinery: [`LlmProxyPool::retire_replica`]
//! parks the victim's in-flight generations for RECLAIM and returns
//! immediately; the victim's own completion collector absorbs the
//! salvage answers and re-dispatches resumed tasks to survivors (or
//! delivers results that finished inside the drain window, exactly
//! once). Shrinking the fleet burns no decoded tokens (the
//! `TokenLedger` stays clean), no caller observes the drain, and
//! `tick` never stalls the training thread on a drain — there is no
//! caller-side salvage wait anywhere (`retire_replica` is O(lock), not
//! O(SALVAGE_WAIT x in-flight)).

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::fleet::LlmProxyPool;
use crate::metrics::trace::EventPhase;

/// Autoscaler shape and cadence (`autoscale: {…}` in YAML / CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoscaleCfg {
    /// master switch: false = the pool stays at its spawned size
    pub enabled: bool,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// target work (pool-queued + in-flight requests) per serving
    /// replica; the loop sizes the fleet to hold this
    pub target_queue_depth: f64,
    /// seconds between decisions (wall or virtual)
    pub interval: f64,
    /// seconds after any Grow/Shrink before the next one; must be
    /// >= interval so a scale action is observed before the next
    pub cooldown: f64,
    /// dead band around the target as a fraction (0.25 = only act when
    /// per-replica load leaves [0.75, 1.25] x target)
    pub hysteresis: f64,
    /// derive the per-replica target from `decode_knee` x the live
    /// generation-length profile (mean/p90) instead of the hand-tuned
    /// `target_queue_depth` constant; the constant becomes the ceiling
    pub adaptive_target: bool,
    /// requests per replica where decode throughput saturates (the
    /// knee of the decode-batch curve) — the adaptive target's scale
    pub decode_knee: f64,
}

impl AutoscaleCfg {
    /// The config every call site starts from: autoscaling off.
    pub fn disabled() -> Self {
        AutoscaleCfg {
            enabled: false,
            min_replicas: 1,
            max_replicas: 4,
            target_queue_depth: 8.0,
            interval: 1.0,
            cooldown: 2.0,
            hysteresis: 0.25,
            adaptive_target: false,
            decode_knee: 16.0,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        anyhow::ensure!(self.min_replicas > 0, "autoscale.min_replicas must be > 0");
        anyhow::ensure!(
            self.min_replicas <= self.max_replicas,
            "autoscale.min_replicas ({}) must be <= max_replicas ({})",
            self.min_replicas,
            self.max_replicas
        );
        anyhow::ensure!(
            self.target_queue_depth.is_finite() && self.target_queue_depth > 0.0,
            "autoscale.target_queue_depth must be > 0"
        );
        anyhow::ensure!(
            self.interval.is_finite() && self.interval > 0.0,
            "autoscale.interval must be > 0"
        );
        anyhow::ensure!(
            self.cooldown.is_finite() && self.cooldown >= self.interval,
            "autoscale.cooldown ({}) must be >= interval ({}): a scale action must be \
             observed at least once before the next one",
            self.cooldown,
            self.interval
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.hysteresis),
            "autoscale.hysteresis must be in [0, 1)"
        );
        if self.adaptive_target {
            anyhow::ensure!(
                self.decode_knee.is_finite() && self.decode_knee > 0.0,
                "autoscale.decode_knee must be > 0 when adaptive_target is on"
            );
        }
        Ok(())
    }
}

impl Default for AutoscaleCfg {
    fn default() -> Self {
        Self::disabled()
    }
}

/// What the control loop decided for this interval.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Grow(usize),
    Shrink(usize),
    Hold,
}

/// One interval's observation of the pool (or its sim mirror).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolSignals {
    /// replicas currently routable (serving phase)
    pub serving: usize,
    /// pool-side queue depth: windowed p90 on the real pool,
    /// instantaneous in the sim
    pub queue_depth: f64,
    /// requests in flight across serving replicas
    pub outstanding: usize,
    /// decode slots per replica (continuous-batching admission cap)
    pub slots: usize,
    /// cumulative `TokenLedger` wasted-token counter. The gate
    /// differences consecutive readings: waste accruing within an
    /// interval means decoded work is already being burned (failing
    /// replicas, churning migrations) — shrinking then would pile a
    /// drain onto a fleet mid-incident, so Shrink is suppressed for
    /// that interval.
    pub wasted_tokens: u64,
    /// live mean generation length from the shared [`LengthPredictor`]
    /// (0 until anything completes)
    ///
    /// [`LengthPredictor`]: crate::coordinator::length_predictor::LengthPredictor
    pub pred_mean_len: f64,
    /// live p90 generation length (heavy tails push p90 far above the
    /// mean — exactly when per-replica queues must stay shallow)
    pub pred_p90_len: f64,
}

/// The pure decision function, shared verbatim by the real control loop
/// and the `sim/fleet.rs` virtual-time mirror.
///
/// `desired = ceil(load / target_queue_depth)` where `load` is all work
/// in the system (queued + in-flight requests), floored so in-flight
/// work still fits the decode windows, clamped to the configured
/// bounds. Hysteresis: act only when the observed per-replica load is
/// outside `target * (1 -/+ hysteresis)`, so a fleet sitting near the
/// target does not flap. A fleet below `min_replicas` (replicas died)
/// always grows back regardless of load.
///
/// With `adaptive_target` on, the per-replica target is derived from
/// the live length profile instead of the hand-tuned constant:
/// `decode_knee * mean/p90`, clamped to `[1, target_queue_depth]`. A
/// homogeneous workload (mean ~= p90) keeps the full decode-knee
/// batch; a heavy tail (p90 >> mean) pulls the target down, because a
/// straggler pins its whole batch and deep per-replica queues turn
/// into tail latency rather than throughput. Until anything completes
/// the profile is empty and the constant applies unchanged.
pub fn decide(cfg: &AutoscaleCfg, s: &PoolSignals) -> ScaleDecision {
    if s.serving < cfg.min_replicas {
        return ScaleDecision::Grow(cfg.min_replicas - s.serving);
    }
    if s.serving > cfg.max_replicas {
        return ScaleDecision::Shrink(s.serving - cfg.max_replicas);
    }
    let target = if cfg.adaptive_target && s.pred_mean_len > 0.0 && s.pred_p90_len > 0.0 {
        (cfg.decode_knee * s.pred_mean_len / s.pred_p90_len).clamp(1.0, cfg.target_queue_depth)
    } else {
        cfg.target_queue_depth
    };
    let load = s.queue_depth.max(0.0) + s.outstanding as f64;
    let per_replica = load / s.serving.max(1) as f64;
    let desired = (load / target).ceil() as usize;
    // never shrink below what the decode windows need for in-flight work
    let floor = (s.outstanding as f64 / s.slots.max(1) as f64).ceil() as usize;
    let desired = desired.max(floor).clamp(cfg.min_replicas, cfg.max_replicas);
    if per_replica > target * (1.0 + cfg.hysteresis) && desired > s.serving {
        ScaleDecision::Grow(desired - s.serving)
    } else if per_replica < target * (1.0 - cfg.hysteresis) && desired < s.serving {
        ScaleDecision::Shrink(s.serving - desired)
    } else {
        ScaleDecision::Hold
    }
}

/// Stateful wrapper around [`decide`]: interval sampling + post-action
/// cooldown, in caller-supplied seconds so wall-clock (`tick`) and
/// virtual-time (`decide_at` from the sim) callers share one clock
/// policy.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    pub cfg: AutoscaleCfg,
    origin: Instant,
    last_tick: Option<f64>,
    last_scale: Option<f64>,
    /// ledger reading at the previous decision (waste-rate brake)
    last_wasted: u64,
}

impl Autoscaler {
    pub fn new(cfg: AutoscaleCfg) -> Self {
        Autoscaler {
            cfg,
            origin: Instant::now(),
            last_tick: None,
            last_scale: None,
            last_wasted: 0,
        }
    }

    /// Gate + decide at `now` seconds (monotonic, caller's epoch).
    /// Returns `Hold` without consulting [`decide`] when the interval
    /// has not elapsed; suppresses Grow/Shrink during the cooldown
    /// window — except the emergency grow-to-min path, which must not
    /// wait out a cooldown while the fleet is below its floor.
    pub fn decide_at(&mut self, now: f64, s: &PoolSignals) -> ScaleDecision {
        if let Some(t) = self.last_tick {
            if now - t < self.cfg.interval {
                return ScaleDecision::Hold;
            }
        }
        self.last_tick = Some(now);
        let waste_delta = s.wasted_tokens.saturating_sub(self.last_wasted);
        self.last_wasted = s.wasted_tokens;
        let d = decide(&self.cfg, s);
        if d == ScaleDecision::Hold {
            return d;
        }
        // waste-rate brake: decoded tokens burned since the last look
        // mean the fleet is already churning (failing replicas, racing
        // migrations) — draining a replica on top of that would burn
        // more. Growth is unaffected.
        if matches!(d, ScaleDecision::Shrink(_)) && waste_delta > 0 {
            return ScaleDecision::Hold;
        }
        let emergency = s.serving < self.cfg.min_replicas;
        if !emergency {
            if let Some(t) = self.last_scale {
                if now - t < self.cfg.cooldown {
                    return ScaleDecision::Hold;
                }
            }
        }
        self.last_scale = Some(now);
        d
    }

    /// Wall-clock control step against the real pool: sample signals,
    /// decide, apply. The AsyncController calls this between training
    /// steps in async mode; it is cheap when the interval has not
    /// elapsed, and a Shrink is cheap too — `retire_idlest` only flips
    /// the slot to draining and parks its work for collector-absorbed
    /// salvage, so the training thread never waits out a drain.
    /// Returns what was decided (after gating).
    pub fn tick(&mut self, pool: &LlmProxyPool) -> ScaleDecision {
        let now = self.origin.elapsed().as_secs_f64();
        // check the interval BEFORE sampling: autoscale_signals()
        // resets the pool's queue-depth window, so an early tick must
        // not read-and-discard the observations the next real decision
        // needs (decide_at re-checks the same gate harmlessly)
        if let Some(t) = self.last_tick {
            if now - t < self.cfg.interval {
                return ScaleDecision::Hold;
            }
        }
        let signals = pool.autoscale_signals();
        let d = self.decide_at(now, &signals);
        match d {
            ScaleDecision::Grow(n) => {
                for _ in 0..n {
                    if pool.serving_replicas() >= self.cfg.max_replicas
                        || pool.add_replica().is_err()
                    {
                        break;
                    }
                }
            }
            ScaleDecision::Shrink(n) => {
                for _ in 0..n {
                    if pool.serving_replicas() <= self.cfg.min_replicas
                        || !pool.retire_idlest()
                    {
                        break;
                    }
                }
            }
            ScaleDecision::Hold => {}
        }
        // the flight recorder sees every applied decision, so a trace
        // shows why the replica lanes appear and drain
        if d != ScaleDecision::Hold {
            let rec = pool.recorder();
            if rec.is_enabled() {
                rec.emit(
                    "scale",
                    EventPhase::Instant,
                    0,
                    None,
                    0,
                    0,
                    format!(
                        "{d:?} serving={} queue_p90={:.1} outstanding={}",
                        pool.serving_replicas(),
                        signals.queue_depth,
                        signals.outstanding
                    ),
                );
            }
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AutoscaleCfg {
        AutoscaleCfg {
            enabled: true,
            min_replicas: 1,
            max_replicas: 8,
            target_queue_depth: 4.0,
            interval: 1.0,
            cooldown: 3.0,
            hysteresis: 0.25,
            adaptive_target: false,
            decode_knee: 16.0,
        }
    }

    fn sig(serving: usize, queue: f64, outstanding: usize) -> PoolSignals {
        PoolSignals {
            serving,
            queue_depth: queue,
            outstanding,
            slots: 8,
            wasted_tokens: 0,
            pred_mean_len: 0.0,
            pred_p90_len: 0.0,
        }
    }

    #[test]
    fn validation_rejects_nonsense_bounds() {
        assert!(cfg().validate().is_ok());
        assert!(AutoscaleCfg::disabled().validate().is_ok(), "disabled cfg is always fine");
        for mutate in [
            (|c: &mut AutoscaleCfg| c.min_replicas = 0) as fn(&mut AutoscaleCfg),
            |c| c.min_replicas = c.max_replicas + 1,
            |c| c.interval = 0.0,
            |c| c.interval = f64::NAN,
            |c| c.cooldown = c.interval / 2.0,
            |c| c.target_queue_depth = 0.0,
            |c| c.hysteresis = 1.0,
            |c| c.hysteresis = -0.1,
            |c| {
                c.adaptive_target = true;
                c.decode_knee = 0.0;
            },
            |c| {
                c.adaptive_target = true;
                c.decode_knee = f64::NAN;
            },
        ] {
            let mut c = cfg();
            mutate(&mut c);
            assert!(c.validate().is_err(), "{c:?} should be rejected");
            // the same nonsense is fine while the scaler is off
            c.enabled = false;
            assert!(c.validate().is_ok(), "disabled cfg must not be validated: {c:?}");
        }
    }

    #[test]
    fn grows_under_queue_pressure() {
        // load 24 over 2 replicas = 12/replica >> 4 * 1.25:
        // desired = ceil(24/4) = 6 -> grow by 4
        assert_eq!(decide(&cfg(), &sig(2, 16.0, 8)), ScaleDecision::Grow(4));
    }

    #[test]
    fn shrinks_when_load_fits_fewer_replicas() {
        // load 4 over 8 replicas = 0.5/replica << 4 * 0.75:
        // desired = ceil(4/4) = 1 -> shrink by 7
        assert_eq!(decide(&cfg(), &sig(8, 2.0, 2)), ScaleDecision::Shrink(7));
        // idle fleet collapses to the floor
        assert_eq!(decide(&cfg(), &sig(8, 0.0, 0)), ScaleDecision::Shrink(7));
    }

    #[test]
    fn hysteresis_band_holds() {
        // per-replica load inside [3, 5] with target 4: no action
        assert_eq!(decide(&cfg(), &sig(4, 8.0, 8)), ScaleDecision::Hold); // 4/replica
        assert_eq!(decide(&cfg(), &sig(4, 11.0, 8)), ScaleDecision::Hold); // 4.75
        assert_eq!(decide(&cfg(), &sig(4, 5.0, 8)), ScaleDecision::Hold); // 3.25
    }

    #[test]
    fn clamps_to_bounds() {
        // colossal load cannot exceed max_replicas
        assert_eq!(decide(&cfg(), &sig(8, 1000.0, 64)), ScaleDecision::Hold);
        assert_eq!(decide(&cfg(), &sig(6, 1000.0, 64)), ScaleDecision::Grow(2));
        // zero load cannot go below min_replicas
        assert_eq!(decide(&cfg(), &sig(1, 0.0, 0)), ScaleDecision::Hold);
    }

    #[test]
    fn below_min_is_an_emergency_grow() {
        // the fleet lost replicas (kill_replica): restore the floor
        // regardless of load
        let mut c = cfg();
        c.min_replicas = 3;
        assert_eq!(decide(&c, &sig(1, 0.0, 0)), ScaleDecision::Grow(2));
        // and the gate does not make it wait out a cooldown
        let mut a = Autoscaler::new(c);
        assert_eq!(a.decide_at(0.0, &sig(3, 100.0, 0)), ScaleDecision::Grow(5));
        assert_eq!(a.decide_at(1.0, &sig(1, 0.0, 0)), ScaleDecision::Grow(2));
    }

    #[test]
    fn in_flight_floor_respects_decode_windows() {
        // queue empty but 60 in flight on 8-slot replicas: shrinking to
        // ceil(60/4)=15 would be clamped by max, but the floor
        // ceil(60/8)=8 keeps the windows feasible anyway
        let c = cfg();
        let s = sig(8, 0.0, 60);
        assert_eq!(decide(&c, &s), ScaleDecision::Hold);
    }

    #[test]
    fn adaptive_target_tracks_the_length_profile() {
        let mut c = cfg();
        c.adaptive_target = true;
        c.decode_knee = 4.0;
        // cold profile: the hand-tuned constant applies unchanged
        assert_eq!(decide(&c, &sig(4, 8.0, 8)), ScaleDecision::Hold);
        // homogeneous lengths (mean == p90): the knee is the target —
        // same as target_queue_depth here, so still a hold
        let homog = PoolSignals { pred_mean_len: 500.0, pred_p90_len: 500.0, ..sig(4, 8.0, 8) };
        assert_eq!(decide(&c, &homog), ScaleDecision::Hold);
        // heavy tail (p90 = 4x mean): target drops to 4 * 0.25 = 1, so
        // the same 4-per-replica load now demands a much wider fleet
        let tailed = PoolSignals { pred_mean_len: 500.0, pred_p90_len: 2000.0, ..sig(4, 8.0, 8) };
        assert_eq!(decide(&c, &tailed), ScaleDecision::Grow(4), "16 load / target 1 -> 8 wide");
        // the adaptive target never exceeds the configured constant
        let short = PoolSignals { pred_mean_len: 500.0, pred_p90_len: 100.0, ..sig(4, 8.0, 8) };
        assert_eq!(decide(&c, &short), ScaleDecision::Hold, "clamped to target_queue_depth");
        // ... and never collapses below one request per replica
        let extreme =
            PoolSignals { pred_mean_len: 1.0, pred_p90_len: 1e9, ..sig(8, 0.0, 8) };
        assert_eq!(decide(&c, &extreme), ScaleDecision::Hold, "floor at 1: 8 load needs 8");
    }

    #[test]
    fn waste_rate_brake_defers_shrink_but_not_growth() {
        let mut a = Autoscaler::new(cfg());
        // t=0: idle fleet, but 100 tokens were burned since the scaler
        // last looked (first look: delta from 0) -> shrink suppressed
        let wasteful = PoolSignals { wasted_tokens: 100, ..sig(8, 0.0, 0) };
        assert_eq!(a.decide_at(0.0, &wasteful), ScaleDecision::Hold);
        // t=1.2: waste stopped accruing (same cumulative reading) ->
        // the shrink goes through
        assert_eq!(a.decide_at(1.2, &wasteful), ScaleDecision::Shrink(7));
        // growth is never braked by waste
        let mut b = Autoscaler::new(cfg());
        let loaded = PoolSignals { wasted_tokens: 100, ..sig(2, 16.0, 8) };
        assert_eq!(b.decide_at(0.0, &loaded), ScaleDecision::Grow(4));
    }

    #[test]
    fn gate_enforces_interval_and_cooldown() {
        let mut a = Autoscaler::new(cfg());
        // t=0: first sample, heavy load -> grow
        assert_eq!(a.decide_at(0.0, &sig(2, 16.0, 8)), ScaleDecision::Grow(4));
        // t=0.5: inside the interval -> hold without deciding
        assert_eq!(a.decide_at(0.5, &sig(2, 16.0, 8)), ScaleDecision::Hold);
        // t=1.5: interval elapsed but cooldown (3s) active -> hold
        assert_eq!(a.decide_at(1.5, &sig(2, 16.0, 8)), ScaleDecision::Hold);
        // t=3.2: cooldown over -> acts again
        assert_eq!(a.decide_at(3.2, &sig(2, 16.0, 8)), ScaleDecision::Grow(4));
        // a Hold decision does not re-arm the cooldown
        assert_eq!(a.decide_at(4.4, &sig(6, 24.0, 0)), ScaleDecision::Hold);
        assert_eq!(a.decide_at(6.3, &sig(6, 0.0, 0)), ScaleDecision::Shrink(5));
    }
}
