//! LLMProxy (paper Section 4.2): the command-driven event loop that
//! orchestrates inference workers.
//!
//! A dedicated thread owns the PJRT decode executable (wrapper types
//! are not Send) and runs a continuous, non-blocking loop with the
//! paper's three services:
//!   1. *Step-wise inference* — each iteration advances every active
//!      slot by one decoding step (continuous batching),
//!   2. *Post-processing* — finished requests are immediately returned
//!      to the originating client over its reply channel,
//!   3. *Process commands* — ADD enqueues requests, ABORT interrupts
//!      and reclaims them, UPDATE_WEIGHTS swaps the policy (the
//!      AsyncController's suspend -> model_update -> resume),
//!      SUSPEND/RESUME gate the loop for synchronous mode.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// A generation request (one sequence; prompt replication happens at
/// the caller by submitting n independent requests — Section 5.1.2).
pub struct GenRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub reply: Sender<GenResult>,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    /// generated tokens (including the terminating EOS if emitted)
    pub tokens: Vec<i32>,
    /// behavior-policy logprob per generated token (pi_old for IS)
    pub logps: Vec<f32>,
    /// policy version that produced (finished) this sample
    pub version: u64,
}

enum Cmd {
    Add(GenRequest),
    Abort(u64),
    UpdateWeights { weights: Vec<f32>, version: u64, ack: Option<Sender<()>> },
    Suspend,
    Resume,
    Shutdown,
}

/// Cloneable command handle to a proxy thread. The fleet layer hands
/// these to its per-replica completion collectors so they can dispatch
/// pool-queued requests without owning the replica itself; `LlmProxy`
/// (which additionally owns the join handle) delegates here.
#[derive(Clone)]
pub struct ProxyClient {
    tx: Sender<Cmd>,
    next_id: Arc<AtomicU64>,
}

impl ProxyClient {
    /// ADD with a caller-supplied reply channel; returns the request id.
    /// The pool points every request at its per-replica collector.
    pub fn submit(&self, prompt: Vec<i32>, max_new_tokens: usize, reply: Sender<GenResult>) -> u64 {
        self.try_submit(prompt, max_new_tokens, reply).unwrap_or(0)
    }

    /// ADD that reports delivery: `None` means the proxy thread is gone
    /// (its event loop exited), so the request — and its reply sender —
    /// were dropped. The fleet uses this to detect dead replicas and
    /// fail requests over instead of stranding callers.
    pub fn try_submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        reply: Sender<GenResult>,
    ) -> Option<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Cmd::Add(GenRequest { id, prompt, max_new_tokens, reply })).ok().map(|_| id)
    }

    /// ABORT: interrupt a running/queued request (its reply channel
    /// simply never fires; the work is reclaimed). Aborting an id that
    /// already finished (or never existed) is a no-op.
    pub fn abort(&self, id: u64) {
        let _ = self.tx.send(Cmd::Abort(id));
    }

    /// model_update broadcast: swap weights and advance the version.
    pub fn update_weights(&self, weights: Vec<f32>, version: u64) {
        let _ = self.tx.send(Cmd::UpdateWeights { weights, version, ack: None });
    }

    /// model_update with completion acknowledgement: the returned
    /// channel fires once the swap has been applied (between decode
    /// steps). The staggered fleet broadcast waits on this before
    /// moving to the next replica, so at most one replica is ever
    /// paused at a time. If the proxy thread is gone the channel
    /// disconnects instead — callers should treat both as "done".
    pub fn update_weights_synced(&self, weights: Vec<f32>, version: u64) -> Receiver<()> {
        let (ack, rx) = channel();
        let _ = self.tx.send(Cmd::UpdateWeights { weights, version, ack: Some(ack) });
        rx
    }

    pub fn suspend(&self) {
        let _ = self.tx.send(Cmd::Suspend);
    }

    pub fn resume(&self) {
        let _ = self.tx.send(Cmd::Resume);
    }

    /// Fault injection: stop the event loop as if the replica process
    /// died. In-flight requests are dropped without replies (callers
    /// recover via hang-timeout migration); subsequent submissions fail
    /// and the fleet marks the replica dead.
    pub(crate) fn kill(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }
}

/// Client handle to the proxy thread.
pub struct LlmProxy {
    client: ProxyClient,
    join: Option<JoinHandle<Result<ProxyReport>>>,
}

/// Loop statistics returned at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProxyReport {
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub completed: u64,
    pub aborted: u64,
    /// decode-batch occupancy summed over steps (utilization proxy)
    pub occupancy_sum: u64,
}

impl ProxyReport {
    /// Mean fraction of decode slots busy per step.
    pub fn mean_occupancy(&self, batch: usize) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / (self.decode_steps as f64 * batch as f64)
    }
}

impl LlmProxy {
    /// Spawn the proxy event loop. The thread constructs its own
    /// ModelRuntime from `artifacts_dir`; `init_weights` is the flat
    /// parameter snapshot; `eos` terminates generation.
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        init_weights: Vec<f32>,
        eos: i32,
        seed: u64,
    ) -> Self {
        let (tx, rx) = channel();
        let join = std::thread::Builder::new()
            .name("llm-proxy".into())
            .spawn(move || proxy_loop(artifacts_dir, init_weights, eos, seed, rx))
            .expect("spawn llm-proxy");
        LlmProxy { client: ProxyClient { tx, next_id: Arc::new(AtomicU64::new(1)) }, join: Some(join) }
    }

    /// A cloneable command handle (no join handle; cannot shut down).
    pub fn client(&self) -> ProxyClient {
        self.client.clone()
    }

    /// Test-only replica with no engine: accepts commands, holds ADDed
    /// requests without ever decoding them, acks weight swaps. Lets the
    /// fleet's routing/bookkeeping be exercised without artifacts.
    #[cfg(test)]
    pub(crate) fn spawn_stub() -> Self {
        let (tx, rx) = channel::<Cmd>();
        let join = std::thread::Builder::new()
            .name("llm-proxy-stub".into())
            .spawn(move || {
                let mut held: Vec<GenRequest> = Vec::new();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Add(req) => held.push(req),
                        Cmd::Abort(id) => held.retain(|r| r.id != id),
                        Cmd::UpdateWeights { ack, .. } => {
                            if let Some(ack) = ack {
                                let _ = ack.send(());
                            }
                        }
                        Cmd::Suspend | Cmd::Resume => {}
                        Cmd::Shutdown => break,
                    }
                }
                Ok(ProxyReport::default())
            })
            .expect("spawn llm-proxy stub");
        LlmProxy { client: ProxyClient { tx, next_id: Arc::new(AtomicU64::new(1)) }, join: Some(join) }
    }

    /// ADD: enqueue a generation request; returns (id, reply receiver).
    pub fn generate(&self, prompt: Vec<i32>, max_new_tokens: usize) -> (u64, Receiver<GenResult>) {
        let (reply, rx) = channel();
        let id = self.client.submit(prompt, max_new_tokens, reply);
        (id, rx)
    }

    /// ABORT: interrupt a running/queued request (its reply channel
    /// simply never fires; the work is reclaimed).
    pub fn abort(&self, id: u64) {
        self.client.abort(id);
    }

    /// model_update broadcast: swap weights and advance the version.
    pub fn update_weights(&self, weights: Vec<f32>, version: u64) {
        self.client.update_weights(weights, version);
    }

    /// model_update with applied-acknowledgement (see [`ProxyClient`]).
    pub fn update_weights_synced(&self, weights: Vec<f32>, version: u64) -> Receiver<()> {
        self.client.update_weights_synced(weights, version)
    }

    pub fn suspend(&self) {
        self.client.suspend();
    }

    pub fn resume(&self) {
        self.client.resume();
    }

    /// Stop the loop and collect its report.
    pub fn shutdown(mut self) -> Result<ProxyReport> {
        let _ = self.client.tx.send(Cmd::Shutdown);
        match self.join.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("proxy thread panicked"))?,
            None => anyhow::bail!("already shut down"),
        }
    }
}

impl Drop for LlmProxy {
    fn drop(&mut self) {
        let _ = self.client.tx.send(Cmd::Shutdown);
        if let Some(h) = self.join.take() {
            let _ = h.join();
        }
    }
}

struct Slot {
    req: GenRequest,
    /// absolute write position in the row buffer
    pos: usize,
    prompt_len: usize,
    tokens: Vec<i32>,
    logps: Vec<f32>,
}

/// ABORT shared by both command-handling sites: purge the queue AND
/// any occupied decode slot (an abort landing while suspended must not
/// leave the slot to decode on after resume).
fn do_abort(
    id: u64,
    queue: &mut VecDeque<GenRequest>,
    slots: &mut [Option<Slot>],
    tokens_buf: &mut [i32],
    s: usize,
    report: &mut ProxyReport,
) {
    queue.retain(|r| r.id != id);
    for (si, slot) in slots.iter_mut().enumerate() {
        if slot.as_ref().map(|sl| sl.req.id) == Some(id) {
            *slot = None;
            report.aborted += 1;
            tokens_buf[si * s..(si + 1) * s].fill(0);
        }
    }
}

fn proxy_loop(
    dir: std::path::PathBuf,
    init_weights: Vec<f32>,
    eos: i32,
    seed: u64,
    rx: Receiver<Cmd>,
) -> Result<ProxyReport> {
    let rt = ModelRuntime::load(&dir)?;
    let (b, s, v) = (rt.manifest.decode_batch, rt.manifest.max_seq, rt.manifest.vocab);
    let mut params = rt.params_literal(&init_weights)?;
    let mut version = 0u64;
    let mut rng = Rng::new(seed ^ 0x11f);

    let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
    let mut tokens_buf = vec![0i32; b * s];
    let mut queue: VecDeque<GenRequest> = VecDeque::new();
    let mut suspended = false;
    let mut report = ProxyReport::default();

    'outer: loop {
        // --- service 3: process commands (non-blocking drain) ---
        loop {
            match rx.try_recv() {
                Ok(Cmd::Add(req)) => queue.push_back(req),
                Ok(Cmd::Abort(id)) => {
                    do_abort(id, &mut queue, &mut slots, &mut tokens_buf, s, &mut report)
                }
                Ok(Cmd::UpdateWeights { weights, version: ver, ack }) => {
                    // suspend -> broadcast -> resume, atomically w.r.t.
                    // decode steps (we are between steps here)
                    params = rt.params_literal(&weights)?;
                    version = ver;
                    if let Some(ack) = ack {
                        let _ = ack.send(());
                    }
                }
                Ok(Cmd::Suspend) => suspended = true,
                Ok(Cmd::Resume) => suspended = false,
                Ok(Cmd::Shutdown) => break 'outer,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => break 'outer,
            }
        }

        // admit queued requests into free slots (continuous batching)
        if !suspended {
            for si in 0..b {
                if slots[si].is_none() {
                    if let Some(req) = queue.pop_front() {
                        let pl = req.prompt.len().min(s - 1);
                        let row = &mut tokens_buf[si * s..(si + 1) * s];
                        row.fill(0);
                        row[..pl].copy_from_slice(&req.prompt[..pl]);
                        slots[si] = Some(Slot {
                            pos: pl,
                            prompt_len: pl,
                            tokens: Vec::new(),
                            logps: Vec::new(),
                            req,
                        });
                    }
                }
            }
        }

        let active = slots.iter().filter(|x| x.is_some()).count();
        if suspended || active == 0 {
            // idle: block briefly for the next command
            match rx.recv_timeout(std::time::Duration::from_millis(2)) {
                Ok(cmd) => {
                    // re-inject into the drain above on the next pass
                    match cmd {
                        Cmd::Add(req) => queue.push_back(req),
                        Cmd::Abort(id) => {
                            do_abort(id, &mut queue, &mut slots, &mut tokens_buf, s, &mut report)
                        }
                        Cmd::UpdateWeights { weights, version: ver, ack } => {
                            params = rt.params_literal(&weights)?;
                            version = ver;
                            if let Some(ack) = ack {
                                let _ = ack.send(());
                            }
                        }
                        Cmd::Suspend => suspended = true,
                        Cmd::Resume => suspended = false,
                        Cmd::Shutdown => break 'outer,
                    }
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
            }
            continue;
        }

        // --- service 1: one decode step over the whole batch ---
        let pos_vec: Vec<i32> = slots
            .iter()
            .map(|sl| sl.as_ref().map(|x| x.pos as i32).unwrap_or(1))
            .collect();
        let logits = rt.decode_step(&params, &tokens_buf, &pos_vec)?;
        report.decode_steps += 1;
        report.occupancy_sum += active as u64;

        // --- sample + service 2: post-process completions ---
        for si in 0..b {
            let Some(slot) = slots[si].as_mut() else { continue };
            let row_logits = &logits[si * v..(si + 1) * v];
            // temperature-1, top-p-1 raw sampling (paper Appendix A)
            let tok = rng.sample_logits(row_logits) as i32;
            // exact behavior logprob from the same logits
            let max = row_logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 =
                max + row_logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln();
            slot.logps.push(row_logits[tok as usize] - lse);
            slot.tokens.push(tok);
            tokens_buf[si * s + slot.pos] = tok;
            slot.pos += 1;
            report.tokens_generated += 1;

            let done = tok == eos
                || slot.tokens.len() >= slot.req.max_new_tokens
                || slot.pos >= s;
            if done {
                let slot = slots[si].take().unwrap();
                report.completed += 1;
                let _ = slot.req.reply.send(GenResult {
                    id: slot.req.id,
                    tokens: slot.tokens,
                    logps: slot.logps,
                    version,
                });
                tokens_buf[si * s..(si + 1) * s].fill(0);
                let _ = slot.prompt_len;
            }
        }
    }

    Ok(report)
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/integration.rs (requires
    // artifacts); unit logic (occupancy math) tested here.
    use super::*;

    #[test]
    fn occupancy_math() {
        let r = ProxyReport { decode_steps: 10, occupancy_sum: 40, ..Default::default() };
        assert!((r.mean_occupancy(8) - 0.5).abs() < 1e-12);
        assert_eq!(ProxyReport::default().mean_occupancy(8), 0.0);
    }
}
