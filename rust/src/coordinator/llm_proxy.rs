//! LLMProxy (paper Section 4.2): the command-driven event loop that
//! orchestrates inference workers.
//!
//! A dedicated thread owns the PJRT decode executable (wrapper types
//! are not Send) and runs a continuous, non-blocking loop with the
//! paper's three services:
//!   1. *Step-wise inference* — each iteration advances every active
//!      slot by one decoding step (continuous batching),
//!   2. *Post-processing* — finished requests are immediately returned
//!      to the originating client over its reply channel,
//!   3. *Process commands* — ADD enqueues requests, ABORT interrupts
//!      and reclaims them, RECLAIM interrupts and *salvages* the
//!      decoded prefix (partial-rollout migration, Section 5.2.2),
//!      UPDATE_WEIGHTS swaps the policy (the AsyncController's
//!      suspend -> model_update -> resume), SUSPEND/RESUME gate the
//!      loop for synchronous mode.
//!
//! The request surface is the resumable [`GenerationTask`]: a prompt
//! plus an optional already-decoded prefix. On ADD the loop prefills
//! `prompt ++ prefix` and continues decoding from where the previous
//! attempt stopped, so a generation migrated off a hung or dead
//! replica resumes instead of burning its decoded tokens. Every token
//! dropped *without* salvage (ABORT, loop teardown) is counted into
//! `ProxyReport::wasted_tokens` and the pool-shared [`TokenLedger`] —
//! partial output never vanishes without a trace.
//!
//! All replies ride one channel type, [`ProxyEvent`]: completions as
//! `Done`, RECLAIM answers as `Reclaimed`. Because the loop emits both
//! onto whatever senders it holds *from one thread*, a caller that
//! points a task's reply and its reclaim at the same channel gets a
//! total FIFO order between "it finished" and "it was salvaged" — the
//! property the fleet's collectors use to close the drain race: a
//! generation that completes just before the RECLAIM lands has its
//! `Done` strictly ahead of the empty `Reclaimed` answer.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::Result;

use crate::metrics::trace::{AttrCategory, AttrStopwatch, Attribution};
use crate::runtime::ModelRuntime;
use crate::util::rng::Rng;

/// A resumable generation request (one sequence; prompt replication
/// happens at the caller by submitting n independent tasks —
/// Section 5.1.2). `prefix` carries tokens decoded by an earlier
/// attempt of the *same* logical generation: the proxy prefills
/// `prompt ++ prefix` and keeps decoding, so migration off a fail-slow
/// or dead replica salvages instead of restarting. A fresh task has an
/// empty prefix.
pub struct GenerationTask {
    pub prompt: Vec<i32>,
    /// tokens already decoded by a previous attempt (salvaged prefix)
    pub prefix: Vec<i32>,
    /// behavior logprobs of the prefix tokens, recorded when they were
    /// first decoded (pi_old must not be recomputed under new weights)
    pub prefix_logps: Vec<f32>,
    /// weight version that produced the *first* decoded token; only
    /// meaningful when `prefix` is non-empty. A resumed generation may
    /// finish under a newer version — the completion then reports a
    /// piecewise-policy sequence (`GenResult::prefix_version` !=
    /// `GenResult::version`).
    pub prefix_version: u64,
    /// total new-token budget for the logical generation; the salvaged
    /// prefix counts against it (a resumed task decodes
    /// `budget - prefix.len()` more tokens at most)
    pub budget: usize,
    /// argmax decoding instead of sampling: resume-deterministic, so a
    /// migrated generation is token-identical to an uninterrupted one
    pub greedy: bool,
    /// prompt-group key for the generation-length predictor (GRPO
    /// members / retries of one env task share it). 0 is a valid group;
    /// ungrouped callers just share one statistics bucket.
    pub group: u64,
    /// predicted total generation length in tokens, stamped by the
    /// fleet's `LengthPredictor` at dispatch (0 = no prediction: the
    /// admission order falls back to the budget, i.e. FIFO among equal
    /// budgets). Already clamped to `budget`.
    pub predicted_len: usize,
    /// predictor classified this rollout into the long class — admitted
    /// under the long-work reservation instead of shortest-first
    pub long_class: bool,
    /// conversation identity for multi-turn agentic episodes: every
    /// turn of one episode carries the same key (the engine stamps
    /// `Episode::group_key`, like PR 7 stamped `group`), so the pool's
    /// KV-prefix index can route a returning turn to the replica still
    /// holding the conversation's KV state. 0 = no conversation
    /// affinity (single-turn callers).
    pub conversation: u64,
    /// tokens of this task's `prompt ++ prefix` the *target* replica
    /// already holds in KV cache, stamped by the fleet at dispatch from
    /// the prefix index (0 = no match / index disabled). The proxy
    /// skips re-prefill for the covered portion: only the uncovered
    /// delta is billed to `prefill`/`prefill_replay` attribution.
    pub cached_prefix: usize,
    /// where the completion ([`ProxyEvent::Done`]) is delivered. The
    /// fleet points every replica-side task at the replica's collector
    /// channel, which also receives the RECLAIM answers — one FIFO
    /// stream per replica.
    pub reply: Sender<ProxyEvent>,
}

impl GenerationTask {
    /// A from-scratch task: empty prefix, sampling decode.
    pub fn fresh(prompt: Vec<i32>, budget: usize, reply: Sender<ProxyEvent>) -> Self {
        GenerationTask {
            prompt,
            prefix: Vec::new(),
            prefix_logps: Vec::new(),
            prefix_version: 0,
            budget,
            greedy: false,
            group: 0,
            predicted_len: 0,
            long_class: false,
            conversation: 0,
            cached_prefix: 0,
            reply,
        }
    }

    /// Builder: switch to argmax decoding (eval episodes, determinism
    /// tests).
    pub fn with_greedy(mut self) -> Self {
        self.greedy = true;
        self
    }

    /// Tokens already decoded by earlier attempts.
    pub fn decoded(&self) -> usize {
        self.prefix.len()
    }
}

/// A generation request as held by the proxy loop (task + loop id).
struct GenRequest {
    id: u64,
    task: GenerationTask,
    /// admission rounds in which a younger request was admitted ahead
    /// of this one — the starvation clock for [`pick_admission`]'s
    /// aging bound
    passed_over: u32,
}

/// A finished generation.
#[derive(Clone, Debug)]
pub struct GenResult {
    pub id: u64,
    /// generated tokens (including the terminating EOS if emitted).
    /// For a resumed task this is the FULL response — salvaged prefix
    /// plus the continuation — so callers never splice.
    pub tokens: Vec<i32>,
    /// behavior-policy logprob per generated token (pi_old for IS)
    pub logps: Vec<f32>,
    /// policy version that produced (finished) this sample
    pub version: u64,
    /// policy version that produced the first token. Differs from
    /// `version` when a salvaged prefix spans a weight update (or the
    /// weights were swapped mid-decode): the sequence is piecewise-
    /// policy and is surfaced as a `cross_version` sample downstream.
    pub prefix_version: u64,
}

impl GenResult {
    /// The sample straddles a weight update (piecewise behavior policy).
    pub fn cross_version(&self) -> bool {
        self.prefix_version != self.version
    }
}

/// The decoded progress of an interrupted request, handed back by
/// RECLAIM so the caller can resubmit it elsewhere as a resumed
/// [`GenerationTask`].
#[derive(Clone, Debug, Default)]
pub struct Salvage {
    pub tokens: Vec<i32>,
    pub logps: Vec<f32>,
    /// weight version that produced the first salvaged token
    pub start_version: u64,
}

/// What a replica emits onto a reply channel: finished generations as
/// [`Done`](ProxyEvent::Done), RECLAIM answers as
/// [`Reclaimed`](ProxyEvent::Reclaimed). Both are sent by the proxy
/// thread, so on any single channel they arrive in the order the loop
/// produced them. `Reclaimed { salvage: None }` means the id was
/// unknown at the replica — because it already finished (its `Done`
/// precedes the answer on the same channel) or never existed.
#[derive(Debug)]
pub enum ProxyEvent {
    Done(GenResult),
    Reclaimed { id: u64, salvage: Option<Salvage> },
    /// Collector wakeup hint, sent by the *pool* (never a proxy loop)
    /// onto a replica's collector channel when a salvage is parked
    /// there: the collector recomputes its expiry deadline instead of
    /// polling on a tick. Carries no payload and never reaches caller
    /// reply channels.
    Nudge,
}

impl ProxyEvent {
    /// Unwrap a completed generation; panics on a reclaim answer. For
    /// callers that never issue RECLAIMs on their reply channel
    /// (tests, examples, the single-proxy training surface).
    pub fn done(self) -> GenResult {
        match self {
            ProxyEvent::Done(r) => r,
            ProxyEvent::Reclaimed { id, .. } => {
                panic!("expected a completed generation, got a reclaim answer for {id}")
            }
            ProxyEvent::Nudge => {
                panic!("expected a completed generation, got a collector nudge")
            }
        }
    }
}

/// Pool-shared live counters for decoded-token outcomes. Replica loops
/// add waste as they discard work; the fleet adds salvage as it reuses
/// it. Readable at any time (`LlmProxyPool::token_stats`), unlike the
/// per-replica `ProxyReport` which is only collected at shutdown.
#[derive(Debug, Default)]
pub struct TokenLedger {
    wasted: AtomicU64,
    salvaged: AtomicU64,
    prefix_hit: AtomicU64,
}

impl TokenLedger {
    pub fn add_wasted(&self, n: u64) {
        self.wasted.fetch_add(n, Ordering::Relaxed);
    }

    pub fn add_salvaged(&self, n: u64) {
        self.salvaged.fetch_add(n, Ordering::Relaxed);
    }

    /// Prompt/prefix tokens a dispatch found already KV-resident on its
    /// target replica (the KV-prefix index match) — prefill work the
    /// fleet did NOT have to redo. Charged by the pool at dispatch.
    pub fn add_prefix_hit(&self, n: u64) {
        self.prefix_hit.fetch_add(n, Ordering::Relaxed);
    }

    pub fn stats(&self) -> TokenStats {
        TokenStats {
            wasted_tokens: self.wasted.load(Ordering::Relaxed),
            salvaged_tokens: self.salvaged.load(Ordering::Relaxed),
            prefix_hit_tokens: self.prefix_hit.load(Ordering::Relaxed),
        }
    }
}

/// Per-replica decode-progress gossip, published by the proxy loop on
/// every decoded token and read lock-free by the pool. Two numbers:
/// the monotonic total ever decoded here, and the tokens decoded for
/// requests *currently in slots* that are not yet covered by any
/// salvaged prefix. The latter is what `retire_idlest` adds to the
/// carried-prefix salvage cost to rank victims by TRUE decoded totals
/// — without gossip a replica that decoded 5k fresh tokens looks as
/// cheap to retire as one that decoded none.
#[derive(Debug, Default)]
pub struct ProgressGossip {
    decoded_total: AtomicU64,
    inflight_fresh: AtomicU64,
}

impl ProgressGossip {
    /// One token decoded into a live slot.
    fn on_token(&self) {
        self.decoded_total.fetch_add(1, Ordering::Relaxed);
        self.inflight_fresh.fetch_add(1, Ordering::Relaxed);
    }

    /// A slot closed (done / abort / reclaim / teardown): its `fresh`
    /// locally-decoded tokens are no longer at risk in flight.
    fn on_slot_closed(&self, fresh: usize) {
        let _ = self.inflight_fresh.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            Some(v.saturating_sub(fresh as u64))
        });
    }

    /// Tokens ever decoded by this replica (monotonic).
    pub fn decoded_total(&self) -> u64 {
        self.decoded_total.load(Ordering::Relaxed)
    }

    /// Freshly decoded tokens currently at risk in live slots (i.e.
    /// what a retire/kill would have to salvage beyond carried
    /// prefixes).
    pub fn inflight_fresh(&self) -> u64 {
        self.inflight_fresh.load(Ordering::Relaxed)
    }
}

/// Snapshot of a [`TokenLedger`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TokenStats {
    /// decoded tokens discarded without salvage (aborts, teardown,
    /// salvage below `min_salvage_tokens`, salvage with the
    /// `partial_migration` arm off)
    pub wasted_tokens: u64,
    /// decoded tokens carried to a resumed attempt by migration or
    /// dead-replica resubmission
    pub salvaged_tokens: u64,
    /// prompt/prefix tokens found already KV-resident on the dispatch
    /// target (KV-prefix index hits) — re-prefill work avoided by
    /// cache-aware routing
    pub prefix_hit_tokens: u64,
}

enum Cmd {
    Add(GenRequest),
    Abort(u64),
    /// abort-with-salvage: remove the request and answer on `reply`
    /// with `ProxyEvent::Reclaimed` — decoded progress for live ids,
    /// `salvage: None` for unknown/finished ones (the caller's channel
    /// already carries the `Done` in the latter case).
    Reclaim { id: u64, reply: Sender<ProxyEvent> },
    UpdateWeights { weights: Vec<f32>, version: u64, ack: Option<Sender<()>> },
    Suspend,
    Resume,
    Shutdown,
}

/// Cloneable command handle to a proxy thread. The fleet layer hands
/// these to its per-replica completion collectors so they can dispatch
/// pool-queued requests without owning the replica itself; `LlmProxy`
/// (which additionally owns the join handle) delegates here.
#[derive(Clone)]
pub struct ProxyClient {
    tx: Sender<Cmd>,
    next_id: Arc<AtomicU64>,
}

impl ProxyClient {
    /// ADD a [`GenerationTask`]; returns the request id. The pool
    /// points every task at its per-replica collector.
    pub fn submit(&self, task: GenerationTask) -> u64 {
        self.try_submit(task).unwrap_or(0)
    }

    /// ADD that reports delivery: `None` means the proxy thread is gone
    /// (its event loop exited), so the task — and its reply sender —
    /// were dropped. The fleet uses this to detect dead replicas and
    /// fail requests over instead of stranding callers.
    pub fn try_submit(&self, task: GenerationTask) -> Option<u64> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Cmd::Add(GenRequest { id, task, passed_over: 0 })).ok().map(|_| id)
    }

    /// ABORT: interrupt a running/queued request (its reply channel
    /// simply never fires; the decoded tokens are counted wasted).
    /// Aborting an id that already finished (or never existed) is a
    /// no-op.
    pub fn abort(&self, id: u64) {
        let _ = self.tx.send(Cmd::Abort(id));
    }

    /// RECLAIM onto a dedicated channel: interrupt a running/queued
    /// request and receive its decoded progress for resumption
    /// elsewhere. Unknown/finished ids answer `Reclaimed { salvage:
    /// None }`; a gone replica disconnects the channel.
    pub fn reclaim(&self, id: u64) -> Receiver<ProxyEvent> {
        let (reply, rx) = channel();
        let _ = self.tx.send(Cmd::Reclaim { id, reply });
        rx
    }

    /// RECLAIM answered onto a caller-supplied sender — the fleet
    /// passes the replica's own completion channel, so the answer is
    /// totally ordered against the request's possible `Done` event.
    /// Returns false when the proxy thread is gone (no answer will
    /// ever come); never blocks.
    pub(crate) fn reclaim_via(&self, id: u64, reply: Sender<ProxyEvent>) -> bool {
        self.tx.send(Cmd::Reclaim { id, reply }).is_ok()
    }

    /// model_update broadcast: swap weights and advance the version.
    pub fn update_weights(&self, weights: Vec<f32>, version: u64) {
        let _ = self.tx.send(Cmd::UpdateWeights { weights, version, ack: None });
    }

    /// model_update with completion acknowledgement: the returned
    /// channel fires once the swap has been applied (between decode
    /// steps). The staggered fleet broadcast waits on this before
    /// moving to the next replica, so at most one replica is ever
    /// paused at a time. If the proxy thread is gone the channel
    /// disconnects instead — callers should treat both as "done".
    pub fn update_weights_synced(&self, weights: Vec<f32>, version: u64) -> Receiver<()> {
        let (ack, rx) = channel();
        let _ = self.tx.send(Cmd::UpdateWeights { weights, version, ack: Some(ack) });
        rx
    }

    pub fn suspend(&self) {
        let _ = self.tx.send(Cmd::Suspend);
    }

    pub fn resume(&self) {
        let _ = self.tx.send(Cmd::Resume);
    }

    /// Fault injection: stop the event loop as if the replica process
    /// died. In-flight requests are dropped without replies (the fleet
    /// drains salvage first — see `LlmProxyPool::kill_replica`);
    /// subsequent submissions fail and the fleet marks the replica
    /// dead.
    pub(crate) fn kill(&self) {
        let _ = self.tx.send(Cmd::Shutdown);
    }
}

/// How a test stub answers RECLAIM (see `spawn_stub_inner`).
#[cfg(test)]
#[derive(Clone, Copy)]
enum StubReclaim {
    /// fabricate this many freshly decoded tokens on top of the prefix
    Salvage(usize),
    /// emit a `Done` first, then answer `salvage: None` (drain race)
    FinishFirst(usize),
    /// never answer (wedged replica)
    Mute,
}

/// Client handle to the proxy thread.
pub struct LlmProxy {
    client: ProxyClient,
    ledger: Arc<TokenLedger>,
    /// where this loop's wall-seconds went (decode/prefill/sync/idle);
    /// the loop laps it continuously, the pool reads it live
    attr: Arc<Attribution>,
    /// live decoded-token gossip (shared with the loop; the pool reads
    /// it for retire-victim ranking and predicted-remaining loads)
    gossip: Arc<ProgressGossip>,
    join: Option<JoinHandle<Result<ProxyReport>>>,
}

/// Loop statistics returned at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProxyReport {
    pub decode_steps: u64,
    pub tokens_generated: u64,
    pub completed: u64,
    pub aborted: u64,
    /// requests interrupted by RECLAIM with their progress handed back
    /// (successful salvage drains; NOT counted in `aborted`, which
    /// keeps meaning real cancellations)
    pub reclaimed: u64,
    /// decoded tokens this replica discarded without salvage: aborts
    /// (including the previously salvaged prefix of a resumed task —
    /// the whole accumulated response is lost) and requests still held
    /// when the loop exited
    pub wasted_tokens: u64,
    /// decode-batch occupancy summed over steps (utilization proxy)
    pub occupancy_sum: u64,
}

impl ProxyReport {
    /// Mean fraction of decode slots busy per step.
    pub fn mean_occupancy(&self, batch: usize) -> f64 {
        if self.decode_steps == 0 {
            return 0.0;
        }
        self.occupancy_sum as f64 / (self.decode_steps as f64 * batch as f64)
    }
}

impl LlmProxy {
    /// Spawn the proxy event loop with a private token ledger. The
    /// thread constructs its own ModelRuntime from `artifacts_dir`;
    /// `init_weights` is the flat parameter snapshot; `eos` terminates
    /// generation.
    pub fn spawn(
        artifacts_dir: std::path::PathBuf,
        init_weights: Vec<f32>,
        eos: i32,
        seed: u64,
    ) -> Self {
        Self::spawn_with_ledger(artifacts_dir, init_weights, eos, seed, Arc::default())
    }

    /// Spawn with a caller-owned ledger (the pool shares one across
    /// all replicas so fleet-level waste is live-readable).
    pub(crate) fn spawn_with_ledger(
        artifacts_dir: std::path::PathBuf,
        init_weights: Vec<f32>,
        eos: i32,
        seed: u64,
        ledger: Arc<TokenLedger>,
    ) -> Self {
        let (tx, rx) = channel();
        let lg = ledger.clone();
        let attr: Arc<Attribution> = Arc::default();
        let at = attr.clone();
        let gossip: Arc<ProgressGossip> = Arc::default();
        let gs = gossip.clone();
        let join = std::thread::Builder::new()
            .name("llm-proxy".into())
            .spawn(move || proxy_loop(artifacts_dir, init_weights, eos, seed, rx, lg, at, gs))
            .expect("spawn llm-proxy");
        LlmProxy {
            client: ProxyClient { tx, next_id: Arc::new(AtomicU64::new(1)) },
            ledger,
            attr,
            gossip,
            join: Some(join),
        }
    }

    /// A cloneable command handle (no join handle; cannot shut down).
    pub fn client(&self) -> ProxyClient {
        self.client.clone()
    }

    /// Live wasted/salvaged token counters for this replica's ledger.
    pub fn token_stats(&self) -> TokenStats {
        self.ledger.stats()
    }

    /// The loop's live time-attribution accumulator (shared with the
    /// proxy thread; the pool aggregates these into `PoolReport`).
    pub fn attribution(&self) -> Arc<Attribution> {
        self.attr.clone()
    }

    /// The loop's live decode-progress gossip (decoded totals +
    /// in-flight fresh tokens). A stub replica never decodes, so its
    /// gossip stays zero — exactly the truth.
    pub fn progress_gossip(&self) -> Arc<ProgressGossip> {
        self.gossip.clone()
    }

    /// Test-only replica with no engine: accepts commands, holds ADDed
    /// requests without ever decoding them, acks weight swaps, and
    /// answers RECLAIM with `fake_progress` synthetic tokens appended
    /// to the task's salvaged prefix (0 = hand back exactly what
    /// arrived). Lets the fleet's routing/salvage bookkeeping be
    /// exercised without artifacts.
    #[cfg(test)]
    pub(crate) fn spawn_stub_with_progress(fake_progress: usize) -> Self {
        Self::spawn_stub_inner(StubReclaim::Salvage(fake_progress), std::time::Duration::ZERO)
    }

    #[cfg(test)]
    pub(crate) fn spawn_stub() -> Self {
        Self::spawn_stub_with_progress(0)
    }

    /// Stub that sleeps `delay` before processing each RECLAIM —
    /// a fail-slow replica whose salvage answers arrive late. Lets
    /// tests assert the caller path never waits on them.
    #[cfg(test)]
    pub(crate) fn spawn_stub_with_reclaim_delay(
        fake_progress: usize,
        delay: std::time::Duration,
    ) -> Self {
        Self::spawn_stub_inner(StubReclaim::Salvage(fake_progress), delay)
    }

    /// Stub that *finishes* a held generation the moment a RECLAIM for
    /// it arrives: the `Done` (prefix + `finish_tokens` fakes) is
    /// emitted on the task's reply channel first, then the reclaim is
    /// answered `salvage: None` — the drain race, fabricated
    /// deterministically.
    #[cfg(test)]
    pub(crate) fn spawn_stub_finishing_on_reclaim(finish_tokens: usize) -> Self {
        Self::spawn_stub_inner(StubReclaim::FinishFirst(finish_tokens), std::time::Duration::ZERO)
    }

    /// Stub that never answers RECLAIMs at all — a wedged replica.
    /// Exercises the collector-side resolution timeout.
    #[cfg(test)]
    pub(crate) fn spawn_stub_mute() -> Self {
        Self::spawn_stub_inner(StubReclaim::Mute, std::time::Duration::ZERO)
    }

    #[cfg(test)]
    fn spawn_stub_inner(behavior: StubReclaim, reclaim_delay: std::time::Duration) -> Self {
        let (tx, rx) = channel::<Cmd>();
        let attr: Arc<Attribution> = Arc::default();
        let at = attr.clone();
        let join = std::thread::Builder::new()
            .name("llm-proxy-stub".into())
            .spawn(move || {
                // a stub never decodes, so its whole life is an idle
                // bubble; lap at the real loop's 2 ms idle cadence so
                // live attribution reads stay fresh
                let mut sw = AttrStopwatch::new(at);
                let mut held: Vec<GenRequest> = Vec::new();
                'stub: loop {
                    let cmd = match rx.recv_timeout(std::time::Duration::from_millis(2)) {
                        Ok(cmd) => cmd,
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                            sw.lap(AttrCategory::IdleBubble);
                            continue;
                        }
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    };
                    match cmd {
                        Cmd::Add(req) => held.push(req),
                        Cmd::Abort(id) => held.retain(|r| r.id != id),
                        Cmd::Reclaim { id, reply } => {
                            if !reclaim_delay.is_zero() {
                                std::thread::sleep(reclaim_delay);
                            }
                            let found = held.iter().position(|r| r.id == id);
                            match (behavior, found) {
                                (StubReclaim::Mute, _) => {}
                                (StubReclaim::Salvage(progress), Some(i)) => {
                                    let req = held.remove(i);
                                    let mut tokens = req.task.prefix;
                                    let mut logps = req.task.prefix_logps;
                                    for k in 0..progress {
                                        tokens.push(1 + k as i32);
                                        logps.push(-0.5);
                                    }
                                    let _ = reply.send(ProxyEvent::Reclaimed {
                                        id,
                                        salvage: Some(Salvage {
                                            tokens,
                                            logps,
                                            start_version: req.task.prefix_version,
                                        }),
                                    });
                                }
                                (StubReclaim::FinishFirst(extra), Some(i)) => {
                                    // the generation "finished racing
                                    // the reclaim": Done first, then
                                    // the empty answer — FIFO on the
                                    // collector's channel
                                    let req = held.remove(i);
                                    let mut tokens = req.task.prefix.clone();
                                    let mut logps = req.task.prefix_logps.clone();
                                    for k in 0..extra {
                                        tokens.push(7 + k as i32);
                                        logps.push(-0.25);
                                    }
                                    let pv = req.task.prefix_version;
                                    let _ = req.task.reply.send(ProxyEvent::Done(GenResult {
                                        id,
                                        tokens,
                                        logps,
                                        version: pv,
                                        prefix_version: pv,
                                    }));
                                    let _ =
                                        reply.send(ProxyEvent::Reclaimed { id, salvage: None });
                                }
                                (_, None) => {
                                    let _ =
                                        reply.send(ProxyEvent::Reclaimed { id, salvage: None });
                                }
                            }
                        }
                        Cmd::UpdateWeights { ack, .. } => {
                            if let Some(ack) = ack {
                                let _ = ack.send(());
                            }
                        }
                        Cmd::Suspend | Cmd::Resume => {}
                        Cmd::Shutdown => break 'stub,
                    }
                    sw.lap(AttrCategory::IdleBubble);
                }
                Ok(ProxyReport::default())
            })
            .expect("spawn llm-proxy stub");
        LlmProxy {
            client: ProxyClient { tx, next_id: Arc::new(AtomicU64::new(1)) },
            ledger: Arc::default(),
            attr,
            gossip: Arc::default(),
            join: Some(join),
        }
    }

    /// ADD: enqueue a from-scratch generation; returns (id, reply
    /// receiver). The receiver yields `ProxyEvent::Done` — unwrap with
    /// [`ProxyEvent::done`]. Convenience over [`ProxyClient::submit`].
    pub fn generate(&self, prompt: Vec<i32>, max_new_tokens: usize) -> (u64, Receiver<ProxyEvent>) {
        let (reply, rx) = channel();
        let id = self.client.submit(GenerationTask::fresh(prompt, max_new_tokens, reply));
        (id, rx)
    }

    /// ADD an explicit [`GenerationTask`] (resumed and/or greedy).
    pub fn submit(&self, task: GenerationTask) -> u64 {
        self.client.submit(task)
    }

    /// ABORT: interrupt a running/queued request (its reply channel
    /// simply never fires; the work is counted wasted).
    pub fn abort(&self, id: u64) {
        self.client.abort(id);
    }

    /// RECLAIM: interrupt and salvage (see [`ProxyClient::reclaim`]).
    pub fn reclaim(&self, id: u64) -> Receiver<ProxyEvent> {
        self.client.reclaim(id)
    }

    /// model_update broadcast: swap weights and advance the version.
    pub fn update_weights(&self, weights: Vec<f32>, version: u64) {
        self.client.update_weights(weights, version);
    }

    /// model_update with applied-acknowledgement (see [`ProxyClient`]).
    pub fn update_weights_synced(&self, weights: Vec<f32>, version: u64) -> Receiver<()> {
        self.client.update_weights_synced(weights, version)
    }

    pub fn suspend(&self) {
        self.client.suspend();
    }

    pub fn resume(&self) {
        self.client.resume();
    }

    /// Stop the loop and collect its report.
    pub fn shutdown(mut self) -> Result<ProxyReport> {
        let _ = self.client.tx.send(Cmd::Shutdown);
        match self.join.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("proxy thread panicked"))?,
            None => anyhow::bail!("already shut down"),
        }
    }
}

impl Drop for LlmProxy {
    fn drop(&mut self) {
        let _ = self.client.tx.send(Cmd::Shutdown);
        if let Some(h) = self.join.take() {
            let _ = h.join();
        }
    }
}

struct Slot {
    req: GenRequest,
    /// absolute write position in the row buffer
    pos: usize,
    /// full response so far: salvaged prefix + locally decoded tokens
    tokens: Vec<i32>,
    logps: Vec<f32>,
    /// weight version of the first response token (inherited from the
    /// task's prefix_version on resume, stamped at admission otherwise)
    start_version: u64,
    /// tokens of `tokens` that were carried in as salvage; the excess
    /// over this is fresh local decode progress (gossip accounting)
    salvaged: usize,
}

/// How many admission rounds a queued request may be passed over before
/// it jumps to the head of the order regardless of class or predicted
/// length — the starvation-proof aging bound of the two-class admission
/// ([`pick_admission`]). With a decode batch of `b`, a request is
/// admitted after at most `AGING_LIMIT` slot-fill decisions skip it.
const AGING_LIMIT: u32 = 32;

/// Two-class admission order over the replica queue (replaces plain
/// FIFO `pop_front`). Priority:
///
///   1. **aged** — any request passed over [`AGING_LIMIT`] times goes
///      first (oldest such), so no prediction pattern can starve it;
///   2. **long-work reservation** — while fewer than `long_reserve`
///      occupied slots hold long-class work and a long request is
///      queued, the oldest long request is admitted: shortest-first
///      alone would park the tail behind an endless short stream;
///   3. **shortest-predicted-first** — minimum predicted *remaining*
///      tokens (prediction minus carried salvage; unpredicted requests
///      count their full budget), ties oldest-first. With a cold
///      predictor every request scores its budget, so equal-budget
///      traffic degrades to exact FIFO — the pre-existing order.
///
/// Every request older than the admitted one gets its `passed_over`
/// clock bumped.
fn pick_admission(
    queue: &mut VecDeque<GenRequest>,
    active_long: usize,
    long_reserve: usize,
) -> Option<GenRequest> {
    if queue.is_empty() {
        return None;
    }
    let remaining = |r: &GenRequest| {
        let predicted = if r.task.predicted_len == 0 { r.task.budget } else { r.task.predicted_len };
        predicted.saturating_sub(r.task.prefix.len()).max(1)
    };
    let shortest = |q: &VecDeque<GenRequest>| {
        q.iter()
            .enumerate()
            .min_by_key(|(i, r)| (remaining(r), *i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    };
    let idx = if let Some(aged) = queue.iter().position(|r| r.passed_over >= AGING_LIMIT) {
        aged
    } else if active_long < long_reserve {
        queue.iter().position(|r| r.task.long_class).unwrap_or_else(|| shortest(queue))
    } else {
        shortest(queue)
    };
    for (i, r) in queue.iter_mut().enumerate() {
        if i < idx {
            r.passed_over += 1;
        }
    }
    queue.remove(idx)
}

/// The loop's accounting sinks, bundled so the command handlers stay
/// under a sane argument count: per-loop report, pool-shared waste
/// ledger, and the decode-progress gossip.
struct Sinks<'a> {
    report: &'a mut ProxyReport,
    ledger: &'a TokenLedger,
    gossip: &'a ProgressGossip,
}

/// ABORT shared by both command-handling sites: purge the queue AND
/// any occupied decode slot (an abort landing while suspended must not
/// leave the slot to decode on after resume). Every decoded token
/// dropped here — including the salvaged prefix a queued or resumed
/// task carried — is accounted as wasted.
fn do_abort(
    id: u64,
    queue: &mut VecDeque<GenRequest>,
    slots: &mut [Option<Slot>],
    tokens_buf: &mut [i32],
    s: usize,
    sinks: &mut Sinks<'_>,
) {
    queue.retain(|r| {
        if r.id == id {
            sinks.report.wasted_tokens += r.task.prefix.len() as u64;
            sinks.ledger.add_wasted(r.task.prefix.len() as u64);
            false
        } else {
            true
        }
    });
    for (si, slot) in slots.iter_mut().enumerate() {
        if slot.as_ref().map(|sl| sl.req.id) == Some(id) {
            let sl = slot.take().unwrap();
            sinks.report.aborted += 1;
            sinks.report.wasted_tokens += sl.tokens.len() as u64;
            sinks.ledger.add_wasted(sl.tokens.len() as u64);
            sinks.gossip.on_slot_closed(sl.tokens.len() - sl.salvaged);
            tokens_buf[si * s..(si + 1) * s].fill(0);
        }
    }
}

/// RECLAIM: like ABORT, but the decoded progress is handed back to the
/// caller for resumption instead of being dropped — the *caller*
/// decides whether to reuse or discard the salvage and accounts
/// accordingly. Unknown/finished ids are answered explicitly with
/// `salvage: None` so the caller's collector can tell "nothing left to
/// salvage" (the `Done` precedes this answer on the same channel) from
/// "replica gone" (channel disconnect). If the reply channel is
/// already closed (pool teardown), the progress is counted wasted
/// right here, so late salvage never vanishes untraced.
fn do_reclaim(
    id: u64,
    reply: Sender<ProxyEvent>,
    queue: &mut VecDeque<GenRequest>,
    slots: &mut [Option<Slot>],
    tokens_buf: &mut [i32],
    s: usize,
    sinks: &mut Sinks<'_>,
) {
    let salvage = if let Some(i) = queue.iter().position(|r| r.id == id) {
        let req = queue.remove(i).unwrap();
        Some(Salvage {
            tokens: req.task.prefix,
            logps: req.task.prefix_logps,
            start_version: req.task.prefix_version,
        })
    } else if let Some(si) =
        (0..slots.len()).find(|&si| slots[si].as_ref().map(|sl| sl.req.id) == Some(id))
    {
        let sl = slots[si].take().unwrap();
        sinks.report.reclaimed += 1;
        sinks.gossip.on_slot_closed(sl.tokens.len() - sl.salvaged);
        tokens_buf[si * s..(si + 1) * s].fill(0);
        Some(Salvage { tokens: sl.tokens, logps: sl.logps, start_version: sl.start_version })
    } else {
        None
    };
    let n = salvage.as_ref().map(|sv| sv.tokens.len() as u64).unwrap_or(0);
    if reply.send(ProxyEvent::Reclaimed { id, salvage }).is_err() && n > 0 {
        sinks.report.wasted_tokens += n;
        sinks.ledger.add_wasted(n);
    }
}

/// Deterministic argmax over one row of logits (ties: lowest index).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &l) in row.iter().enumerate() {
        if l > row[best] {
            best = i;
        }
    }
    best
}

#[allow(clippy::too_many_arguments)]
fn proxy_loop(
    dir: std::path::PathBuf,
    init_weights: Vec<f32>,
    eos: i32,
    seed: u64,
    rx: Receiver<Cmd>,
    ledger: Arc<TokenLedger>,
    attr: Arc<Attribution>,
    gossip: Arc<ProgressGossip>,
) -> Result<ProxyReport> {
    let rt = ModelRuntime::load(&dir)?;
    let (b, s, v) = (rt.manifest.decode_batch, rt.manifest.max_seq, rt.manifest.vocab);
    let mut params = rt.params_literal(&init_weights)?;
    let mut version = 0u64;
    let mut rng = Rng::new(seed ^ 0x11f);

    let mut slots: Vec<Option<Slot>> = (0..b).map(|_| None).collect();
    let mut tokens_buf = vec![0i32; b * s];
    let mut queue: VecDeque<GenRequest> = VecDeque::new();
    // commands received by the idle wait, funneled through the drain
    let mut stash: VecDeque<Cmd> = VecDeque::new();
    let mut suspended = false;
    let mut report = ProxyReport::default();
    // time-attribution: every instant of this loop's life lands in
    // exactly one category, lapped at the segment boundaries below
    let mut sw = AttrStopwatch::new(attr);

    'outer: loop {
        // --- service 3: process commands (stash + non-blocking drain) ---
        let mut swapped_weights = false;
        loop {
            let cmd = match stash.pop_front() {
                Some(c) => c,
                None => match rx.try_recv() {
                    Ok(c) => c,
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break 'outer,
                },
            };
            match cmd {
                Cmd::Add(req) => queue.push_back(req),
                Cmd::Abort(id) => do_abort(
                    id,
                    &mut queue,
                    &mut slots,
                    &mut tokens_buf,
                    s,
                    &mut Sinks { report: &mut report, ledger: &ledger, gossip: &gossip },
                ),
                Cmd::Reclaim { id, reply } => do_reclaim(
                    id,
                    reply,
                    &mut queue,
                    &mut slots,
                    &mut tokens_buf,
                    s,
                    &mut Sinks { report: &mut report, ledger: &ledger, gossip: &gossip },
                ),
                Cmd::UpdateWeights { weights, version: ver, ack } => {
                    // suspend -> broadcast -> resume, atomically w.r.t.
                    // decode steps (we are between steps here)
                    params = rt.params_literal(&weights)?;
                    version = ver;
                    swapped_weights = true;
                    if let Some(ack) = ack {
                        let _ = ack.send(());
                    }
                }
                Cmd::Suspend => suspended = true,
                Cmd::Resume => suspended = false,
                Cmd::Shutdown => break 'outer,
            }
        }
        if swapped_weights {
            // the drain segment was dominated by the parameter rebuild
            sw.lap(AttrCategory::WeightSync);
        }

        // admit queued tasks into free slots (continuous batching),
        // prefilling prompt ++ salvaged prefix. Order is the two-class
        // length-aware admission of `pick_admission`, not FIFO: a
        // quarter of the batch is reserved for long-class work, the
        // rest fills shortest-predicted-first with an aging bound.
        let mut admitted_fresh = false;
        let mut admitted_resumed = false;
        if !suspended {
            let mut active_long =
                slots.iter().flatten().filter(|sl| sl.req.task.long_class).count();
            let long_reserve = (b / 4).max(1);
            for si in 0..b {
                if slots[si].is_none() {
                    let Some(mut req) = pick_admission(&mut queue, active_long, long_reserve)
                    else {
                        break;
                    };
                    let pl = req.task.prompt.len().min(s - 1);
                    let mut tokens = std::mem::take(&mut req.task.prefix);
                    let mut logps = std::mem::take(&mut req.task.prefix_logps);
                    // clamp the salvage to the row and the budget; a
                    // truncated tail was decoded work that cannot be
                    // reused here, so it is accounted, not vanished
                    let full = tokens.len();
                    tokens.truncate((s - 1 - pl).min(req.task.budget));
                    let dropped = (full - tokens.len()) as u64;
                    if dropped > 0 {
                        report.wasted_tokens += dropped;
                        ledger.add_wasted(dropped);
                    }
                    logps.resize(tokens.len(), 0.0);
                    let start_version =
                        if tokens.is_empty() { version } else { req.task.prefix_version };
                    if tokens.len() >= req.task.budget {
                        // salvage already satisfies the budget: finish
                        // without occupying a decode slot. Zero tokens
                        // were decoded HERE, so the producing version
                        // is the prefix's — stamping the replica's
                        // current version would fabricate a piecewise
                        // (cross_version) sample out of thin air
                        report.completed += 1;
                        let _ = req.task.reply.send(ProxyEvent::Done(GenResult {
                            id: req.id,
                            tokens,
                            logps,
                            version: start_version,
                            prefix_version: start_version,
                        }));
                        continue;
                    }
                    let row = &mut tokens_buf[si * s..(si + 1) * s];
                    row.fill(0);
                    row[..pl].copy_from_slice(&req.task.prompt[..pl]);
                    row[pl..pl + tokens.len()].copy_from_slice(&tokens);
                    // re-prefill owed: the router may have placed this
                    // task on a replica whose KV cache already covers
                    // part of `prompt ++ prefix` (the pool stamped the
                    // match length at dispatch); only the uncovered
                    // delta is billed. A resumed task whose whole
                    // accumulated response is cache-covered rebuilds
                    // nothing — its admission is NOT a replay.
                    let covered = req.task.cached_prefix.min(pl + tokens.len());
                    if tokens.is_empty() || covered >= pl + tokens.len() {
                        admitted_fresh = true;
                    } else {
                        // the KV rebuild of a salvaged prefix: the
                        // migration bill, attributed separately
                        admitted_resumed = true;
                    }
                    if req.task.long_class {
                        active_long += 1;
                    }
                    slots[si] = Some(Slot {
                        pos: pl + tokens.len(),
                        salvaged: tokens.len(),
                        tokens,
                        logps,
                        start_version,
                        req,
                    });
                }
            }
        }
        if admitted_resumed {
            sw.lap(AttrCategory::PrefillReplay);
        } else if admitted_fresh {
            sw.lap(AttrCategory::Prefill);
        }

        let active = slots.iter().filter(|x| x.is_some()).count();
        if suspended || active == 0 {
            // idle: block briefly for the next command and funnel it
            // through the drain above on the next pass
            match rx.recv_timeout(std::time::Duration::from_millis(2)) {
                Ok(cmd) => stash.push_back(cmd),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
            }
            // suspended = waiting out a weight sync; otherwise the
            // paper's resource bubble: nothing to decode
            sw.lap(if suspended { AttrCategory::WeightSync } else { AttrCategory::IdleBubble });
            continue;
        }

        // --- service 1: one decode step over the whole batch ---
        let pos_vec: Vec<i32> = slots
            .iter()
            .map(|sl| sl.as_ref().map(|x| x.pos as i32).unwrap_or(1))
            .collect();
        let logits = rt.decode_step(&params, &tokens_buf, &pos_vec)?;
        report.decode_steps += 1;
        report.occupancy_sum += active as u64;

        // --- sample + service 2: post-process completions ---
        for si in 0..b {
            let Some(slot) = slots[si].as_mut() else { continue };
            let row_logits = &logits[si * v..(si + 1) * v];
            // temperature-1, top-p-1 raw sampling (paper Appendix A),
            // or argmax for greedy tasks (resume-deterministic)
            let tok = if slot.req.task.greedy {
                argmax(row_logits) as i32
            } else {
                rng.sample_logits(row_logits) as i32
            };
            // exact behavior logprob from the same logits
            let max = row_logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 =
                max + row_logits.iter().map(|&l| (l - max).exp()).sum::<f32>().ln();
            slot.logps.push(row_logits[tok as usize] - lse);
            slot.tokens.push(tok);
            tokens_buf[si * s + slot.pos] = tok;
            slot.pos += 1;
            report.tokens_generated += 1;
            gossip.on_token();

            let done = tok == eos
                || slot.tokens.len() >= slot.req.task.budget
                || slot.pos >= s;
            if done {
                let slot = slots[si].take().unwrap();
                report.completed += 1;
                gossip.on_slot_closed(slot.tokens.len() - slot.salvaged);
                let _ = slot.req.task.reply.send(ProxyEvent::Done(GenResult {
                    id: slot.req.id,
                    tokens: slot.tokens,
                    logps: slot.logps,
                    version,
                    prefix_version: slot.start_version,
                }));
                tokens_buf[si * s..(si + 1) * s].fill(0);
            }
        }
        sw.lap(AttrCategory::DecodeBusy);
    }

    // teardown: requests still held never complete — their decoded
    // tokens (including salvaged prefixes) are wasted unless a RECLAIM
    // already pulled them out above
    for slot in slots.iter_mut().filter_map(Option::take) {
        report.wasted_tokens += slot.tokens.len() as u64;
        ledger.add_wasted(slot.tokens.len() as u64);
        gossip.on_slot_closed(slot.tokens.len() - slot.salvaged);
    }
    for req in queue.drain(..) {
        report.wasted_tokens += req.task.prefix.len() as u64;
        ledger.add_wasted(req.task.prefix.len() as u64);
    }

    Ok(report)
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/integration.rs (requires
    // artifacts); unit logic tested here.
    use super::*;

    #[test]
    fn occupancy_math() {
        let r = ProxyReport { decode_steps: 10, occupancy_sum: 40, ..Default::default() };
        assert!((r.mean_occupancy(8) - 0.5).abs() < 1e-12);
        assert_eq!(ProxyReport::default().mean_occupancy(8), 0.0);
    }

    #[test]
    fn argmax_is_deterministic_on_ties() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9, 0.2]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-1.0, -1.0]), 0);
    }

    #[test]
    fn fresh_task_has_no_prefix_and_builder_sets_greedy() {
        let (tx, _rx) = channel();
        let t = GenerationTask::fresh(vec![1, 2], 8, tx).with_greedy();
        assert!(t.prefix.is_empty() && t.prefix_logps.is_empty());
        assert_eq!(t.decoded(), 0);
        assert_eq!(t.budget, 8);
        assert!(t.greedy);
    }

    #[test]
    fn cross_version_flags_piecewise_sequences() {
        let r = GenResult {
            id: 1,
            tokens: vec![5],
            logps: vec![-0.1],
            version: 3,
            prefix_version: 2,
        };
        assert!(r.cross_version());
        let r = GenResult { prefix_version: 3, ..r };
        assert!(!r.cross_version());
    }

    #[test]
    fn ledger_counts_are_live() {
        let l = TokenLedger::default();
        l.add_wasted(5);
        l.add_salvaged(3);
        l.add_wasted(2);
        l.add_prefix_hit(11);
        assert_eq!(
            l.stats(),
            TokenStats { wasted_tokens: 7, salvaged_tokens: 3, prefix_hit_tokens: 11 }
        );
    }

    #[test]
    fn abort_counts_wasted_tokens_from_queue_and_slots() {
        let ledger = TokenLedger::default();
        let mut report = ProxyReport::default();
        let (reply, _rx) = channel();
        let mut queue = VecDeque::new();
        queue.push_back(GenRequest {
            id: 1,
            task: GenerationTask {
                prefix: vec![9, 9, 9],
                prefix_logps: vec![-0.1; 3],
                ..GenerationTask::fresh(vec![1], 8, reply)
            },
            passed_over: 0,
        });
        let s = 8;
        let mut buf = vec![0i32; s];
        let (reply2, _rx2) = channel();
        let mut slots = vec![Some(Slot {
            req: GenRequest {
                id: 2,
                task: GenerationTask::fresh(vec![1], 8, reply2),
                passed_over: 0,
            },
            pos: 4,
            tokens: vec![7, 7],
            logps: vec![-0.2, -0.2],
            start_version: 0,
            salvaged: 0,
        })];
        let gossip = ProgressGossip::default();
        gossip.on_token();
        gossip.on_token(); // the 2 decoded tokens in the slot
        let mut sinks = Sinks { report: &mut report, ledger: &ledger, gossip: &gossip };
        do_abort(1, &mut queue, &mut slots, &mut buf, s, &mut sinks);
        do_abort(2, &mut queue, &mut slots, &mut buf, s, &mut sinks);
        assert_eq!(report.wasted_tokens, 5, "3 queued-prefix + 2 decoded");
        assert_eq!(ledger.stats().wasted_tokens, 5);
        assert_eq!(report.aborted, 1, "only the slotted request counts as aborted");
        assert!(queue.is_empty() && slots[0].is_none());
        assert_eq!(gossip.decoded_total(), 2, "the monotonic total survives the abort");
        assert_eq!(gossip.inflight_fresh(), 0, "aborted fresh tokens leave the gauge");
    }

    #[test]
    fn reclaim_salvages_instead_of_wasting() {
        let mut report = ProxyReport::default();
        let (reply, _rx) = channel();
        let s = 8;
        let mut buf = vec![0i32; s];
        let mut queue = VecDeque::new();
        let mut slots = vec![Some(Slot {
            req: GenRequest {
                id: 5,
                task: GenerationTask::fresh(vec![1], 8, reply),
                passed_over: 0,
            },
            pos: 5,
            tokens: vec![4, 5, 6],
            logps: vec![-0.1, -0.2, -0.3],
            start_version: 2,
            salvaged: 1,
        })];
        let ledger = TokenLedger::default();
        let gossip = ProgressGossip::default();
        gossip.on_token();
        gossip.on_token(); // 2 fresh on top of 1 salvaged
        let (stx, srx) = channel();
        do_reclaim(
            5,
            stx,
            &mut queue,
            &mut slots,
            &mut buf,
            s,
            &mut Sinks { report: &mut report, ledger: &ledger, gossip: &gossip },
        );
        assert_eq!(gossip.inflight_fresh(), 0, "reclaimed fresh tokens leave the gauge");
        let ProxyEvent::Reclaimed { id, salvage: Some(salvage) } = srx.recv().unwrap() else {
            panic!("live id must answer with salvage");
        };
        assert_eq!(id, 5);
        assert_eq!(salvage.tokens, vec![4, 5, 6]);
        assert_eq!(salvage.logps.len(), 3);
        assert_eq!(salvage.start_version, 2);
        assert_eq!(report.wasted_tokens, 0, "salvaged work is not wasted");
        assert_eq!(report.reclaimed, 1);
        assert_eq!(report.aborted, 0, "a salvage drain is not a cancellation");
        // unknown id: an explicit empty answer, not silence — the
        // caller's collector uses it to tell "already finished" from
        // "replica gone"
        let (stx, srx) = channel();
        do_reclaim(
            99,
            stx,
            &mut queue,
            &mut slots,
            &mut buf,
            s,
            &mut Sinks { report: &mut report, ledger: &ledger, gossip: &gossip },
        );
        match srx.recv().unwrap() {
            ProxyEvent::Reclaimed { id: 99, salvage: None } => {}
            other => panic!("unknown id must answer salvage: None, got {other:?}"),
        }
    }

    #[test]
    fn late_reclaim_with_dead_receiver_counts_wasted() {
        // the pool tore down (collector channel closed) before the
        // wedged loop processed the RECLAIM: the undeliverable salvage
        // must be accounted, not silently dropped
        let ledger = TokenLedger::default();
        let mut report = ProxyReport::default();
        let (reply, _rx) = channel();
        let s = 8;
        let mut buf = vec![0i32; s];
        let mut queue = VecDeque::new();
        let mut slots = vec![Some(Slot {
            req: GenRequest {
                id: 5,
                task: GenerationTask::fresh(vec![1], 8, reply),
                passed_over: 0,
            },
            pos: 5,
            tokens: vec![4, 5, 6],
            logps: vec![-0.1, -0.2, -0.3],
            start_version: 0,
            salvaged: 0,
        })];
        let gossip = ProgressGossip::default();
        let (stx, srx) = channel::<ProxyEvent>();
        drop(srx); // the collector is gone
        do_reclaim(
            5,
            stx,
            &mut queue,
            &mut slots,
            &mut buf,
            s,
            &mut Sinks { report: &mut report, ledger: &ledger, gossip: &gossip },
        );
        assert_eq!(report.wasted_tokens, 3, "undelivered salvage is wasted");
        assert_eq!(ledger.stats().wasted_tokens, 3);
        assert_eq!(report.reclaimed, 1);
    }

    /// A queued request with an explicit length prediction/class.
    fn qreq(id: u64, predicted_len: usize, long_class: bool, budget: usize) -> GenRequest {
        let (reply, _rx) = channel();
        let task = GenerationTask {
            predicted_len,
            long_class,
            ..GenerationTask::fresh(vec![1], budget, reply)
        };
        GenRequest { id, task, passed_over: 0 }
    }

    #[test]
    fn admission_is_shortest_predicted_first_and_fifo_when_cold() {
        // warm predictor: shortest predicted remaining goes first
        let mut q: VecDeque<GenRequest> =
            [qreq(1, 900, false, 1000), qreq(2, 50, false, 1000), qreq(3, 200, false, 1000)]
                .into_iter()
                .collect();
        // reservation satisfied (active_long >= reserve): pure shortest
        assert_eq!(pick_admission(&mut q, 1, 1).unwrap().id, 2);
        assert_eq!(pick_admission(&mut q, 1, 1).unwrap().id, 3);
        assert_eq!(pick_admission(&mut q, 1, 1).unwrap().id, 1);
        assert!(pick_admission(&mut q, 1, 1).is_none());
        // cold predictor (predicted_len == 0, equal budgets): exact FIFO
        let mut q: VecDeque<GenRequest> =
            [qreq(1, 0, false, 64), qreq(2, 0, false, 64), qreq(3, 0, false, 64)]
                .into_iter()
                .collect();
        for want in [1, 2, 3] {
            assert_eq!(pick_admission(&mut q, 1, 1).unwrap().id, want);
        }
        // a carried salvage prefix shortens the predicted remaining
        let mut long_but_nearly_done = qreq(7, 500, false, 1000);
        long_but_nearly_done.task.prefix = vec![9; 490]; // 10 to go
        let mut q: VecDeque<GenRequest> =
            [qreq(1, 100, false, 1000), long_but_nearly_done].into_iter().collect();
        assert_eq!(pick_admission(&mut q, 1, 1).unwrap().id, 7);
    }

    #[test]
    fn admission_reserves_slots_for_long_work() {
        let fill = || -> VecDeque<GenRequest> {
            [qreq(1, 10, false, 1000), qreq(2, 30_000, true, 50_000), qreq(3, 20, false, 1000)]
                .into_iter()
                .collect()
        };
        // no long work in the batch yet: the reservation admits the
        // long request ahead of shorter predictions
        let mut q = fill();
        assert_eq!(pick_admission(&mut q, 0, 2).unwrap().id, 2);
        // reservation full: shortest-first resumes
        let mut q = fill();
        assert_eq!(pick_admission(&mut q, 2, 2).unwrap().id, 1);
        // reservation open but nothing long queued: shortest-first
        let mut q: VecDeque<GenRequest> =
            [qreq(1, 500, false, 1000), qreq(2, 20, false, 1000)].into_iter().collect();
        assert_eq!(pick_admission(&mut q, 0, 2).unwrap().id, 2);
    }

    #[test]
    fn admission_aging_bound_is_starvation_proof() {
        // request 1 predicts huge; an endless stream of short work
        // would starve it under pure shortest-first. Count how many
        // admissions it takes before it surfaces anyway.
        let mut q: VecDeque<GenRequest> = [qreq(1, 100_000, false, 100_000)].into_iter().collect();
        let mut next_id = 2;
        let mut rounds = 0u32;
        loop {
            rounds += 1;
            assert!(rounds <= AGING_LIMIT + 1, "aging bound failed to fire");
            // keep one short competitor queued at all times
            q.push_back(qreq(next_id, 5, false, 1000));
            next_id += 1;
            if pick_admission(&mut q, 1, 1).unwrap().id == 1 {
                break;
            }
        }
        assert!(rounds > 1, "the straggler must not win while its clock is fresh");
        // the passed-over clocks of the skipped competitors carried over
        assert!(q.iter().all(|r| r.passed_over <= AGING_LIMIT));
    }
}
