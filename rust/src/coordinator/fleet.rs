//! Inference fleet (the paper's LLMProxy generalized to a *pool* of
//! replicas): N `LlmProxy` engines behind one resumable-task
//! interface, with an **elastic replica lifecycle**.
//!
//! The single-proxy coordinator cannot reproduce the Figure 1b scaling
//! story — rollout throughput is capped by one decode loop. The pool
//! adds the load-bearing mechanisms of replica-level serving:
//!
//!   1. *Load-balanced placement*: each [`GenerationTask`] is routed by
//!      a pluggable [`RoutePolicy`] (round-robin, least-outstanding,
//!      queue scheduling with pool-side backpressure, EWMA, or
//!      length-aware tail packing — see `routing.rs`). A per-replica
//!      completion collector feeds finished generations back to the
//!      caller, updates the shared [`LengthPredictor`] with each
//!      completion's true length, and re-dispatches pool-queued work
//!      as decode slots free up.
//!   2. *Staggered (rolling) weight sync*: `update_weights` walks the
//!      replicas one at a time, waiting for each to acknowledge the
//!      swap before moving on, so at most one replica is paused while
//!      the other N-1 keep decoding. While the pool is suspended
//!      (synchronous mode) the swap is instead broadcast inline so it
//!      stays ordered before the controller's `resume` on every
//!      replica's command channel — sync mode remains strictly
//!      on-policy.
//!   3. *Prefix-salvaging migration* (`partial_migration`, the
//!      fail-slow story of Section 5.2.2), now **fully asynchronous**:
//!      `migrate`/`retire_replica`/`kill_replica` park the in-flight
//!      entry in a *PendingSalvage* table and return immediately — no
//!      caller-side salvage wait. The RECLAIM answer rides the
//!      replica's own completion channel, so the per-replica collector
//!      resolves each parked entry exactly once: either a [`Salvage`]
//!      arrives (the task re-dispatches to a survivor, resumed from
//!      its decoded prefix) or the generation's own `Done` beats it
//!      (the finished result is delivered to the caller with zero
//!      re-decode — the drain race is closed by channel FIFO order,
//!      not by timing). When every peer's decode window is full, the
//!      hang watchdog's migrate degrades to *ReclaimInPlace*
//!      (`reclaim_in_place`): the hung generation is salvaged and
//!      re-enters pool admission instead of piling onto a saturated
//!      survivor. Salvages shorter than `min_salvage_tokens` (or any
//!      salvage when the knob is off) are discarded and counted as
//!      `wasted_tokens`; reused prefixes count as `salvaged_tokens` in
//!      the pool-shared [`TokenLedger`].
//!   4. *Elastic lifecycle* (`spawn → serving → draining → retired`,
//!      driven by `coordinator/autoscaler.rs`): [`add_replica`]
//!      spawns a fresh proxy loop at the pool's current weight
//!      version, registers its collector and histograms, and opens it
//!      to routing — reusing a retired slot when one exists;
//!      [`retire_replica`] marks the slot *draining* (the `Router`
//!      stops selecting it immediately), parks its in-flight
//!      generations for asynchronous salvage, orders the loop to stop,
//!      and returns — the slot's own collector absorbs the salvage
//!      answers, re-dispatches the work to survivors as resumed tasks,
//!      joins the loop once its channel disconnects, and archives the
//!      occupant's [`ReplicaReport`] (phase → retired). `retire_idlest`
//!      is salvage-cost-aware: among equally idle replicas it drains
//!      the one whose in-flight work carries the fewest
//!      already-salvaged prefix tokens (the caller-side estimate of
//!      the KV replay bill). Slot state is generation-counted: a
//!      reused slot bumps its generation, resets its histograms/routed
//!      counts, and clears the router's EWMA estimate
//!      (`Router::reset_replica`), so a fresh occupant never inherits
//!      its predecessor's statistics.
//!
//! [`add_replica`]: LlmProxyPool::add_replica
//! [`retire_replica`]: LlmProxyPool::retire_replica
//!
//! Fail-*stop* replicas are handled on two paths: `kill_replica`
//! drains salvage from the doomed loop and immediately re-dispatches
//! its in-flight work to survivors (resumed when salvage succeeded),
//! and a replica whose event loop is simply gone is detected at submit
//! time — the request fails over to a surviving replica with its
//! salvaged prefix intact, and when no serving replica remains it is
//! dropped so the caller observes disconnection instead of hanging
//! forever.
//!
//! Per-replica queue-depth and utilization are recorded into
//! [`metrics::Histogram`]s and returned in the [`PoolReport`]; the
//! pool-queue depth is additionally recorded into a *windowed*
//! histogram (`Histogram::reset`) that the autoscaler reads once per
//! interval.
//!
//! **Observability**: the pool carries a [`FlightRecorder`]
//! (`PoolCfg::trace`) that records every request's lifecycle — submit
//! → queue-wait → route → prefill → decode → {park / salvage /
//! re-dispatch / abort} → done — into per-replica rings, a central
//! [`MetricsRegistry`] of named counters (`metrics()`), and per-slot
//! [`Attribution`] accumulators classifying every replica-second
//! (`attribution()`, rolled into [`ReplicaReport`]/[`PoolReport`]).
//! When `trace.export_path` is set, `shutdown` writes `trace.json`
//! (Chrome `trace_event`, openable in `chrome://tracing`/Perfetto),
//! `trace.jsonl`, and `metrics.{txt,csv}` into that directory.

use std::collections::{HashMap, VecDeque};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::autoscaler::PoolSignals;
use crate::coordinator::kv_index::{KvCacheCfg, KvPrefixIndex};
use crate::coordinator::length_predictor::{LengthPredictor, PredictorCfg};
use crate::coordinator::llm_proxy::{
    GenResult, GenerationTask, LlmProxy, ProgressGossip, ProxyClient, ProxyEvent, ProxyReport,
    Salvage, TokenLedger, TokenStats,
};
use crate::coordinator::routing::{ReplicaLoad, RouteHint, RoutePolicy, Router};
use crate::metrics::registry::{Counter, HistogramHandle, MetricsRegistry};
use crate::metrics::telemetry::TelemetrySignals;
use crate::metrics::trace::{
    AttrSnapshot, Attribution, EventPhase, FlightRecorder, TraceCfg,
};
use crate::metrics::{Histogram, Table};

/// Spawns a replica for `(slot, generation)` — the hook that makes
/// `add_replica` possible after the pool's construction arguments are
/// gone. `LlmProxyPool::spawn` installs one that builds a real proxy
/// loop at the pool's latest weight snapshot; tests install stub
/// spawners.
type ReplicaSpawner = Box<dyn Fn(usize, u64) -> LlmProxy + Send + Sync>;

/// Fleet shape and behavior knobs (`num_replicas`, `route_policy`,
/// `rolling_update`, `partial_migration`, `min_salvage_tokens` in
/// YAML / CLI).
#[derive(Clone, Debug)]
pub struct PoolCfg {
    pub num_replicas: usize,
    pub route_policy: RoutePolicy,
    /// staggered weight sync: replicas swap one at a time (>= N-1 keep
    /// decoding); false = broadcast to all replicas at once
    pub rolling_update: bool,
    /// decode slots per replica (the manifest's `decode_batch`) —
    /// the admission cap the queue-scheduling policy routes against
    pub replica_slots: usize,
    /// carry the decoded prefix across migration / dead-replica
    /// resubmission; false = the old abort-and-resubmit-from-scratch
    /// behavior (decoded tokens are burned, but now counted)
    pub partial_migration: bool,
    /// shortest salvage worth resuming; shorter prefixes are dropped
    /// (and counted wasted) rather than carried
    pub min_salvage_tokens: usize,
    /// seconds a parked salvage may wait for its replica's RECLAIM
    /// answer before the *collector* gives up and re-dispatches the
    /// task from its last salvaged prefix. This is the collector-side
    /// resolution timeout that replaced the old caller-side
    /// SALVAGE_WAIT: it bounds how long a wedged replica can hold a
    /// PendingSalvage entry, never how long `migrate`/`retire_replica`
    /// take (those return immediately). A wedged loop's late answer is
    /// counted wasted when it finally arrives.
    pub salvage_timeout: f64,
    /// when a hung generation has nowhere to move (every peer's decode
    /// window is full), RECLAIM it in place: salvage the prefix and
    /// re-enter pool admission — pause/rebalance without reserving a
    /// saturated survivor. false = a saturated migrate is refused and
    /// the watchdog simply re-fires later.
    pub reclaim_in_place: bool,
    /// flight-recorder knobs (`trace: {enabled, ring_capacity,
    /// export_path}` in YAML / CLI); disabled costs one branch per
    /// would-be event
    pub trace: TraceCfg,
    /// generation-length predictor shape (`length_predictor: {…}` in
    /// YAML / CLI) — feeds TailAware routing, two-class proxy
    /// admission, and the autoscaler's adaptive target
    pub predictor: PredictorCfg,
    /// KV-prefix index + cache-aware routing (`kv_cache: {…}` in
    /// YAML / CLI): track which token prefixes are KV-resident per
    /// serving replica and prefer placements where resume is free.
    /// Disabled = legacy placement, byte for byte.
    pub kv_cache: KvCacheCfg,
}

impl PoolCfg {
    pub fn single(replica_slots: usize) -> Self {
        PoolCfg {
            num_replicas: 1,
            route_policy: RoutePolicy::default(),
            rolling_update: true,
            replica_slots,
            partial_migration: true,
            min_salvage_tokens: 1,
            salvage_timeout: 0.5,
            reclaim_in_place: true,
            trace: TraceCfg::disabled(),
            predictor: PredictorCfg::default(),
            kv_cache: KvCacheCfg::disabled(),
        }
    }
}

/// Where a replica slot is in its lifecycle. Only `Serving` slots are
/// routable; `Draining` is the transient phase inside `retire_replica`
/// (in-flight work being salvaged out); `Dead` slots crashed and keep
/// their weight-version lag visible; `Retired` slots drained cleanly
/// and are reusable by `add_replica`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    Serving,
    Draining,
    Dead,
    Retired,
}

/// A request held pool-side (queue scheduling backpressure, or every
/// replica suspended). The task keeps its salvaged prefix while it
/// waits.
struct Pending {
    pool_id: u64,
    task: GenerationTask,
    /// placement preference, not a hard constraint: a task salvaged
    /// off a (presumed hung) replica records it here so the drain
    /// tries every other replica first — being stuck behind a hung
    /// replica is strictly worse than a deep healthy queue — and only
    /// returns to the source when nothing else is routable.
    avoid: Option<usize>,
}

/// A request dispatched to a replica. The task (prompt + current
/// salvaged prefix) is retained so migration and dead-replica
/// resubmission can move it with the same reply channel.
struct InFlight {
    replica: usize,
    inner_id: u64,
    task: GenerationTask,
    migrations: u32,
    /// dispatch wall time — feeds the router's EWMA token-rate estimate
    dispatched: Instant,
    /// predicted remaining tokens at dispatch — the per-replica
    /// predicted-remaining load score sums these (minus the gossiped
    /// decode progress since)
    predicted: f64,
}

/// Where a parked task goes once its RECLAIM resolves with a salvage
/// (or times out).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SalvageDest {
    /// migration/drain: re-dispatch to a survivor, avoiding the source
    Migrate,
    /// ReclaimInPlace: re-enter pool-side admission (pause/rebalance)
    /// — chosen when every peer's decode window is already full
    Requeue,
}

/// An [`InFlight`] entry parked in the *PendingSalvage* table: its
/// RECLAIM is in flight on the replica's completion channel and the
/// replica's collector owns the resolution. The entry KEEPS its
/// `by_inner` registration, so a completion racing the reclaim
/// resolves the parked entry — delivered to the caller exactly once,
/// with zero re-decode — instead of being dropped as stale (the old
/// drain race, closed by construction).
struct Parked {
    replica: usize,
    inner_id: u64,
    task: GenerationTask,
    migrations: u32,
    /// original dispatch time (feeds the EWMA when the race resolves
    /// as a completion)
    dispatched: Instant,
    /// when the collector stops waiting for the replica's answer and
    /// re-dispatches from the last salvaged prefix
    deadline: Instant,
    dest: SalvageDest,
    /// predicted remaining tokens, carried from the in-flight entry
    predicted: f64,
}

/// How a parked salvage resolved. Exactly one of these reaches
/// `Shared::resolve_parked` per parked entry (late answers for
/// already-resolved ids are counted wasted and dropped).
enum Resolution {
    /// the generation finished inside the reclaim window (drain race)
    Completed(GenResult),
    /// the replica handed back its decoded progress
    Salvaged(Salvage),
    /// the replica is gone or ran out `salvage_timeout`
    Lost,
}

fn depth_hist() -> Histogram {
    Histogram::new(1.0, 1.25)
}

fn util_hist() -> Histogram {
    Histogram::new(0.01, 1.25)
}

fn latency_hist() -> Histogram {
    Histogram::new(1e-3, 1.25)
}

struct PoolState {
    router: Router,
    /// per-slot command handles (index = slot; grows with the fleet)
    clients: Vec<ProxyClient>,
    /// per-slot lifecycle phase
    phase: Vec<Phase>,
    /// per-slot occupant generation: bumped every time a retired slot
    /// is reused, so statistics never leak across occupants
    generation: Vec<u64>,
    /// pool-side FIFO of requests awaiting a routable replica
    queue: VecDeque<Pending>,
    /// pool request id -> live request
    inflight: HashMap<u64, InFlight>,
    /// PendingSalvage: pool id -> entry parked for asynchronous
    /// RECLAIM, resolved exactly once by its replica's collector
    parked: HashMap<u64, Parked>,
    /// tombstones for parked entries killed by `abort`: (replica,
    /// inner id) -> prefix tokens already counted wasted at the abort.
    /// The in-flight RECLAIM answer, if it ever arrives, then bills
    /// only the *new* progress — and a wedged replica that never
    /// answers leaks nothing, because the prefix was billed up front.
    aborted_parked: HashMap<(usize, u64), usize>,
    /// per replica: inner (proxy) id -> pool id. Live AND parked
    /// requests are registered; a completion whose inner id is absent
    /// here was aborted — dropped as stale.
    by_inner: Vec<HashMap<u64, u64>>,
    outstanding: Vec<usize>,
    /// pool-wide suspend (sync mode): requests pool-queue until resume
    pool_suspended: bool,
    /// replica currently applying a rolling weight swap, if any
    syncing: Option<usize>,
    /// last weight version each replica acknowledged — rolling-sync
    /// skew is max - min of this vector (retired slots excluded)
    replica_version: Vec<u64>,
    routed: Vec<u64>,
    migrated: u64,
    /// hung generations RECLAIMed in place (salvaged + re-queued
    /// instead of moved) because every peer's window was full
    reclaimed_in_place: u64,
    /// migrations/resubmissions that carried a salvaged prefix
    resumed: u64,
    /// rolling-broadcast waves completed by the sync agent
    sync_waves: u64,
    /// replicas added after construction (autoscaler grow actions)
    grown: u64,
    /// decode slots per replica (routing admission cap)
    slots: usize,
    /// per-replica outstanding at dispatch time
    depth: Vec<Histogram>,
    /// per-replica occupancy fraction (outstanding/slots) at dispatch
    util: Vec<Histogram>,
    /// pool-queue length at submit since the autoscaler's last read
    /// (reset every interval — the per-interval percentile feed).
    /// The *lifetime* pool-queue histogram lives in the metrics
    /// registry (`pool.queue_depth`), not here.
    queue_window: Histogram,
    /// per-slot time-attribution of the current occupant's proxy loop
    /// (shared `Arc` with the loop); reset to a fresh accumulator when
    /// the slot's report is archived so occupants never blend
    attr: Vec<Arc<Attribution>>,
    /// per-slot progress gossip shared with the occupant's decode loop:
    /// monotonic decoded totals + live fresh-token gauge, the
    /// caller-side view of decode progress that RECLAIM answers would
    /// otherwise be the only source of
    gossip: Vec<Arc<ProgressGossip>>,
    /// episode-completion latency (dispatch → Done) since the last
    /// StepLog read; reset on every read, like `queue_window`
    lat_window: Histogram,
    /// when the slot's current occupant left service and began
    /// draining (pool-side half of the `Draining` attribution bucket)
    drain_start: Vec<Option<Instant>>,
    /// master clones of the per-replica collector channels; taken at
    /// shutdown/retirement so the collectors can observe disconnection
    completion_tx: Vec<Option<Sender<ProxyEvent>>>,
    /// pool-level KV-prefix index: which token prefixes are resident
    /// per serving replica (inserted on completion/salvage, invalidated
    /// on kill/retire/slot-reuse/weight-sync, LRU under the per-replica
    /// byte budget). Lives under the state lock like the router.
    kv: KvPrefixIndex,
    /// dispatches whose target already held part of the task's prefix
    kv_hits: u64,
    /// kv-enabled dispatches that found no cached prefix anywhere
    kv_misses: u64,
    /// prompt/prefix tokens found KV-resident at dispatch (re-prefill
    /// avoided)
    kv_hit_tokens: u64,
    /// when the slot's current occupant started serving
    serve_start: Vec<Option<Instant>>,
    /// serving seconds already banked for the current occupant (killed
    /// replicas stop accruing at the kill)
    served: Vec<f64>,
    /// archived reports of occupants drained out by `retire_replica`
    retired: Vec<ReplicaReport>,
}

impl PoolState {
    fn loads(&self) -> Vec<ReplicaLoad> {
        (0..self.outstanding.len())
            .map(|r| ReplicaLoad {
                outstanding: self.outstanding[r],
                slots: self.slots,
                suspended: self.pool_suspended
                    || self.phase[r] != Phase::Serving
                    || self.syncing == Some(r),
                predicted_remaining: self.predicted_remaining(r),
            })
            .collect()
    }

    /// Predicted tokens replica `r` still owes: the sum of its
    /// in-flight (and parked) predictions minus the fresh decode
    /// progress its loop has gossiped since dispatch, floored at one
    /// token per outstanding request. This is TailAware's load score —
    /// a replica holding one 10k-token straggler is "fuller" than one
    /// holding four 100-token rollouts.
    fn predicted_remaining(&self, r: usize) -> f64 {
        let predicted: f64 = self
            .inflight
            .values()
            .filter(|e| e.replica == r)
            .map(|e| e.predicted)
            .chain(self.parked.values().filter(|p| p.replica == r).map(|p| p.predicted))
            .sum();
        let decoded = self.gossip.get(r).map(|g| g.inflight_fresh() as f64).unwrap_or(0.0);
        (predicted - decoded).max(self.outstanding[r] as f64)
    }

    /// No slot can ever serve a request again (every occupant dead or
    /// retired): queued work is dropped so callers observe
    /// disconnection instead of waiting forever.
    fn none_serviceable(&self) -> bool {
        !self.phase.iter().any(|&p| p == Phase::Serving)
    }

    fn serving(&self) -> usize {
        self.phase.iter().filter(|&&p| p == Phase::Serving).count()
    }

    /// Bank the current occupant's serving time (kill/retire/shutdown).
    fn close_serve_clock(&mut self, r: usize) -> f64 {
        if let Some(t) = self.serve_start[r].take() {
            self.served[r] += t.elapsed().as_secs_f64();
        }
        self.served[r]
    }

    /// Caller-side estimate of how expensive replica `r` would be to
    /// drain: the already-salvaged prefix tokens its in-flight (and
    /// parked) work carries. Fresh decode on the replica is invisible
    /// until a RECLAIM answers, so the carried prefix length is the
    /// best static proxy for the KV replay bill a retire would incur.
    fn salvage_cost(&self, r: usize) -> usize {
        self.inflight
            .values()
            .filter(|e| e.replica == r)
            .map(|e| e.task.prefix.len())
            .chain(self.parked.values().filter(|p| p.replica == r).map(|p| p.task.prefix.len()))
            .sum()
    }
}

/// Pre-registered handles into the pool's [`MetricsRegistry`]: the
/// hot paths bump these lock-free cells and never touch the registry
/// lock again after construction.
struct FleetMetrics {
    registry: Arc<MetricsRegistry>,
    submitted: Counter,
    completed: Counter,
    migrated: Counter,
    reclaimed_in_place: Counter,
    /// parked salvages whose replica never answered inside
    /// `salvage_timeout` (the collectors' deadline sweeps)
    expired: Counter,
    grown: Counter,
    retired: Counter,
    /// KV-prefix index outcomes at dispatch (cache-aware routing)
    kv_hits: Counter,
    kv_misses: Counter,
    kv_hit_tokens: Counter,
    kv_evictions: Counter,
    /// pool-queue length at submit (lifetime) — the registry-owned
    /// replacement for the old ad-hoc `PoolState.queue_depth` field
    pool_queue_depth: HistogramHandle,
    /// dispatch → Done wall seconds per episode (lifetime) — the
    /// tail-latency scoreboard `fig_tail_latency` reads
    completion_latency: HistogramHandle,
}

impl FleetMetrics {
    fn new() -> Self {
        let registry = Arc::new(MetricsRegistry::new());
        FleetMetrics {
            submitted: registry.counter("pool.submitted"),
            completed: registry.counter("pool.completed"),
            migrated: registry.counter("pool.migrated"),
            reclaimed_in_place: registry.counter("pool.reclaimed_in_place"),
            expired: registry.counter("pool.salvage_expired"),
            grown: registry.counter("pool.grown"),
            retired: registry.counter("pool.retired"),
            kv_hits: registry.counter("pool.kv_hits"),
            kv_misses: registry.counter("pool.kv_misses"),
            kv_hit_tokens: registry.counter("pool.kv_hit_tokens"),
            kv_evictions: registry.counter("pool.kv_evictions"),
            pool_queue_depth: registry.histogram("pool.queue_depth", 1.0, 1.25),
            completion_latency: registry.histogram("pool.completion_latency", 1e-3, 1.25),
            registry,
        }
    }
}

/// State shared between callers, collectors, and the sync agent.
struct Shared {
    state: Mutex<PoolState>,
    /// live wasted/salvaged token counters, shared with every replica
    ledger: Arc<TokenLedger>,
    partial_migration: bool,
    min_salvage_tokens: usize,
    /// collector-side resolution timeout for parked salvages
    salvage_timeout: Duration,
    /// saturated migrations salvage-and-requeue instead of refusing
    reclaim_in_place: bool,
    /// live count of PendingSalvage entries — the lock-free gate that
    /// lets collectors block indefinitely when nothing is parked
    parked_count: AtomicUsize,
    /// proxy handles of retiring slots; the slot's collector joins the
    /// loop and archives the report once its channel disconnects.
    /// Lock order: retiring may be taken before state, never after.
    retiring: Mutex<HashMap<usize, LlmProxy>>,
    /// lifecycle tracing (a disabled recorder is one branch per event)
    recorder: Arc<FlightRecorder>,
    /// named counters/histograms, snapshot-and-reset by reporters
    metrics: FleetMetrics,
    /// routing policy, echoed into `route` trace events
    route_policy: RoutePolicy,
    /// shared generation-length predictor: fed by the collectors on
    /// every completion, read by routing / admission stamps / the
    /// autoscaler signals
    predictor: Arc<LengthPredictor>,
}

impl Shared {
    /// Pool-level (ring 0) trace event for request `req`.
    fn ev_pool(&self, name: &'static str, phase: EventPhase, req: u64, detail: String) {
        self.recorder.emit(name, phase, req, None, 0, 0, detail);
    }

    /// Replica-level trace event stamped with the slot's current
    /// generation and acknowledged weight version. Caller holds the
    /// state lock.
    fn ev_replica(
        &self,
        st: &PoolState,
        name: &'static str,
        phase: EventPhase,
        req: u64,
        r: usize,
        detail: String,
    ) {
        self.recorder.emit(name, phase, req, Some(r), st.generation[r], st.replica_version[r], detail);
    }

    /// A request enters the pool queue: open its `queue` span.
    /// Every `st.queue.push_back` site pairs with a `trace_queue_end`
    /// at the pop/drop site, so the span invariant holds: a request
    /// has an open `queue` span iff it sits in `st.queue`.
    fn trace_queue_begin(&self, req: u64) {
        self.ev_pool("queue", EventPhase::Begin, req, String::new());
    }

    fn trace_queue_end(&self, req: u64) {
        self.ev_pool("queue", EventPhase::End, req, String::new());
    }

    /// Length-scheduling hint for routing `task`: predicted remaining
    /// tokens (budget-clamped, prefix-discounted) plus the long/short
    /// class, and — with the KV-prefix index on — the per-replica
    /// cached-prefix match over `prompt ++ prefix` that drives the
    /// router's cache-aware override. Only `TailAware` consumes the
    /// length fields; an empty `cached` leaves every policy's decision
    /// byte-identical to the legacy path.
    fn hint_for(&self, st: &PoolState, task: &GenerationTask) -> Option<RouteHint> {
        let predicted = self.predictor.predict_for(task.group, task.budget);
        let cached = if st.kv.enabled() {
            let mut key = task.prompt.clone();
            key.extend_from_slice(&task.prefix);
            let per: Vec<usize> = (0..st.phase.len())
                .map(|r| {
                    if st.phase[r] == Phase::Serving { st.kv.lookup(r, &key) } else { 0 }
                })
                .collect();
            if per.iter().all(|&c| c == 0) { Vec::new() } else { per }
        } else {
            Vec::new()
        };
        Some(RouteHint {
            predicted_len: predicted.saturating_sub(task.prefix.len()).max(1) as f64,
            long: self.predictor.classify(predicted as f64),
            cached,
        })
    }

    /// Dispatch a request to replica `r`; caller holds the state lock.
    /// A submit failure means the replica's event loop is gone — the
    /// replica is marked dead and the request fails over *with its
    /// salvaged prefix intact*: re-routed if a replica is available
    /// now, re-queued while any serve, and dropped (disconnecting the
    /// caller's reply channel) once no serving replica remains.
    fn dispatch(&self, st: &mut PoolState, r: usize, req: Pending, migrations: u32) {
        let mut r = r;
        let mut req = req;
        // stamp the length-scheduling hints at (re)dispatch time: the
        // prediction is re-derived on every hop so a salvaged prefix
        // shrinks the remaining estimate, and the budget clamp
        // guarantees the stamp never exceeds what the row can hold
        let predicted = self.predictor.predict_for(req.task.group, req.task.budget);
        req.task.predicted_len = predicted;
        req.task.long_class = self.predictor.classify(predicted as f64);
        let remaining = predicted.saturating_sub(req.task.prefix.len()).max(1);
        // the KV-index key is the exact token stream the replica will
        // prefill: prompt plus any salvaged/episode prefix
        let kv_key: Option<Vec<i32>> = if st.kv.enabled() {
            let mut k = req.task.prompt.clone();
            k.extend_from_slice(&req.task.prefix);
            Some(k)
        } else {
            None
        };
        loop {
            let Some(tx) = st.completion_tx[r].as_ref().cloned() else {
                // no collector channel. A *retired or draining* slot
                // means the target drained out between selection and
                // this dispatch — fail over exactly like a dead
                // replica; such slots are suspended in `loads`, so the
                // router cannot hand them back. A serving slot with no
                // channel means the pool is tearing down: drop the
                // request — counting its carried prefix — so the
                // caller observes disconnection
                if matches!(st.phase[r], Phase::Retired | Phase::Draining) {
                    let loads = st.loads();
                    let hint = self.hint_for(st, &req.task);
                    match st.router.route_excluding_hinted(&loads, Some(r), hint) {
                        Some(next) => {
                            r = next;
                            continue;
                        }
                        None if st.none_serviceable() => {
                            self.ledger.add_wasted(req.task.prefix.len() as u64);
                            self.ev_pool("lost", EventPhase::Instant, req.pool_id, String::new());
                            return;
                        }
                        None => {
                            self.trace_queue_begin(req.pool_id);
                            st.queue.push_back(req);
                            return;
                        }
                    }
                }
                self.ledger.add_wasted(req.task.prefix.len() as u64);
                self.ev_pool("lost", EventPhase::Instant, req.pool_id, String::new());
                return;
            };
            let cached = kv_key.as_ref().map_or(0, |k| st.kv.lookup(r, k));
            let replica_task = GenerationTask {
                prompt: req.task.prompt.clone(),
                prefix: req.task.prefix.clone(),
                prefix_logps: req.task.prefix_logps.clone(),
                prefix_version: req.task.prefix_version,
                budget: req.task.budget,
                greedy: req.task.greedy,
                group: req.task.group,
                predicted_len: req.task.predicted_len,
                long_class: req.task.long_class,
                conversation: req.task.conversation,
                cached_prefix: cached,
                reply: tx,
            };
            match st.clients[r].try_submit(replica_task) {
                Some(inner_id) => {
                    st.depth[r].record(st.outstanding[r] as f64);
                    st.by_inner[r].insert(inner_id, req.pool_id);
                    st.outstanding[r] += 1;
                    st.routed[r] += 1;
                    st.util[r].record(st.outstanding[r].min(st.slots) as f64 / st.slots as f64);
                    if !req.task.prefix.is_empty() {
                        st.resumed += 1;
                    }
                    if let Some(k) = kv_key.as_ref() {
                        if cached > 0 {
                            st.kv_hits += 1;
                            st.kv_hit_tokens += cached as u64;
                            self.metrics.kv_hits.inc();
                            self.metrics.kv_hit_tokens.add(cached as u64);
                            self.ledger.add_prefix_hit(cached as u64);
                            st.kv.touch(r, k);
                            if self.recorder.is_enabled() {
                                self.ev_replica(
                                    st,
                                    "kv_hit",
                                    EventPhase::Instant,
                                    req.pool_id,
                                    r,
                                    format!("cached={cached}"),
                                );
                            }
                        } else {
                            st.kv_misses += 1;
                            self.metrics.kv_misses.inc();
                            if self.recorder.is_enabled() {
                                self.ev_replica(
                                    st,
                                    "kv_miss",
                                    EventPhase::Instant,
                                    req.pool_id,
                                    r,
                                    String::new(),
                                );
                            }
                        }
                    }
                    if self.recorder.is_enabled() {
                        let policy = self.route_policy;
                        self.ev_replica(
                            st,
                            "route",
                            EventPhase::Instant,
                            req.pool_id,
                            r,
                            format!("replica={r} policy={policy:?}"),
                        );
                        self.ev_replica(
                            st,
                            "prefill",
                            EventPhase::Instant,
                            req.pool_id,
                            r,
                            format!("prefix={}", req.task.prefix.len()),
                        );
                        self.ev_replica(
                            st,
                            "decode",
                            EventPhase::Begin,
                            req.pool_id,
                            r,
                            format!("migrations={migrations}"),
                        );
                    }
                    st.inflight.insert(
                        req.pool_id,
                        InFlight {
                            replica: r,
                            inner_id,
                            task: req.task,
                            migrations,
                            dispatched: Instant::now(),
                            predicted: remaining as f64,
                        },
                    );
                    return;
                }
                None => {
                    st.phase[r] = Phase::Dead;
                    st.kv.invalidate_replica(r);
                    st.close_serve_clock(r);
                    let loads = st.loads();
                    let hint = self.hint_for(st, &req.task);
                    match st.router.route_excluding_hinted(&loads, Some(r), hint) {
                        Some(next) => r = next,
                        None if st.none_serviceable() => {
                            // drop: caller disconnects; the salvaged
                            // prefix dies with the fleet
                            self.ledger.add_wasted(req.task.prefix.len() as u64);
                            self.ev_pool("lost", EventPhase::Instant, req.pool_id, String::new());
                            return;
                        }
                        None => {
                            self.trace_queue_begin(req.pool_id);
                            st.queue.push_back(req);
                            return;
                        }
                    }
                }
            }
        }
    }

    /// Move pool-queued requests onto replicas while the router
    /// allows. A request's `avoid` preference is honored first and
    /// relaxed only when no other replica is routable — a salvaged
    /// task returns to its hung source replica as a last resort, never
    /// as the first pick.
    fn drain(&self, st: &mut PoolState) {
        if st.none_serviceable() {
            // drop: callers observe disconnection; carried prefixes are
            // decoded work that now dies uncollected — count it
            for p in st.queue.drain(..) {
                self.ledger.add_wasted(p.task.prefix.len() as u64);
                self.trace_queue_end(p.pool_id);
                self.ev_pool("lost", EventPhase::Instant, p.pool_id, String::new());
            }
            return;
        }
        while !st.queue.is_empty() {
            let loads = st.loads();
            let front = st.queue.front().unwrap();
            let mut avoid = front.avoid;
            let hint = self.hint_for(st, &front.task);
            // `avoid` is a soft preference (the salvage source may be
            // slow, not dead). When that same replica holds the best
            // cached prefix for the task, going back is the cheaper
            // resume — drop the avoidance and let the cache-aware
            // override send it home.
            if let (Some(a), Some(h)) = (avoid, hint.as_ref()) {
                if !h.cached.is_empty() {
                    let at_avoid = h.cached.get(a).copied().unwrap_or(0);
                    if at_avoid > 0 && h.cached.iter().all(|&c| c <= at_avoid) {
                        avoid = None;
                    }
                }
            }
            let picked = match st.router.route_excluding_hinted(&loads, avoid, hint.clone()) {
                Some(r) => Some(r),
                // the avoided replica is the only routable one: better
                // there than starving in the queue
                None if avoid.is_some() => st.router.route_hinted(&loads, hint),
                None => None,
            };
            let Some(r) = picked else { break };
            let p = st.queue.pop_front().unwrap();
            self.trace_queue_end(p.pool_id);
            self.dispatch(st, r, p, 0);
        }
    }

    /// Record that replica `r` now holds KV state covering
    /// `prompt ++ tokens` (a completion it just decoded, or a salvage
    /// it produced). No-op while the index is disabled or the slot is
    /// not serving. Evictions forced by the insert are counted and
    /// traced. Caller holds the state lock.
    fn kv_insert_done(
        &self,
        st: &mut PoolState,
        r: usize,
        prompt: &[i32],
        tokens: &[i32],
        req: u64,
    ) {
        if !st.kv.enabled() || st.phase[r] != Phase::Serving {
            return;
        }
        let mut key = prompt.to_vec();
        key.extend_from_slice(tokens);
        let before = st.kv.stats().evictions;
        st.kv.insert(r, &key);
        let evicted = st.kv.stats().evictions - before;
        if evicted > 0 {
            self.metrics.kv_evictions.add(evicted);
            if self.recorder.is_enabled() {
                self.ev_replica(
                    st,
                    "kv_evict",
                    EventPhase::Instant,
                    req,
                    r,
                    format!("blocks={evicted}"),
                );
            }
        }
    }

    /// Fold a RECLAIM answer into the task ahead of resubmission.
    /// With `partial_migration` on and the salvage at or above the
    /// floor, the decoded tokens become the task's resume prefix
    /// (counted `salvaged`); otherwise the newly decoded progress is
    /// burned (counted `wasted`), and with the knob off the task is
    /// reset to a bare from-scratch prompt.
    fn absorb_salvage(&self, task: &mut GenerationTask, s: Salvage) {
        let old = task.prefix.len();
        if self.partial_migration
            && s.tokens.len() >= self.min_salvage_tokens
            && s.tokens.len() >= old
        {
            self.ledger.add_salvaged((s.tokens.len() - old) as u64);
            task.prefix = s.tokens;
            task.prefix_logps = s.logps;
            task.prefix_version = s.start_version;
        } else {
            let carried = if self.partial_migration { old } else { 0 };
            self.ledger.add_wasted(s.tokens.len().saturating_sub(carried) as u64);
            if !self.partial_migration {
                task.prefix.clear();
                task.prefix_logps.clear();
            }
        }
    }

    /// Park `pool_id`'s in-flight entry in the PendingSalvage table
    /// and send its RECLAIM. Returns false when the id is not in
    /// flight. Never blocks: the reclaim answer — or the generation's
    /// own completion, whichever the replica emits first — resolves
    /// the entry on the replica's collector; a loop that is already
    /// gone resolves immediately (re-dispatch from the last salvaged
    /// prefix). Caller holds the state lock.
    fn park_for_reclaim(&self, st: &mut PoolState, pool_id: u64, dest: SalvageDest) -> bool {
        let Some(entry) = st.inflight.remove(&pool_id) else { return false };
        let InFlight { replica, inner_id, task, migrations, dispatched, predicted } = entry;
        // the answer rides the replica's own completion channel, so it
        // is totally FIFO-ordered against the request's Done event
        let reply = st.completion_tx[replica].as_ref().cloned();
        st.parked.insert(
            pool_id,
            Parked {
                replica,
                inner_id,
                task,
                migrations,
                dispatched,
                deadline: Instant::now() + self.salvage_timeout,
                dest,
                predicted,
            },
        );
        self.parked_count.fetch_add(1, Ordering::Relaxed);
        self.ev_replica(st, "park", EventPhase::Instant, pool_id, replica, String::new());
        let delivered = match reply {
            Some(tx) => {
                let ok = st.clients[replica].reclaim_via(inner_id, tx.clone());
                if ok {
                    // wake the replica's collector (it may be blocked in
                    // a plain recv with nothing previously parked) so it
                    // adopts this entry's expiry deadline
                    let _ = tx.send(ProxyEvent::Nudge);
                }
                ok
            }
            None => false,
        };
        if !delivered {
            // the loop is gone: no answer will ever come
            self.resolve_parked(st, pool_id, Resolution::Lost);
        }
        true
    }

    /// Resolve a parked salvage exactly once: deliver the completed
    /// result (drain race — zero re-decode, nothing wasted), or fold
    /// the salvage into the task and re-dispatch it by its
    /// destination. Returns a caller reply to send after the state
    /// lock drops (`Completed` resolutions only). A resolution for an
    /// id no longer parked (expired, aborted) counts a late salvage's
    /// tokens wasted and is otherwise a no-op — double resolution is
    /// structurally impossible.
    fn resolve_parked(
        &self,
        st: &mut PoolState,
        pool_id: u64,
        how: Resolution,
    ) -> Option<(Sender<ProxyEvent>, GenResult)> {
        let Some(p) = st.parked.remove(&pool_id) else {
            if let Resolution::Salvaged(s) = how {
                // aborted or expired before the answer arrived: the
                // entry left a tombstone carrying the prefix length
                // that was already billed (abort) or lives on in the
                // re-dispatched task (expiry), so the collector's
                // already-resolved branch bills only the NEW progress.
                // Reaching here without a tombstone cannot happen for
                // parked entries — bill everything, conservatively.
                self.ledger.add_wasted(s.tokens.len() as u64);
            }
            return None;
        };
        self.parked_count.fetch_sub(1, Ordering::Relaxed);
        st.by_inner[p.replica].remove(&p.inner_id);
        st.outstanding[p.replica] = st.outstanding[p.replica].saturating_sub(1);
        if self.recorder.is_enabled() {
            let name = match &how {
                Resolution::Completed(_) => "done",
                Resolution::Salvaged(_) => "salvage",
                Resolution::Lost => "expired",
            };
            let detail = match &how {
                Resolution::Salvaged(s) => format!("tokens={}", s.tokens.len()),
                _ => String::new(),
            };
            self.ev_replica(st, "decode", EventPhase::End, pool_id, p.replica, String::new());
            self.ev_replica(st, name, EventPhase::Instant, pool_id, p.replica, detail);
        }
        let mut task = p.task;
        match how {
            Resolution::Completed(res) => {
                // the generation finished inside the reclaim window:
                // deliver it once, count it completed, re-decode nothing
                self.metrics.completed.inc();
                self.predictor.record(task.group, res.tokens.len());
                let lat = p.dispatched.elapsed().as_secs_f64().max(1e-6);
                st.lat_window.record(lat);
                self.metrics.completion_latency.record(lat);
                let fresh = res.tokens.len().saturating_sub(task.prefix.len());
                if fresh > 0 {
                    st.router.on_completion(
                        p.replica,
                        fresh as f64,
                        p.dispatched.elapsed().as_secs_f64(),
                    );
                }
                self.kv_insert_done(st, p.replica, &task.prompt, &res.tokens, pool_id);
                self.drain(st);
                return Some((task.reply, GenResult { id: pool_id, ..res }));
            }
            Resolution::Salvaged(s) => {
                self.absorb_salvage(&mut task, s);
                // the source still holds KV for everything it decoded;
                // while it keeps serving, the index remembers so the
                // re-dispatch can send the resume home for free
                self.kv_insert_done(st, p.replica, &task.prompt, &task.prefix, pool_id);
            }
            Resolution::Lost => {
                // the replica may still answer after the deadline; a
                // tombstone records the prefix that lives on in the
                // re-dispatched task so the late answer is billed for
                // exactly the NEW progress, not the whole salvage
                st.aborted_parked.insert((p.replica, p.inner_id), task.prefix.len());
            }
        }
        let migrations = p.migrations + 1;
        // either way the task prefers to land anywhere but the replica
        // it was just reclaimed from (drain relaxes this only when
        // nothing else is routable)
        let req = Pending { pool_id, task, avoid: Some(p.replica) };
        match p.dest {
            SalvageDest::Requeue => {
                st.reclaimed_in_place += 1;
                self.metrics.reclaimed_in_place.inc();
                self.trace_queue_begin(req.pool_id);
                st.queue.push_back(req);
                self.drain(st);
            }
            SalvageDest::Migrate => {
                let loads = st.loads();
                let hint = self.hint_for(st, &req.task);
                match st.router.route_excluding_hinted(&loads, Some(p.replica), hint) {
                    Some(nr) => {
                        self.ev_pool("redispatch", EventPhase::Instant, pool_id, String::new());
                        self.dispatch(st, nr, req, migrations);
                        st.migrated += 1;
                        self.metrics.migrated.inc();
                    }
                    None if st.none_serviceable() => {
                        // drop: caller disconnects with the fleet
                        self.ledger.add_wasted(req.task.prefix.len() as u64);
                        self.ev_pool("lost", EventPhase::Instant, pool_id, String::new());
                    }
                    None => {
                        // no survivor outside the source right now:
                        // queue it (keeping the avoid preference) and
                        // drain — with only the source still serving,
                        // staying put beats stranding the task until
                        // the next unrelated completion
                        self.trace_queue_begin(req.pool_id);
                        st.queue.push_back(req);
                        self.drain(st);
                    }
                }
            }
        }
        None
    }
}

/// Per-replica completion collector: the single resolver for
/// everything replica `r` emits. Completions decrement load
/// accounting, feed the router's EWMA token-rate estimate, and are
/// forwarded to the original caller (rewriting the id to the pool id);
/// RECLAIM answers resolve PendingSalvage entries — re-dispatching
/// resumed tasks to survivors, or (when the generation finished inside
/// the reclaim window) delivering the completed result with zero
/// re-decode. When nothing is parked fleet-wide it blocks on the
/// channel outright; while entries are parked on this replica it
/// sleeps exactly until the earliest deadline (no polling tick, no
/// idle wakeups — a [`ProxyEvent::Nudge`] from `park_for_reclaim`
/// interrupts the blocking wait so a fresh deadline is adopted). When
/// its channel disconnects it finalizes a pending retirement (join
/// the loop, archive the report, open the slot).
fn collector_loop(shared: Arc<Shared>, r: usize, rx: Receiver<ProxyEvent>) {
    'events: loop {
        // Earliest expiry deadline among the entries parked on THIS
        // replica, if any. The lock-free parked_count gate keeps the
        // common (nothing parked anywhere) path off the state lock.
        let next_deadline = if shared.parked_count.load(Ordering::Relaxed) == 0 {
            None
        } else {
            let st = shared.state.lock().unwrap();
            st.parked.values().filter(|p| p.replica == r).map(|p| p.deadline).min()
        };
        let ev = match next_deadline {
            None => match rx.recv() {
                Ok(ev) => ev,
                Err(_) => break 'events,
            },
            Some(deadline) => {
                let now = Instant::now();
                if now >= deadline {
                    // the replica never answered (wedged mid-decode):
                    // give up and re-dispatch from the last salvaged
                    // prefix; a late answer bills only its new progress
                    // (the entry leaves a tombstone behind)
                    let mut st = shared.state.lock().unwrap();
                    let sweep_now = Instant::now();
                    let overdue: Vec<u64> = st
                        .parked
                        .iter()
                        .filter(|(_, p)| p.replica == r && sweep_now >= p.deadline)
                        .map(|(&pid, _)| pid)
                        .collect();
                    for pid in overdue {
                        shared.metrics.expired.inc();
                        shared.resolve_parked(&mut st, pid, Resolution::Lost);
                    }
                    continue;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(ev) => ev,
                    // expiry is due: loop around to sweep it
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break 'events,
                }
            }
        };
        match ev {
            ProxyEvent::Done(res) => {
                let deliver = {
                    let mut st = shared.state.lock().unwrap();
                    collector_on_done(&shared, &mut st, r, res)
                };
                if let Some((reply, res)) = deliver {
                    let _ = reply.send(ProxyEvent::Done(res));
                }
            }
            ProxyEvent::Reclaimed { id, salvage } => {
                let deliver = {
                    let mut st = shared.state.lock().unwrap();
                    collector_on_reclaimed(&shared, &mut st, r, id, salvage)
                };
                if let Some((reply, res)) = deliver {
                    let _ = reply.send(ProxyEvent::Done(res));
                }
            }
            // a park just (re)armed a deadline: recompute at loop top
            ProxyEvent::Nudge => {}
        }
    }
    // the loop has exited and every sender is gone. A crashed loop may
    // have dropped unanswered reclaims on the floor — resolve any
    // leftovers so no PendingSalvage entry leaks
    {
        let mut st = shared.state.lock().unwrap();
        let leftovers: Vec<u64> = st
            .parked
            .iter()
            .filter(|(_, p)| p.replica == r)
            .map(|(&pid, _)| pid)
            .collect();
        for pid in leftovers {
            shared.resolve_parked(&mut st, pid, Resolution::Lost);
        }
        // tombstones of aborted parked entries on this replica can
        // never be answered now; their prefixes were billed at the
        // abort, so they are just stale memory
        st.aborted_parked.retain(|&(rep, _), _| rep != r);
    }
    finalize_retirement(&shared, r);
}

/// A completion from replica `r`: resolve the parked entry it raced
/// (if any) or the live in-flight entry. Returns the caller delivery
/// to perform after the state lock drops.
fn collector_on_done(
    shared: &Arc<Shared>,
    st: &mut PoolState,
    r: usize,
    res: GenResult,
) -> Option<(Sender<ProxyEvent>, GenResult)> {
    let Some(&pool_id) = st.by_inner[r].get(&res.id) else {
        // stale: the request was aborted after it finished — the
        // racing completion is dropped and its decoded tokens burned.
        // If the abort hit a PARKED entry whose generation finished
        // inside the reclaim window, the salvaged prefix was already
        // billed at the abort: consume the tombstone so only the fresh
        // tokens are charged here
        let carried = st.aborted_parked.remove(&(r, res.id)).unwrap_or(0);
        shared.ledger.add_wasted(res.tokens.len().saturating_sub(carried) as u64);
        return None;
    };
    if st.parked.contains_key(&pool_id) {
        // the drain race, resolved the right way around: the
        // generation finished inside the migrate/retire window
        return shared.resolve_parked(st, pool_id, Resolution::Completed(res));
    }
    st.by_inner[r].remove(&res.id);
    st.outstanding[r] = st.outstanding[r].saturating_sub(1);
    shared.metrics.completed.inc();
    if shared.recorder.is_enabled() {
        shared.ev_replica(st, "decode", EventPhase::End, pool_id, r, String::new());
        shared.ev_replica(
            st,
            "done",
            EventPhase::Instant,
            pool_id,
            r,
            format!("tokens={}", res.tokens.len()),
        );
    }
    let entry = st.inflight.remove(&pool_id);
    if let Some(e) = &entry {
        shared.kv_insert_done(st, r, &e.task.prompt, &res.tokens, pool_id);
        shared.predictor.record(e.task.group, res.tokens.len());
        let lat = e.dispatched.elapsed().as_secs_f64().max(1e-6);
        st.lat_window.record(lat);
        shared.metrics.completion_latency.record(lat);
        // feed the router only the tokens THIS replica decoded:
        // crediting a resumed task's salvaged prefix over the time
        // since re-dispatch would inflate the EWMA rate of whichever
        // replica absorbs migrated work
        let fresh = res.tokens.len().saturating_sub(e.task.prefix.len());
        if fresh > 0 {
            st.router.on_completion(r, fresh as f64, e.dispatched.elapsed().as_secs_f64());
        }
    }
    shared.drain(st);
    entry.map(|e| (e.task.reply, GenResult { id: pool_id, ..res }))
}

/// A RECLAIM answer from replica `r`, keyed by the *inner* id it was
/// issued against.
fn collector_on_reclaimed(
    shared: &Arc<Shared>,
    st: &mut PoolState,
    r: usize,
    inner_id: u64,
    salvage: Option<Salvage>,
) -> Option<(Sender<ProxyEvent>, GenResult)> {
    match st.by_inner[r].get(&inner_id).copied() {
        Some(pool_id) if st.parked.contains_key(&pool_id) => {
            let how = match salvage {
                Some(s) => Resolution::Salvaged(s),
                // parked yet unknown at the replica without a prior
                // Done on this channel: should not happen (FIFO), but
                // a lost answer must still re-dispatch the task
                None => Resolution::Lost,
            };
            shared.resolve_parked(st, pool_id, how)
        }
        _ => {
            // already resolved: the Done beat this answer on the same
            // channel, or the entry expired / was aborted. A late
            // salvage has nowhere to go — but both abort and expiry
            // leave a tombstone carrying the prefix length that was
            // already billed (abort) or re-dispatched with the task
            // (expiry), so the bill here is EXACTLY the new progress
            // the wedged replica decoded after the entry was parked.
            // The tombstone is consumed on ANY answer (a None answer
            // is the end of the story too — its Done, if one existed,
            // ran just above)
            let carried = st.aborted_parked.remove(&(r, inner_id)).unwrap_or(0);
            if let Some(s) = salvage {
                shared.ledger.add_wasted(s.tokens.len().saturating_sub(carried) as u64);
            }
            None
        }
    }
}

/// Collector exit hook: if slot `r` was retiring, join its loop (the
/// channel disconnect proves it exited), archive the occupant's
/// report, and open the slot for reuse. The `retiring` lock is held
/// across the archive so `pending_retirements` observes the slot until
/// the report lands.
fn finalize_retirement(shared: &Arc<Shared>, r: usize) {
    let mut retiring = shared.retiring.lock().unwrap();
    let Some(proxy) = retiring.remove(&r) else { return };
    let proxy_report = proxy.shutdown().unwrap_or_default();
    let mut st = shared.state.lock().unwrap();
    let serve_secs = st.close_serve_clock(r);
    // archive the occupant's time-attribution, adding the pool-side
    // drain tail (between leaving service and this finalization), and
    // hand the slot a fresh accumulator so occupants never blend
    let mut attr = st.attr[r].snapshot();
    if let Some(t) = st.drain_start[r].take() {
        attr.draining += t.elapsed().as_secs_f64();
    }
    st.attr[r] = Arc::default();
    shared.metrics.retired.inc();
    shared.ev_replica(&st, "retired", EventPhase::Instant, 0, r, String::new());
    st.retired.push(ReplicaReport {
        utilization: proxy_report.mean_occupancy(st.slots),
        proxy: proxy_report,
        routed: st.routed[r],
        queue_depth: st.depth[r].clone(),
        util_hist: st.util[r].clone(),
        slot: r,
        generation: st.generation[r],
        serve_secs,
        attr,
    });
    st.phase[r] = Phase::Retired;
}

fn spawn_collector(shared: &Arc<Shared>, r: usize, rx: Receiver<ProxyEvent>) -> JoinHandle<()> {
    let sh = shared.clone();
    std::thread::Builder::new()
        .name(format!("fleet-collect-{r}"))
        .spawn(move || collector_loop(sh, r, rx))
        .expect("spawn fleet collector")
}

/// Rolling weight-sync agent: serializes broadcast waves so that even
/// with back-to-back training steps at most one replica is suspended at
/// any moment. Each replica swap is acknowledged before the next
/// begins; a dead replica's ack channel disconnects, which counts as
/// done (fail-stop replicas must not wedge training). Non-serving
/// slots are skipped — a replica added mid-wave was already pinned to
/// the latest weights at install time.
fn sync_agent(shared: Arc<Shared>, rx: Receiver<(Vec<f32>, u64)>) {
    while let Ok((weights, version)) = rx.recv() {
        let mut r = 0usize;
        loop {
            let client = {
                let mut st = shared.state.lock().unwrap();
                if r >= st.clients.len() {
                    break;
                }
                if st.phase[r] != Phase::Serving {
                    r += 1;
                    continue;
                }
                st.syncing = Some(r);
                st.clients[r].clone()
            };
            let ack = client.update_weights_synced(weights.clone(), version);
            // a dead replica's ack channel disconnects: the wave moves
            // on, but the replica is NOT stamped — version_skew keeps
            // reporting how far behind it really is
            let applied = ack.recv().is_ok();
            let mut st = shared.state.lock().unwrap();
            st.syncing = None;
            if applied && st.phase[r] != Phase::Retired {
                st.replica_version[r] = version;
                st.kv.set_version(r, version);
                if shared.recorder.is_enabled() {
                    shared.ev_replica(
                        &st,
                        "weight_sync",
                        EventPhase::Instant,
                        0,
                        r,
                        format!("version={version}"),
                    );
                }
            }
            shared.drain(&mut st);
            drop(st);
            r += 1;
        }
        shared.state.lock().unwrap().sync_waves += 1;
    }
}

/// Final statistics for one replica-slot occupant.
#[derive(Clone, Debug, Default)]
pub struct ReplicaReport {
    pub proxy: ProxyReport,
    /// requests routed here (including migrations in)
    pub routed: u64,
    /// mean decode-slot occupancy over the replica's lifetime
    pub utilization: f64,
    /// outstanding-at-dispatch histogram
    pub queue_depth: Histogram,
    /// occupancy-fraction-at-dispatch histogram
    pub util_hist: Histogram,
    /// the slot this occupant lived in
    pub slot: usize,
    /// occupant generation within the slot (0 = original occupant)
    pub generation: u64,
    /// wall seconds this occupant spent in the serving phase — the
    /// replica-seconds currency the autoscaler economizes
    pub serve_secs: f64,
    /// where this occupant's replica-seconds went: decode-busy /
    /// prefill / prefill-replay / weight-sync / draining / idle-bubble
    pub attr: AttrSnapshot,
}

/// Final fleet statistics (per live replica + retired occupants +
/// pool-level).
#[derive(Clone, Debug, Default)]
pub struct PoolReport {
    /// slots still occupied at shutdown (serving, draining, or dead)
    pub replicas: Vec<ReplicaReport>,
    /// occupants drained out by `retire_replica`, in retirement order
    pub retired: Vec<ReplicaReport>,
    pub migrated: u64,
    /// hung generations RECLAIMed in place (salvaged + re-queued)
    /// because every peer's decode window was full at migrate time
    pub reclaimed_in_place: u64,
    /// migrations/resubmissions dispatched with a salvaged prefix
    pub resumed: u64,
    pub sync_waves: u64,
    /// replicas added after construction (autoscaler grow actions)
    pub grown: u64,
    /// pool-queue depth at submit time
    pub pool_queue_depth: Histogram,
    /// fleet-wide decoded-token outcomes (salvaged vs wasted)
    pub tokens: TokenStats,
    /// dispatches that landed on a replica already holding part of the
    /// task's prefix (KV-prefix index on)
    pub kv_hits: u64,
    /// kv-enabled dispatches with no cached prefix anywhere
    pub kv_misses: u64,
    /// prompt/prefix tokens whose re-prefill the index avoided
    pub kv_hit_tokens: u64,
    /// index blocks evicted under the per-replica byte budget
    pub kv_evictions: u64,
}

impl PoolReport {
    /// Every occupant the pool ever had: live slots then retired ones.
    pub fn all_occupants(&self) -> impl Iterator<Item = &ReplicaReport> {
        self.replicas.iter().chain(self.retired.iter())
    }

    /// Sum of the per-occupant loop reports (single-proxy-compatible
    /// aggregate view), including retired occupants.
    pub fn aggregate(&self) -> ProxyReport {
        let mut agg = ProxyReport::default();
        for r in self.all_occupants() {
            agg.decode_steps += r.proxy.decode_steps;
            agg.tokens_generated += r.proxy.tokens_generated;
            agg.completed += r.proxy.completed;
            agg.aborted += r.proxy.aborted;
            agg.reclaimed += r.proxy.reclaimed;
            agg.wasted_tokens += r.proxy.wasted_tokens;
            agg.occupancy_sum += r.proxy.occupancy_sum;
        }
        agg
    }

    /// Total replica-seconds served across every occupant — what an
    /// elastic fleet holds strictly below a static peak-provisioned one
    /// (see `benches/fig_autoscale.rs`).
    pub fn replica_seconds(&self) -> f64 {
        self.all_occupants().map(|r| r.serve_secs).sum()
    }

    /// Fleet-wide dispatch-depth histogram, merged across every
    /// occupant (live and retired slots share one bucket layout).
    pub fn merged_queue_depth(&self) -> Histogram {
        let mut h = depth_hist();
        for r in self.all_occupants() {
            h.merge(&r.queue_depth);
        }
        h
    }

    /// Fleet-wide time-attribution, merged across every occupant —
    /// the paper's resource bubbles, split by cause instead of
    /// aggregated into one utilization number.
    pub fn attribution(&self) -> AttrSnapshot {
        let mut total = AttrSnapshot::default();
        for r in self.all_occupants() {
            total.merge(&r.attr);
        }
        total
    }

    /// Markdown table of per-occupant utilization and queue depth — the
    /// fleet section of bench/example reports. Retired occupants are
    /// listed after the live slots as `slot~generation (retired)`.
    /// `attr b/s/i` is the occupant's serving time split into
    /// busy/weight-sync/idle percent (see `AttrSnapshot`).
    pub fn format_table(&self) -> String {
        let mut t = Table::new(&[
            "replica", "routed", "completed", "aborted", "tokens", "wasted", "util", "depth mean",
            "depth p99", "attr b/s/i",
        ]);
        let mut row = |label: String, r: &ReplicaReport| {
            t.row(&[
                label,
                r.routed.to_string(),
                r.proxy.completed.to_string(),
                r.proxy.aborted.to_string(),
                r.proxy.tokens_generated.to_string(),
                r.proxy.wasted_tokens.to_string(),
                format!("{:.2}", r.utilization),
                format!("{:.1}", r.queue_depth.mean()),
                format!("{:.1}", r.queue_depth.percentile(99.0)),
                r.attr.format_compact(),
            ]);
        };
        for r in &self.replicas {
            row(r.slot.to_string(), r);
        }
        for r in &self.retired {
            row(format!("{}~{} (retired)", r.slot, r.generation), r);
        }
        t.to_markdown()
    }
}

/// Client handle to a fleet of `LlmProxy` replicas. Mirrors the
/// single-proxy surface (`generate`/`try_submit`/`abort`/
/// `update_weights`/`suspend`/`resume`/`shutdown`) so the RolloutEngine
/// and the AsyncController are replica-count-agnostic, and adds the
/// elastic lifecycle (`add_replica`/`retire_replica`) the autoscaler
/// drives.
pub struct LlmProxyPool {
    shared: Arc<Shared>,
    /// per-slot proxy handles; `None` = retired slot (loop joined)
    replicas: Mutex<Vec<Option<LlmProxy>>>,
    collectors: Mutex<Vec<Option<JoinHandle<()>>>>,
    /// serializes add/retire so concurrent scale actions cannot race a
    /// slot; never held while the state lock is held
    lifecycle: Mutex<()>,
    sync_tx: Option<Sender<(Vec<f32>, u64)>>,
    sync_join: Option<JoinHandle<()>>,
    next_pool_id: AtomicU64,
    slots: usize,
    /// builds new replicas for `add_replica`; absent on pools
    /// assembled from pre-spawned replicas without a factory
    spawner: Option<ReplicaSpawner>,
    /// latest broadcast weights + version — what a freshly added
    /// replica is pinned to
    latest: Arc<Mutex<(Vec<f32>, u64)>>,
    /// where `shutdown` writes `trace.{json,jsonl}` and
    /// `metrics.{txt,csv}` (`PoolCfg::trace.export_path`)
    export_path: Option<PathBuf>,
}

impl LlmProxyPool {
    /// Spawn `num_replicas` proxy event loops plus one completion
    /// collector per replica (and, when rolling updates are on, the
    /// weight-sync agent). Each replica gets a decorrelated sampling
    /// seed; replica 0 matches the single-proxy stream exactly. All
    /// replicas share one [`TokenLedger`]. The pool retains a spawner
    /// so `add_replica` can grow the fleet later at the then-current
    /// weight version.
    pub fn spawn(
        cfg: &PoolCfg,
        artifacts_dir: PathBuf,
        init_weights: Vec<f32>,
        eos: i32,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(cfg.num_replicas > 0, "num_replicas must be > 0");
        anyhow::ensure!(cfg.replica_slots > 0, "replica_slots must be > 0");
        anyhow::ensure!(
            cfg.salvage_timeout.is_finite() && cfg.salvage_timeout > 0.0,
            "salvage_timeout must be > 0 seconds"
        );
        anyhow::ensure!(
            !cfg.trace.enabled || cfg.trace.ring_capacity > 0,
            "trace.ring_capacity must be > 0 when tracing is enabled"
        );
        cfg.predictor.validate()?;
        cfg.kv_cache.validate()?;
        let ledger = Arc::new(TokenLedger::default());
        let latest = Arc::new(Mutex::new((init_weights.clone(), 0u64)));
        let replicas: Vec<LlmProxy> = (0..cfg.num_replicas)
            .map(|r| {
                let rseed = seed ^ (r as u64).wrapping_mul(0x9e3779b97f4a7c15);
                LlmProxy::spawn_with_ledger(
                    artifacts_dir.clone(),
                    init_weights.clone(),
                    eos,
                    rseed,
                    ledger.clone(),
                )
            })
            .collect();
        let spawn_ledger = ledger.clone();
        let spawn_latest = latest.clone();
        let spawner: ReplicaSpawner = Box::new(move |slot, generation| {
            let weights = spawn_latest.lock().unwrap().0.clone();
            let rseed = seed
                ^ (slot as u64).wrapping_mul(0x9e3779b97f4a7c15)
                ^ generation.wrapping_mul(0xd1b54a32d192ed03);
            LlmProxy::spawn_with_ledger(
                artifacts_dir.clone(),
                weights,
                eos,
                rseed,
                spawn_ledger.clone(),
            )
        });
        Ok(Self::assemble_with(cfg, replicas, ledger, Some(spawner), latest))
    }

    /// Wire collectors, shared state, and the sync agent around an
    /// already-spawned replica set (tests; no spawner, so the pool
    /// cannot grow).
    #[cfg(test)]
    fn assemble(cfg: &PoolCfg, replicas: Vec<LlmProxy>, ledger: Arc<TokenLedger>) -> Self {
        Self::assemble_with(cfg, replicas, ledger, None, Arc::new(Mutex::new((vec![], 0))))
    }

    fn assemble_with(
        cfg: &PoolCfg,
        replicas: Vec<LlmProxy>,
        ledger: Arc<TokenLedger>,
        spawner: Option<ReplicaSpawner>,
        latest: Arc<Mutex<(Vec<f32>, u64)>>,
    ) -> Self {
        let n = replicas.len();
        let clients: Vec<ProxyClient> = replicas.iter().map(|p| p.client()).collect();
        let mut completion_tx = Vec::with_capacity(n);
        let mut completion_rx = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = channel();
            completion_tx.push(Some(tx));
            completion_rx.push(rx);
        }
        let attr: Vec<Arc<Attribution>> = replicas.iter().map(|p| p.attribution()).collect();
        let gossip: Vec<Arc<ProgressGossip>> =
            replicas.iter().map(|p| p.progress_gossip()).collect();
        let state = PoolState {
            router: Router::new(cfg.route_policy),
            clients,
            phase: vec![Phase::Serving; n],
            generation: vec![0; n],
            queue: VecDeque::new(),
            inflight: HashMap::new(),
            parked: HashMap::new(),
            aborted_parked: HashMap::new(),
            by_inner: vec![HashMap::new(); n],
            outstanding: vec![0; n],
            pool_suspended: false,
            syncing: None,
            replica_version: vec![0; n],
            routed: vec![0; n],
            migrated: 0,
            reclaimed_in_place: 0,
            resumed: 0,
            sync_waves: 0,
            grown: 0,
            slots: cfg.replica_slots,
            depth: (0..n).map(|_| depth_hist()).collect(),
            util: (0..n).map(|_| util_hist()).collect(),
            queue_window: depth_hist(),
            attr,
            gossip,
            lat_window: latency_hist(),
            drain_start: vec![None; n],
            completion_tx,
            kv: KvPrefixIndex::new(cfg.kv_cache, n),
            kv_hits: 0,
            kv_misses: 0,
            kv_hit_tokens: 0,
            serve_start: (0..n).map(|_| Some(Instant::now())).collect(),
            served: vec![0.0; n],
            retired: Vec::new(),
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(state),
            ledger,
            partial_migration: cfg.partial_migration,
            min_salvage_tokens: cfg.min_salvage_tokens.max(1),
            salvage_timeout: Duration::from_secs_f64(cfg.salvage_timeout.max(1e-3)),
            reclaim_in_place: cfg.reclaim_in_place,
            parked_count: AtomicUsize::new(0),
            retiring: Mutex::new(HashMap::new()),
            recorder: FlightRecorder::from_cfg(&cfg.trace),
            metrics: FleetMetrics::new(),
            route_policy: cfg.route_policy,
            predictor: Arc::new(LengthPredictor::new(cfg.predictor)),
        });
        let mut collectors = Vec::with_capacity(n);
        for (r, rx) in completion_rx.into_iter().enumerate() {
            collectors.push(Some(spawn_collector(&shared, r, rx)));
        }
        let (sync_tx, sync_join) = if cfg.rolling_update && n > 1 {
            let (tx, rx) = channel();
            let sh = shared.clone();
            let h = std::thread::Builder::new()
                .name("fleet-sync".into())
                .spawn(move || sync_agent(sh, rx))
                .expect("spawn fleet sync agent");
            (Some(tx), Some(h))
        } else {
            (None, None)
        };
        LlmProxyPool {
            shared,
            replicas: Mutex::new(replicas.into_iter().map(Some).collect()),
            collectors: Mutex::new(collectors),
            lifecycle: Mutex::new(()),
            sync_tx,
            sync_join,
            next_pool_id: AtomicU64::new(1),
            slots: cfg.replica_slots,
            spawner,
            latest,
            export_path: cfg.trace.export_path.clone(),
        }
    }

    /// Total replica slots ever opened (serving + draining + dead +
    /// retired).
    pub fn num_replicas(&self) -> usize {
        self.shared.state.lock().unwrap().clients.len()
    }

    /// Replicas currently routable.
    pub fn serving_replicas(&self) -> usize {
        self.shared.state.lock().unwrap().serving()
    }

    /// GROW: spawn a fresh replica at the pool's latest weight version,
    /// register its collector + histograms, and open it to routing —
    /// reusing a retired slot (generation bumped, stats reset, router
    /// EWMA cleared) when one exists, appending a new slot otherwise.
    /// Returns the slot index. Fails on pools assembled without a
    /// spawner.
    pub fn add_replica(&self) -> Result<usize> {
        let spawner = self
            .spawner
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("pool has no replica spawner: cannot grow"))?;
        let _guard = self.lifecycle.lock().unwrap();
        let (slot, generation, fresh) = {
            let st = self.shared.state.lock().unwrap();
            match st.phase.iter().position(|&p| p == Phase::Retired) {
                Some(s) => (s, st.generation[s] + 1, false),
                None => (st.clients.len(), 0, true),
            }
        };
        // spawning loads a runtime — keep it off the state lock so
        // collectors and callers flow while the replica boots
        let replica = spawner(slot, generation);
        let client = replica.client();
        let attr = replica.attribution();
        let gossip = replica.progress_gossip();
        // pin the newcomer to the latest broadcast weights: the spawner
        // snapshot may have raced a concurrent update_weights
        let (weights, version) = {
            let l = self.latest.lock().unwrap();
            (l.0.clone(), l.1)
        };
        client.update_weights(weights, version);
        let (tx, rx) = channel();
        {
            let mut st = self.shared.state.lock().unwrap();
            if fresh {
                st.clients.push(client);
                st.phase.push(Phase::Serving);
                st.generation.push(0);
                st.by_inner.push(HashMap::new());
                st.outstanding.push(0);
                st.replica_version.push(version);
                st.routed.push(0);
                st.depth.push(depth_hist());
                st.util.push(util_hist());
                st.attr.push(attr);
                st.gossip.push(gossip);
                st.drain_start.push(None);
                st.completion_tx.push(Some(tx));
                st.serve_start.push(Some(Instant::now()));
                st.served.push(0.0);
            } else {
                st.clients[slot] = client;
                st.phase[slot] = Phase::Serving;
                st.generation[slot] = generation;
                st.by_inner[slot].clear();
                // the new occupant's inner ids restart from 1: stale
                // tombstones from the previous occupant must not match
                st.aborted_parked.retain(|&(rep, _), _| rep != slot);
                st.outstanding[slot] = 0;
                st.replica_version[slot] = version;
                st.routed[slot] = 0;
                st.depth[slot] = depth_hist();
                st.util[slot] = util_hist();
                st.attr[slot] = attr;
                st.gossip[slot] = gossip;
                st.drain_start[slot] = None;
                st.completion_tx[slot] = Some(tx);
                st.serve_start[slot] = Some(Instant::now());
                st.served[slot] = 0.0;
                // the new occupant must be probed fresh, not inherit
                // the previous occupant's EWMA token rate
                st.router.reset_replica(slot);
                // ...nor the previous occupant's advertised KV state
                st.kv.invalidate_replica(slot);
            }
            st.grown += 1;
            self.shared.metrics.grown.inc();
            self.shared.ev_replica(&st, "grow", EventPhase::Instant, 0, slot, String::new());
            if st.pool_suspended {
                st.clients[slot].suspend();
            }
            // backlog flows onto the new replica immediately
            self.shared.drain(&mut st);
        }
        {
            let mut reps = self.replicas.lock().unwrap();
            if fresh {
                reps.push(Some(replica));
            } else {
                reps[slot] = Some(replica);
            }
        }
        let handle = spawn_collector(&self.shared, slot, rx);
        {
            let mut cols = self.collectors.lock().unwrap();
            if fresh {
                cols.push(Some(handle));
            } else {
                // the previous occupant's collector archived the slot
                // (phase Retired implies it is past its finalization)
                // — join it before installing the successor's
                if let Some(old) = cols[slot].replace(handle) {
                    let _ = old.join();
                }
            }
        }
        Ok(slot)
    }

    /// SHRINK: drain replica `r` out of the fleet — without ever
    /// blocking the caller. The slot flips to *draining* (the router
    /// stops selecting it instantly), its in-flight generations are
    /// parked in the PendingSalvage table with their RECLAIMs sent,
    /// the loop is ordered to stop (commands are FIFO, so it answers
    /// every reclaim on the way out), and the call returns. The slot's
    /// collector then resolves each entry — re-dispatching resumed
    /// tasks to survivors on their original reply channels, or
    /// delivering a result that finished inside the drain window
    /// exactly once — joins the loop, archives the occupant's
    /// [`ReplicaReport`], and opens the slot (phase → retired).
    /// Scale-down burns no decoded tokens and stalls no event thread.
    /// Returns false when `r` is not serving or is the last serving
    /// replica (the fleet never drains itself to zero).
    pub fn retire_replica(&self, r: usize) -> bool {
        let _guard = self.lifecycle.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            if r >= st.phase.len() || st.phase[r] != Phase::Serving {
                return false;
            }
            if st.serving() < 2 {
                return false; // never drain the last serving replica
            }
            st.phase[r] = Phase::Draining;
            st.kv.invalidate_replica(r);
            st.close_serve_clock(r);
            st.drain_start[r] = Some(Instant::now());
            self.shared.ev_replica(&st, "retire", EventPhase::Instant, 0, r, String::new());
        }
        // stash the proxy handle for the collector to join BEFORE the
        // loop can possibly exit, so the finalization never misses it
        // (bind first: the replicas guard must not be held while the
        // retiring lock is taken)
        let proxy = self.replicas.lock().unwrap()[r].take();
        if let Some(proxy) = proxy {
            self.shared.retiring.lock().unwrap().insert(r, proxy);
        }
        let mut st = self.shared.state.lock().unwrap();
        let ids: Vec<u64> = st
            .inflight
            .iter()
            .filter(|(_, e)| e.replica == r)
            .map(|(&pid, _)| pid)
            .collect();
        for pid in ids {
            self.shared.park_for_reclaim(&mut st, pid, SalvageDest::Migrate);
        }
        // release the master collector sender and order the loop down:
        // it answers the reclaims above first (FIFO), then exits; once
        // its last reply clone drops, the collector finalizes the slot
        st.completion_tx[r].take();
        st.clients[r].kill();
        true
    }

    /// SHRINK by policy: retire the serving replica with the fewest
    /// in-flight requests; ties prefer the replica with the fewest
    /// predicted-remaining tokens (the decode work a drain would
    /// interrupt), then the cheapest true salvage bill — the carried
    /// prefix PLUS the fresh decode progress its loop has gossiped,
    /// which is the KV replay a drain would actually re-pay — then the
    /// lowest slot. False when fewer than two replicas serve.
    pub fn retire_idlest(&self) -> bool {
        let victim = {
            let st = self.shared.state.lock().unwrap();
            (0..st.phase.len())
                .filter(|&i| st.phase[i] == Phase::Serving)
                .min_by_key(|&i| {
                    let replay =
                        st.salvage_cost(i) + st.gossip[i].inflight_fresh() as usize;
                    (st.outstanding[i], st.predicted_remaining(i).round() as u64, replay, i)
                })
        };
        match victim {
            Some(r) => self.retire_replica(r),
            None => false,
        }
    }

    /// PendingSalvage entries currently awaiting resolution (parked by
    /// `migrate`/`retire_replica`/`kill_replica`). Diagnostics: tests
    /// and examples use this to observe the asynchronous drain settle.
    pub fn pending_reclaims(&self) -> usize {
        self.shared.parked_count.load(Ordering::Relaxed)
    }

    /// Retiring slots whose report has not been archived yet.
    pub fn pending_retirements(&self) -> usize {
        self.shared.retiring.lock().unwrap().len()
    }

    /// Hung generations RECLAIMed in place so far (see
    /// `PoolCfg::reclaim_in_place`).
    pub fn reclaims_in_place(&self) -> u64 {
        self.shared.state.lock().unwrap().reclaimed_in_place
    }

    /// One interval's observation for the autoscaler: serving count,
    /// total in-flight, and the windowed p90 pool-queue depth (the
    /// window resets on every read; an interval with no submissions
    /// falls back to the instantaneous queue length).
    pub fn autoscale_signals(&self) -> PoolSignals {
        let mut st = self.shared.state.lock().unwrap();
        let window_p90 = st.queue_window.percentile(90.0);
        st.queue_window.reset();
        let profile = self.shared.predictor.snapshot();
        PoolSignals {
            serving: st.serving(),
            queue_depth: window_p90.max(st.queue.len() as f64),
            outstanding: st.outstanding.iter().sum(),
            slots: st.slots,
            wasted_tokens: self.shared.ledger.stats().wasted_tokens,
            pred_mean_len: profile.mean,
            pred_p90_len: profile.p90,
        }
    }

    /// Windowed episode-completion-latency percentiles `(p50, p99)` in
    /// seconds since the last read; the window resets on every read
    /// (`StepLog`'s per-step feed — the lifetime histogram stays in the
    /// metrics registry as `pool.completion_latency`). `(0, 0)` when no
    /// episode completed in the window.
    pub fn latency_percentiles(&self) -> (f64, f64) {
        let mut st = self.shared.state.lock().unwrap();
        let out = (st.lat_window.percentile(50.0), st.lat_window.percentile(99.0));
        st.lat_window.reset();
        out
    }

    /// Shared generation-length predictor (diagnostics + the engine's
    /// sim mirror feed).
    pub fn length_predictor(&self) -> Arc<LengthPredictor> {
        self.shared.predictor.clone()
    }

    /// ADD: route (or pool-queue) a from-scratch generation; returns
    /// (pool id, reply receiver) — same shape as `LlmProxy::generate`
    /// (the receiver yields `ProxyEvent::Done`; unwrap with
    /// [`ProxyEvent::done`]). When no replica can ever serve it the
    /// reply sender is dropped, so the receiver observes disconnection
    /// instead of hanging.
    pub fn generate(&self, prompt: Vec<i32>, max_new_tokens: usize) -> (u64, Receiver<ProxyEvent>) {
        let (reply, rx) = channel();
        let task = GenerationTask::fresh(prompt, max_new_tokens, reply);
        (self.try_submit(task).unwrap_or(0), rx)
    }

    /// ADD a [`GenerationTask`] with a caller-supplied reply sender:
    /// the event-driven RolloutEngine points every request at one
    /// shared completion channel (results are demultiplexed by the
    /// returned pool id) instead of blocking a thread per receiver.
    /// Returns `None` when no serving replica remains — the task (and
    /// its reply sender) was dropped, and on a *shared* reply channel
    /// that produces no disconnect signal, so callers must not wait
    /// for a result.
    pub fn try_submit(&self, task: GenerationTask) -> Option<u64> {
        let pool_id = self.next_pool_id.fetch_add(1, Ordering::Relaxed);
        let req = Pending { pool_id, task, avoid: None };
        let mut st = self.shared.state.lock().unwrap();
        if st.none_serviceable() {
            return None; // drop: nothing can ever serve this
        }
        self.shared.metrics.submitted.inc();
        self.shared.metrics.pool_queue_depth.record(st.queue.len() as f64);
        st.queue_window.record(st.queue.len() as f64);
        if self.shared.recorder.is_enabled() {
            self.shared.ev_pool(
                "submit",
                EventPhase::Instant,
                pool_id,
                format!("prompt={}", req.task.prompt.len()),
            );
        }
        let loads = st.loads();
        let hint = self.shared.hint_for(&st, &req.task);
        match st.router.route_hinted(&loads, hint) {
            Some(r) => self.shared.dispatch(&mut st, r, req, 0),
            None => {
                self.shared.trace_queue_begin(pool_id);
                st.queue.push_back(req);
            }
        }
        Some(pool_id)
    }

    /// ABORT by pool id: reclaims the request whether it is pool-queued
    /// or on a replica (the replica counts its decoded tokens as
    /// wasted). No-op for finished/unknown ids.
    pub fn abort(&self, pool_id: u64) {
        let mut st = self.shared.state.lock().unwrap();
        st.queue.retain(|p| {
            if p.pool_id == pool_id {
                // a queued task's salvaged prefix dies with it
                self.shared.ledger.add_wasted(p.task.prefix.len() as u64);
                self.shared.trace_queue_end(pool_id);
                self.shared.ev_pool("abort", EventPhase::Instant, pool_id, String::new());
                false
            } else {
                true
            }
        });
        if let Some(e) = st.inflight.remove(&pool_id) {
            st.by_inner[e.replica].remove(&e.inner_id);
            st.outstanding[e.replica] = st.outstanding[e.replica].saturating_sub(1);
            st.clients[e.replica].abort(e.inner_id);
            if self.shared.recorder.is_enabled() {
                self.shared.ev_replica(&st, "decode", EventPhase::End, pool_id, e.replica, String::new());
                self.shared.ev_replica(&st, "abort", EventPhase::Instant, pool_id, e.replica, String::new());
            }
            self.shared.drain(&mut st);
        } else if let Some(p) = st.parked.remove(&pool_id) {
            // abort of a mid-reclaim request: unpark it so the pending
            // answer resolves to nothing. The already-salvaged prefix
            // is billed wasted HERE — a wedged replica that never
            // answers must not leak it from the ledger — and a
            // tombstone lets the answer, if it ever arrives, bill only
            // the new progress (see the collector's already-resolved
            // branch). No abort command is needed: the in-flight
            // RECLAIM removes the request from the replica either way.
            self.shared.parked_count.fetch_sub(1, Ordering::Relaxed);
            st.by_inner[p.replica].remove(&p.inner_id);
            st.outstanding[p.replica] = st.outstanding[p.replica].saturating_sub(1);
            self.shared.ledger.add_wasted(p.task.prefix.len() as u64);
            st.aborted_parked.insert((p.replica, p.inner_id), p.task.prefix.len());
            if self.shared.recorder.is_enabled() {
                self.shared.ev_replica(&st, "decode", EventPhase::End, pool_id, p.replica, String::new());
                self.shared.ev_replica(&st, "abort", EventPhase::Instant, pool_id, p.replica, String::new());
            }
            self.shared.drain(&mut st);
        }
    }

    /// Prefix-salvaging migration: move a (presumed hung) request off
    /// its current replica, keeping the original reply channel. The
    /// entry is parked in the PendingSalvage table and the call
    /// returns immediately — the replica's collector absorbs the
    /// RECLAIM answer and re-dispatches the task, resumed from its
    /// decoded prefix when `partial_migration` allows, or delivers the
    /// result outright if the generation finished inside the window.
    /// When every peer's decode window is full, the request is
    /// RECLAIMed *in place* instead (`reclaim_in_place`): salvaged and
    /// re-entered into pool admission — paused, not piled onto a
    /// saturated survivor. Returns false when the request is unknown /
    /// already finished, or there is no other serving replica at all —
    /// callers should then keep waiting or give the episode up.
    pub fn migrate(&self, pool_id: u64) -> bool {
        let mut st = self.shared.state.lock().unwrap();
        let Some(entry) = st.inflight.get(&pool_id) else { return false };
        let old = entry.replica;
        let loads = st.loads();
        let movable = st.router.has_free_candidate(&loads, Some(old));
        let peers = (0..loads.len()).any(|i| i != old && !loads[i].suspended);
        let dest = if movable {
            SalvageDest::Migrate
        } else if peers && self.shared.reclaim_in_place {
            // ReclaimInPlace: the pool is saturated — pause the hung
            // generation (salvage + re-enter admission) rather than
            // force it onto an already-full survivor
            SalvageDest::Requeue
        } else {
            return false; // single replica / nowhere to go: keep waiting
        };
        self.shared.park_for_reclaim(&mut st, pool_id, dest)
    }

    /// Suspend every live replica (synchronous mode: rollout pauses
    /// during training). New requests pool-queue until `resume`.
    /// Idempotent: an already-suspended pool is left untouched, so the
    /// async governor can issue suspend on a mode transition without
    /// tracking whether the previous mode already did — replicas never
    /// see a double Suspend command.
    pub fn suspend(&self) {
        let mut st = self.shared.state.lock().unwrap();
        if st.pool_suspended {
            return;
        }
        st.pool_suspended = true;
        for r in 0..st.clients.len() {
            if matches!(st.phase[r], Phase::Serving | Phase::Draining) {
                st.clients[r].suspend();
            }
        }
    }

    /// Idempotent inverse of [`suspend`](Self::suspend): resuming a
    /// pool that is not suspended is a no-op (no double Resume, no
    /// spurious drain), so governor transitions like Sync->FullyAsync
    /// cannot double-resume and a transition landing between a
    /// suspend/resume pair cannot leave replicas parked.
    pub fn resume(&self) {
        let mut st = self.shared.state.lock().unwrap();
        if !st.pool_suspended {
            return;
        }
        st.pool_suspended = false;
        for r in 0..st.clients.len() {
            if matches!(st.phase[r], Phase::Serving | Phase::Draining) {
                st.clients[r].resume();
            }
        }
        self.shared.drain(&mut st);
    }

    /// model_update across the fleet. Rolling mode hands the payload to
    /// the sync agent (staggered, >= N-1 replicas keep decoding, waves
    /// from consecutive training steps serialize). While the pool is
    /// suspended (sync mode) — or when rolling is off — broadcast
    /// inline instead: on each replica's command channel the swap then
    /// precedes the controller's Resume, which is exactly the
    /// single-proxy on-policy ordering. The payload is also snapshot
    /// as the pool's `latest`, which freshly added replicas are pinned
    /// to.
    pub fn update_weights(&self, weights: Vec<f32>, version: u64) {
        {
            let mut l = self.latest.lock().unwrap();
            *l = (weights.clone(), version);
        }
        let suspended = self.shared.state.lock().unwrap().pool_suspended;
        if !suspended {
            if let Some(tx) = &self.sync_tx {
                let _ = tx.send((weights, version));
                return;
            }
        }
        // broadcast is ordered ahead of any later command on every live
        // channel, so live replicas are at `version` for new work; dead
        // replicas stay behind and keep showing up in version_skew
        let mut st = self.shared.state.lock().unwrap();
        for r in 0..st.clients.len() {
            if matches!(st.phase[r], Phase::Serving | Phase::Draining) {
                st.clients[r].update_weights(weights.clone(), version);
                st.replica_version[r] = version;
                st.kv.set_version(r, version);
            }
        }
        if self.shared.recorder.is_enabled() {
            self.shared.ev_pool(
                "weight_sync",
                EventPhase::Instant,
                0,
                format!("version={version} broadcast=true"),
            );
        }
    }

    /// Fault injection (tests, chaos drills): hard-stop replica `r`'s
    /// event loop as if the process died — without blocking the
    /// caller. The replica is marked dead (no new work routes there),
    /// its in-flight generations are parked with their RECLAIMs sent,
    /// and the loop is ordered down — commands are FIFO, so the
    /// salvage drain is answered ahead of the shutdown, and the dead
    /// slot's collector re-dispatches the resumed tasks to survivors.
    /// A loop that already exited resolves every entry immediately
    /// (re-dispatch from the last salvaged prefix).
    pub fn kill_replica(&self, r: usize) {
        let mut st = self.shared.state.lock().unwrap();
        if r >= st.phase.len()
            || matches!(st.phase[r], Phase::Dead | Phase::Retired | Phase::Draining)
        {
            return;
        }
        st.phase[r] = Phase::Dead;
        st.kv.invalidate_replica(r);
        st.close_serve_clock(r);
        self.shared.ev_replica(&st, "kill", EventPhase::Instant, 0, r, String::new());
        let ids: Vec<u64> = st
            .inflight
            .iter()
            .filter(|(_, e)| e.replica == r)
            .map(|(&pid, _)| pid)
            .collect();
        for pid in ids {
            self.shared.park_for_reclaim(&mut st, pid, SalvageDest::Migrate);
        }
        st.clients[r].kill();
    }

    /// Rolling-sync weight-version skew across the fleet: max - min of
    /// the last version each live or dead replica acknowledged
    /// (retired slots drained cleanly and are excluded). 0 when every
    /// replica runs the same weights (always, outside a sync wave).
    pub fn version_skew(&self) -> u64 {
        let st = self.shared.state.lock().unwrap();
        let versions = (0..st.replica_version.len())
            .filter(|&r| st.phase[r] != Phase::Retired)
            .map(|r| st.replica_version[r]);
        let max = versions.clone().max().unwrap_or(0);
        let min = versions.min().unwrap_or(0);
        max - min
    }

    /// Live fleet-wide decoded-token outcomes (salvaged vs wasted).
    pub fn token_stats(&self) -> TokenStats {
        self.shared.ledger.stats()
    }

    /// Diagnostics: in-flight requests per replica slot (retired slots
    /// report 0).
    pub fn outstanding_per_replica(&self) -> Vec<usize> {
        self.shared.state.lock().unwrap().outstanding.clone()
    }

    /// Diagnostics: requests currently held pool-side.
    pub fn pool_queue_len(&self) -> usize {
        self.shared.state.lock().unwrap().queue.len()
    }

    /// Diagnostics: migrations/resubmissions that carried a salvaged
    /// prefix so far.
    pub fn resumed_dispatches(&self) -> u64 {
        self.shared.state.lock().unwrap().resumed
    }

    /// The pool's flight recorder (disabled unless `PoolCfg::trace`
    /// enables it) — the autoscaler and controller stamp their own
    /// lifecycle events through this handle.
    pub fn recorder(&self) -> Arc<FlightRecorder> {
        self.shared.recorder.clone()
    }

    /// The pool's named-metrics registry (counters + the lifetime
    /// pool-queue histogram). Reporters may `snapshot_and_reset` for
    /// windowed readings.
    pub fn metrics(&self) -> Arc<MetricsRegistry> {
        self.shared.metrics.registry.clone()
    }

    /// Live fleet-wide time-attribution: archived retirees plus every
    /// slot's current occupant. `StepLog` takes per-step deltas of
    /// this; categories sum to total replica-seconds (serving ones to
    /// `serving_replicas × wall_secs`).
    pub fn attribution(&self) -> AttrSnapshot {
        let st = self.shared.state.lock().unwrap();
        let mut total = AttrSnapshot::default();
        for rep in &st.retired {
            total.merge(&rep.attr);
        }
        for (r, a) in st.attr.iter().enumerate() {
            let mut s = a.snapshot();
            // a slot mid-drain owes its pool-side drain tail too
            if let Some(t) = st.drain_start[r] {
                s.draining += t.elapsed().as_secs_f64();
            }
            total.merge(&s);
        }
        total
    }

    /// The pool-side half of a [`TelemetrySignals`] reading for the
    /// live telemetry plane: caller clock (recorder epoch seconds),
    /// cumulative completions, instantaneous queue/serving, cumulative
    /// attribution and token ledger, and the oldest open decode span's
    /// age (the stalled-episode watchdog input). The caller — the
    /// training controller — fills in the trainer-side fields
    /// (buffer occupancy, get_batch wait, version gap) and the
    /// already-windowed latency percentiles before ticking the plane.
    /// Reads no reset-on-read window, so it never steals `StepLog`'s
    /// per-step feeds.
    pub fn telemetry_signals(&self) -> TelemetrySignals {
        let now = self.shared.recorder.now();
        let tokens = self.shared.ledger.stats();
        let (queue_depth, serving) = {
            let st = self.shared.state.lock().unwrap();
            (st.queue.len() as f64, st.serving())
        };
        TelemetrySignals {
            now,
            completed: self.shared.metrics.completed.get(),
            queue_depth,
            serving,
            attr: self.attribution(),
            wasted_tokens: tokens.wasted_tokens,
            salvaged_tokens: tokens.salvaged_tokens,
            prefix_hit_tokens: tokens.prefix_hit_tokens,
            produced_tokens: 0,
            version_gap: 0.0,
            buffer_ready: 0.0,
            train_wait_secs: 0.0,
            lat_p50: 0.0,
            lat_p99: 0.0,
            oldest_open_decode_secs: self.shared.recorder.oldest_open_span_age("decode", now),
        }
    }

    /// Mirror the recorder's own health into the registry —
    /// `trace.dropped` (overflow count, silent trace loss) and
    /// `trace.ring_occupancy.<i>` per-ring gauges. The telemetry tick
    /// calls this each window; it is also safe to call ad hoc.
    pub fn publish_trace_gauges(&self) {
        crate::metrics::telemetry::publish_recorder_gauges(
            &self.shared.recorder,
            &self.shared.metrics.registry,
        );
    }

    /// Stop every replica and collector; gather the fleet report.
    pub fn shutdown(mut self) -> Result<PoolReport> {
        // 1. finish any queued rolling-sync waves
        self.sync_tx.take();
        if let Some(h) = self.sync_join.take() {
            let _ = h.join();
        }
        // 2. drop master collector senders and abandon queued requests
        {
            let mut st = self.shared.state.lock().unwrap();
            for tx in st.completion_tx.iter_mut() {
                tx.take();
            }
            for p in st.queue.drain(..) {
                self.shared.ledger.add_wasted(p.task.prefix.len() as u64);
                self.shared.trace_queue_end(p.pool_id);
                self.shared.ev_pool("lost", EventPhase::Instant, p.pool_id, String::new());
            }
        }
        // 3. join live replica loops (drops their in-flight reply
        //    clones, letting the collectors observe disconnection).
        //    Retired/retiring slots are None here: their loops are
        //    joined by their own collector (finalize_retirement), and
        //    a retirement still in flight completes before step 4's
        //    collector join returns — the archive is guaranteed to be
        //    in `st.retired` when the report is assembled below
        let mut proxy_reports: Vec<Option<ProxyReport>> = Vec::new();
        {
            let mut reps = self.replicas.lock().unwrap();
            for p in reps.iter_mut() {
                proxy_reports.push(match p.take() {
                    Some(p) => Some(p.shutdown()?),
                    None => None,
                });
            }
        }
        {
            let mut cols = self.collectors.lock().unwrap();
            for h in cols.iter_mut() {
                if let Some(h) = h.take() {
                    let _ = h.join();
                }
            }
        }
        let mut st = self.shared.state.lock().unwrap();
        let mut replicas = Vec::new();
        for (r, proxy) in proxy_reports.into_iter().enumerate() {
            let Some(proxy) = proxy else { continue };
            let serve_secs = st.close_serve_clock(r);
            let mut attr = st.attr[r].snapshot();
            if let Some(t) = st.drain_start[r].take() {
                attr.draining += t.elapsed().as_secs_f64();
            }
            replicas.push(ReplicaReport {
                utilization: proxy.mean_occupancy(self.slots),
                proxy,
                routed: st.routed[r],
                queue_depth: st.depth[r].clone(),
                util_hist: st.util[r].clone(),
                slot: r,
                generation: st.generation[r],
                serve_secs,
                attr,
            });
        }
        let report = PoolReport {
            replicas,
            retired: std::mem::take(&mut st.retired),
            migrated: st.migrated,
            reclaimed_in_place: st.reclaimed_in_place,
            resumed: st.resumed,
            sync_waves: st.sync_waves,
            grown: st.grown,
            pool_queue_depth: self.shared.metrics.pool_queue_depth.read(),
            tokens: self.shared.ledger.stats(),
            kv_hits: st.kv_hits,
            kv_misses: st.kv_misses,
            kv_hit_tokens: st.kv_hit_tokens,
            kv_evictions: st.kv.stats().evictions,
        };
        drop(st);
        if let Some(dir) = &self.export_path {
            self.shared.recorder.export_to_dir(dir)?;
            let snap = self.shared.metrics.registry.snapshot();
            std::fs::write(dir.join("metrics.txt"), snap.to_text())?;
            std::fs::write(dir.join("metrics.csv"), snap.to_csv())?;
        }
        Ok(report)
    }
}

#[cfg(test)]
impl LlmProxyPool {
    /// Block until every PendingSalvage entry has resolved and every
    /// retiring slot has archived its report. Panics after `timeout`.
    pub(crate) fn settle(&self, timeout: Duration) {
        let deadline = Instant::now() + timeout;
        while self.pending_reclaims() > 0 || self.pending_retirements() > 0 {
            assert!(
                Instant::now() < deadline,
                "salvage never settled: {} parked, {} retiring",
                self.pending_reclaims(),
                self.pending_retirements()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// Already-salvaged prefix tokens attached to live work (in
    /// flight, pool-queued, or parked) — the "still in the system"
    /// side of the token-conservation ledger balance.
    pub(crate) fn prefix_tokens_outstanding(&self) -> usize {
        let st = self.shared.state.lock().unwrap();
        st.inflight.values().map(|e| e.task.prefix.len()).sum::<usize>()
            + st.queue.iter().map(|p| p.task.prefix.len()).sum::<usize>()
            + st.parked.values().map(|p| p.task.prefix.len()).sum::<usize>()
    }

    /// Structural invariants that double resolution or a leaked
    /// PendingSalvage entry would break. Called by the race proptests
    /// after every operation.
    pub(crate) fn check_invariants(&self) {
        let st = self.shared.state.lock().unwrap();
        for r in 0..st.outstanding.len() {
            let inflight = st.inflight.values().filter(|e| e.replica == r).count();
            let parked = st.parked.values().filter(|p| p.replica == r).count();
            assert_eq!(
                st.outstanding[r],
                inflight + parked,
                "outstanding drift on replica {r}: {} != {inflight} in flight + {parked} parked",
                st.outstanding[r]
            );
            assert_eq!(
                st.by_inner[r].len(),
                inflight + parked,
                "by_inner drift on replica {r}"
            );
        }
        for pid in st.inflight.keys() {
            assert!(!st.parked.contains_key(pid), "pool id {pid} both in flight and parked");
        }
        assert_eq!(
            st.parked.len(),
            self.shared.parked_count.load(Ordering::Relaxed),
            "parked_count gauge drifted from the PendingSalvage table"
        );
    }
}

impl Drop for LlmProxyPool {
    fn drop(&mut self) {
        // best-effort teardown for error paths: release the collector
        // channels so their threads exit; LlmProxy's own Drop joins the
        // proxy loops. After a clean shutdown() everything is empty.
        self.sync_tx.take();
        if let Some(h) = self.sync_join.take() {
            let _ = h.join();
        }
        if let Ok(mut st) = self.shared.state.lock() {
            for tx in st.completion_tx.iter_mut() {
                tx.take();
            }
            st.queue.clear();
        }
        if let Ok(mut reps) = self.replicas.lock() {
            reps.clear();
        }
        if let Ok(mut cols) = self.collectors.lock() {
            for h in cols.iter_mut() {
                if let Some(h) = h.take() {
                    let _ = h.join();
                }
            }
        }
    }
}

/// Stub-pool constructors shared by the unit tests below and the
/// `coordinator::reclaim_races` interleaving suite. All exercise the
/// pool's routing/salvage bookkeeping WITHOUT artifacts, against live
/// stub event loops that accept commands but never decode (see the
/// `spawn_stub_*` family in `llm_proxy.rs`). End-to-end generation
/// runs live in rust/tests/integration.rs.
#[cfg(test)]
pub(crate) mod testing {
    use super::*;

    pub(crate) fn cfg(n: usize, policy: RoutePolicy, slots: usize) -> PoolCfg {
        PoolCfg {
            num_replicas: n,
            route_policy: policy,
            rolling_update: false,
            replica_slots: slots,
            partial_migration: true,
            min_salvage_tokens: 1,
            salvage_timeout: 2.0,
            reclaim_in_place: true,
            trace: TraceCfg::disabled(),
            predictor: PredictorCfg::default(),
            kv_cache: KvCacheCfg::disabled(),
        }
    }

    pub(crate) fn pool(n: usize, policy: RoutePolicy, slots: usize) -> LlmProxyPool {
        LlmProxyPool::assemble(
            &cfg(n, policy, slots),
            (0..n).map(|_| LlmProxy::spawn_stub()).collect(),
            Arc::default(),
        )
    }

    /// Stub fleet whose replicas fabricate `progress` decoded tokens
    /// on every RECLAIM (salvage-path bookkeeping without artifacts).
    pub(crate) fn pool_with_progress(n: usize, progress: usize, pcfg: &PoolCfg) -> LlmProxyPool {
        LlmProxyPool::assemble(
            pcfg,
            (0..n).map(|_| LlmProxy::spawn_stub_with_progress(progress)).collect(),
            Arc::default(),
        )
    }

    /// Elastic stub fleet: `add_replica` spawns more stubs with the
    /// same fabricated RECLAIM progress.
    pub(crate) fn elastic_pool(n: usize, progress: usize, pcfg: &PoolCfg) -> LlmProxyPool {
        LlmProxyPool::assemble_with(
            pcfg,
            (0..n).map(|_| LlmProxy::spawn_stub_with_progress(progress)).collect(),
            Arc::default(),
            Some(Box::new(move |_slot, _gen| LlmProxy::spawn_stub_with_progress(progress))),
            Arc::new(Mutex::new((vec![], 0))),
        )
    }

    /// Pool of stubs that answer RECLAIM by finishing the generation
    /// first (the drain race, fabricated deterministically).
    pub(crate) fn elastic_finishing_pool(
        n: usize,
        finish_tokens: usize,
        pcfg: &PoolCfg,
    ) -> LlmProxyPool {
        LlmProxyPool::assemble_with(
            pcfg,
            (0..n).map(|_| LlmProxy::spawn_stub_finishing_on_reclaim(finish_tokens)).collect(),
            Arc::default(),
            Some(Box::new(move |_slot, _gen| {
                LlmProxy::spawn_stub_finishing_on_reclaim(finish_tokens)
            })),
            Arc::new(Mutex::new((vec![], 0))),
        )
    }

    /// Pool of stubs that delay every RECLAIM answer by `delay` —
    /// fail-slow replicas for the caller-latency tests.
    pub(crate) fn delayed_pool(
        n: usize,
        progress: usize,
        delay: Duration,
        pcfg: &PoolCfg,
    ) -> LlmProxyPool {
        LlmProxyPool::assemble(
            pcfg,
            (0..n).map(|_| LlmProxy::spawn_stub_with_reclaim_delay(progress, delay)).collect(),
            Arc::default(),
        )
    }

    /// Pool of stubs that never answer RECLAIM at all — wedged
    /// replicas for the resolution-timeout tests.
    pub(crate) fn mute_pool(n: usize, pcfg: &PoolCfg) -> LlmProxyPool {
        LlmProxyPool::assemble(
            pcfg,
            (0..n).map(|_| LlmProxy::spawn_stub_mute()).collect(),
            Arc::default(),
        )
    }

    /// Pool over a caller-supplied (possibly heterogeneous) stub set —
    /// e.g. one wedged replica next to a healthy one.
    pub(crate) fn custom_pool(replicas: Vec<LlmProxy>, pcfg: &PoolCfg) -> LlmProxyPool {
        LlmProxyPool::assemble(pcfg, replicas, Arc::default())
    }
}

#[cfg(test)]
mod tests {
    use super::testing::*;
    use super::*;
    use crate::coordinator::autoscaler::{AutoscaleCfg, Autoscaler, ScaleDecision};

    /// Generous settle window for stub pools (they resolve in µs).
    const SETTLE: Duration = Duration::from_secs(10);

    #[test]
    fn rejects_zero_replicas() {
        let cfg = PoolCfg { num_replicas: 0, ..PoolCfg::single(4) };
        assert!(LlmProxyPool::spawn(&cfg, PathBuf::from("/x"), vec![], 2, 0).is_err());
    }

    #[test]
    fn round_robin_spreads_requests() {
        let p = pool(3, RoutePolicy::RoundRobin, 8);
        for _ in 0..6 {
            let _ = p.generate(vec![1, 2], 4);
        }
        assert_eq!(p.outstanding_per_replica(), vec![2, 2, 2]);
        assert_eq!(p.pool_queue_len(), 0);
    }

    #[test]
    fn least_outstanding_balances_after_abort() {
        let p = pool(2, RoutePolicy::LeastOutstanding, 8);
        let (id0, _rx0) = p.generate(vec![1], 4);
        let (_id1, _rx1) = p.generate(vec![1], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        p.abort(id0);
        assert_eq!(p.outstanding_per_replica(), vec![0, 1]);
        // next request fills the freed replica
        let (_id2, _rx2) = p.generate(vec![1], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        // aborting a finished/unknown id is a no-op
        p.abort(9999);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
    }

    #[test]
    fn queue_sched_backpressures_pool_side() {
        let p = pool(2, RoutePolicy::QueueSched, 1);
        let (a_id, _rx_a) = p.generate(vec![1], 4);
        let (_b_id, _rx_b) = p.generate(vec![1], 4);
        let (_c_id, _rx_c) = p.generate(vec![1], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        assert_eq!(p.pool_queue_len(), 1);
        // freeing a slot dispatches the queued request
        p.abort(a_id);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        assert_eq!(p.pool_queue_len(), 0);
    }

    #[test]
    fn migrate_moves_request_between_replicas() {
        let p = pool(2, RoutePolicy::LeastOutstanding, 8);
        let (id, _rx) = p.generate(vec![1, 2, 3], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 0]);
        assert!(p.migrate(id));
        p.settle(SETTLE); // the collector absorbs the salvage
        assert_eq!(p.outstanding_per_replica(), vec![0, 1]);
        // unknown request: nothing to migrate
        assert!(!p.migrate(12345));
        p.check_invariants();
    }

    #[test]
    fn migrate_salvages_decoded_prefix() {
        // stub replicas fabricate 3 decoded tokens per reclaim: the
        // migrated request must carry them and the ledger must count
        let p = pool_with_progress(2, 3, &cfg(2, RoutePolicy::LeastOutstanding, 8));
        let (id, _rx) = p.generate(vec![1, 2], 10);
        assert!(p.migrate(id));
        p.settle(SETTLE);
        let stats = p.token_stats();
        assert_eq!(stats.salvaged_tokens, 3, "{stats:?}");
        assert_eq!(stats.wasted_tokens, 0, "{stats:?}");
        assert_eq!(p.resumed_dispatches(), 1, "target dispatch must be a resume");
        // a second migration salvages only the NEW progress (3 more
        // fake tokens on top of the carried prefix)
        assert!(p.migrate(id));
        p.settle(SETTLE);
        assert_eq!(p.token_stats().salvaged_tokens, 6);
        assert_eq!(p.resumed_dispatches(), 2);
        p.check_invariants();
    }

    /// The tentpole's engine-path acceptance, on live stub replicas:
    /// a salvaged prefix lands in the source replica's KV index, a
    /// later request sharing the prompt is routed back there by the
    /// cache override (overriding least-outstanding), the dispatch
    /// counts the hit in the ledger and PoolReport, and the flight
    /// recorder sees kv_hit/kv_miss instants.
    #[test]
    fn kv_index_routes_prompt_sharers_back_and_counts_hits() {
        let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
        c.kv_cache = KvCacheCfg {
            enabled: true,
            block_tokens: 2,
            kv_bytes_budget: 1 << 20,
            bytes_per_token: 16,
            invalidate_on_weight_sync: true,
        };
        c.trace = TraceCfg { enabled: true, ring_capacity: 4096, export_path: None };
        let p = pool_with_progress(2, 3, &c);
        let rec = p.recorder();

        // decode starts on replica 0, then migrates to replica 1 with
        // 3 fabricated salvage tokens: prompt ++ prefix (7 tokens = 3
        // whole blocks) is now indexed on the SOURCE replica
        let (id, _rx) = p.generate(vec![1, 2, 3, 4], 10);
        assert!(p.migrate(id));
        p.settle(SETTLE);
        assert_eq!(p.outstanding_per_replica(), vec![0, 1]);

        // two unrelated requests load replica 0 past replica 1, so
        // least-outstanding on its own would pick replica 1 next
        let (_f1, _rx1) = p.generate(vec![9, 9, 9, 9], 10);
        let (_f2, _rx2) = p.generate(vec![9, 9, 9, 9], 10);
        assert_eq!(p.outstanding_per_replica(), vec![2, 1]);

        // a prompt-sharing request must override the load signal and
        // resume where its 4-token prefix (2 blocks) is cached
        let (_id2, _rx3) = p.generate(vec![1, 2, 3, 4], 10);
        assert_eq!(
            p.outstanding_per_replica(),
            vec![3, 1],
            "cache-aware routing must return the prompt to replica 0"
        );
        let stats = p.token_stats();
        assert_eq!(stats.prefix_hit_tokens, 4, "{stats:?}");

        let events = rec.events();
        assert!(
            events.iter().any(|e| e.name == "kv_hit"),
            "the hit must land in the flight recorder"
        );
        assert!(
            events.iter().any(|e| e.name == "kv_miss"),
            "cold dispatches under the enabled index record misses"
        );

        let report = p.shutdown().unwrap();
        assert_eq!(report.kv_hits, 1, "{report:?}");
        assert_eq!(report.kv_hit_tokens, 4, "{report:?}");
        assert!(report.kv_misses >= 1, "{report:?}");
    }

    /// Killing a replica drops its cached prefixes: the next
    /// prompt-sharer must not be routed to (or credited against) the
    /// dead slot's stale KV.
    #[test]
    fn kv_index_forgets_killed_replicas() {
        let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
        c.kv_cache = KvCacheCfg {
            enabled: true,
            block_tokens: 2,
            kv_bytes_budget: 1 << 20,
            bytes_per_token: 16,
            invalidate_on_weight_sync: true,
        };
        let p = pool_with_progress(2, 3, &c);
        let (id, _rx) = p.generate(vec![1, 2, 3, 4], 10);
        assert!(p.migrate(id));
        p.settle(SETTLE);
        // the cached copy lives on replica 0; kill it
        p.kill_replica(0);
        p.settle(SETTLE);
        let (_id2, _rx2) = p.generate(vec![1, 2, 3, 4], 10);
        assert_eq!(
            p.token_stats().prefix_hit_tokens,
            0,
            "a dead replica's KV must never be credited"
        );
        p.check_invariants();
    }

    #[test]
    fn from_scratch_arm_counts_waste_instead() {
        let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
        c.partial_migration = false;
        let p = pool_with_progress(2, 3, &c);
        let (id, _rx) = p.generate(vec![1, 2], 10);
        assert!(p.migrate(id));
        p.settle(SETTLE);
        let stats = p.token_stats();
        assert_eq!(stats.salvaged_tokens, 0, "{stats:?}");
        assert_eq!(stats.wasted_tokens, 3, "dropped progress must be counted: {stats:?}");
        assert_eq!(p.resumed_dispatches(), 0, "from-scratch arm never resumes");
    }

    #[test]
    fn min_salvage_floor_discards_short_prefixes() {
        let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
        c.min_salvage_tokens = 5;
        let p = pool_with_progress(2, 3, &c);
        let (id, _rx) = p.generate(vec![1], 10);
        assert!(p.migrate(id));
        p.settle(SETTLE);
        let stats = p.token_stats();
        assert_eq!(stats.salvaged_tokens, 0, "{stats:?}");
        assert_eq!(stats.wasted_tokens, 3, "below-floor salvage is burned: {stats:?}");
    }

    #[test]
    fn single_replica_cannot_migrate() {
        let p = pool(1, RoutePolicy::LeastOutstanding, 8);
        let (id, _rx) = p.generate(vec![1], 4);
        assert!(!p.migrate(id));
        assert_eq!(p.outstanding_per_replica(), vec![1]);
    }

    #[test]
    fn suspend_queues_resume_flushes() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        p.suspend();
        let _g = p.generate(vec![1], 4);
        assert_eq!(p.pool_queue_len(), 1);
        assert_eq!(p.outstanding_per_replica(), vec![0, 0]);
        p.resume();
        assert_eq!(p.pool_queue_len(), 0);
        assert_eq!(p.outstanding_per_replica(), vec![1, 0]);
    }

    /// Governor mode transitions issue suspend/resume without tracking
    /// what the previous mode already did — the pair must be idempotent
    /// and safe under any interleaving the step loop can produce.
    #[test]
    fn suspend_resume_are_idempotent_across_mode_transitions() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        // double-suspend (e.g. Sync step after a tighten transition
        // already suspended): replicas must not see a second Suspend
        p.suspend();
        p.suspend();
        let _g = p.generate(vec![1], 4);
        assert_eq!(p.pool_queue_len(), 1);
        p.resume();
        assert_eq!(p.pool_queue_len(), 0, "one resume undoes any number of suspends");
        assert_eq!(p.outstanding_per_replica(), vec![1, 0]);
        // double-resume on a running pool (relax transition right
        // after a sync step already resumed): no spurious drain, new
        // work keeps dispatching
        p.resume();
        let _h = p.generate(vec![2], 4);
        assert_eq!(p.pool_queue_len(), 0);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        // a full suspend/resume/resume burst — the governor flipping
        // Sync -> FullyAsync inside one step — leaves the pool live
        p.suspend();
        p.resume();
        p.resume();
        let _i = p.generate(vec![3], 4);
        assert_eq!(p.outstanding_per_replica(), vec![2, 1]);
    }

    #[test]
    fn submit_shares_one_reply_channel_with_unique_ids() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        let (tx, _rx) = channel();
        let a = p.try_submit(GenerationTask::fresh(vec![1], 4, tx.clone())).unwrap();
        let b = p.try_submit(GenerationTask::fresh(vec![2], 4, tx.clone())).unwrap();
        let c = p.try_submit(GenerationTask::fresh(vec![3], 4, tx)).unwrap();
        assert!(a != b && b != c && a != c, "pool ids must demultiplex");
        assert_eq!(p.outstanding_per_replica(), vec![2, 1]);
    }

    #[test]
    fn kill_replica_marks_dead_and_stops_routing() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        p.kill_replica(0);
        let _a = p.generate(vec![1], 4);
        let _b = p.generate(vec![1], 4);
        assert_eq!(p.outstanding_per_replica(), vec![0, 2]);
        assert_eq!(p.serving_replicas(), 1);
        // out-of-range kill is a no-op
        p.kill_replica(99);
    }

    #[test]
    fn kill_replica_salvages_and_redispatches_in_flight() {
        let p = pool_with_progress(2, 4, &cfg(2, RoutePolicy::RoundRobin, 8));
        let (_a, _rx_a) = p.generate(vec![1], 16); // RR -> replica 0
        let (_b, _rx_b) = p.generate(vec![2], 16); // RR -> replica 1
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        p.kill_replica(0);
        p.settle(SETTLE);
        // the victim's request moved to replica 1 with its salvage
        assert_eq!(p.outstanding_per_replica(), vec![0, 2]);
        let stats = p.token_stats();
        assert_eq!(stats.salvaged_tokens, 4, "{stats:?}");
        assert_eq!(p.resumed_dispatches(), 1);
        p.check_invariants();
    }

    #[test]
    fn version_skew_starts_and_broadcasts_to_zero() {
        let p = pool(3, RoutePolicy::LeastOutstanding, 8);
        assert_eq!(p.version_skew(), 0);
        p.update_weights(vec![], 5); // rolling off in this helper: broadcast
        assert_eq!(p.version_skew(), 0);
    }

    #[test]
    fn dead_replica_keeps_version_skew_visible() {
        let p = pool(2, RoutePolicy::LeastOutstanding, 8);
        p.kill_replica(1);
        p.update_weights(vec![], 3);
        // the corpse never applied version 3: the lag must show
        assert_eq!(p.version_skew(), 3);
    }

    #[test]
    fn try_submit_reports_whole_fleet_dead() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        p.kill_replica(0);
        p.kill_replica(1);
        let (tx, _rx) = channel();
        assert!(p.try_submit(GenerationTask::fresh(vec![1], 4, tx)).is_none());
        // generate() still returns a disconnected receiver
        let (_, rx) = p.generate(vec![1], 4);
        assert!(rx.recv().is_err(), "reply channel must disconnect");
    }

    #[test]
    fn dead_replica_fails_over() {
        // replica 0 dies immediately (bogus artifacts); replica 1 is a
        // live stub. Requests routed at the corpse must fail over.
        let dead = LlmProxy::spawn(PathBuf::from("/nonexistent-artifacts"), vec![], 2, 1);
        let p = LlmProxyPool::assemble(
            &cfg(2, RoutePolicy::RoundRobin, 8),
            vec![dead, LlmProxy::spawn_stub()],
            Arc::default(),
        );
        // let the artifact-less replica's event loop exit
        std::thread::sleep(std::time::Duration::from_millis(100));
        let (_a, rx_a) = p.generate(vec![1], 4); // RR -> replica 0 -> failover
        let (_b, _rx_b) = p.generate(vec![1], 4);
        assert_eq!(p.outstanding_per_replica(), vec![0, 2]);
        assert!(
            matches!(
                rx_a.recv_timeout(std::time::Duration::from_millis(50)),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout)
            ),
            "failed-over request must stay pending on the live replica"
        );
    }

    // --- elastic lifecycle -------------------------------------------

    #[test]
    fn add_replica_opens_slot_to_routing() {
        let p = elastic_pool(1, 0, &cfg(1, RoutePolicy::LeastOutstanding, 8));
        assert_eq!(p.serving_replicas(), 1);
        let slot = p.add_replica().unwrap();
        assert_eq!(slot, 1, "fresh slot appended");
        assert_eq!(p.serving_replicas(), 2);
        assert_eq!(p.num_replicas(), 2);
        let _a = p.generate(vec![1], 4);
        let _b = p.generate(vec![2], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1], "new slot must serve");
    }

    #[test]
    fn add_replica_requires_a_spawner() {
        let p = pool(1, RoutePolicy::LeastOutstanding, 8);
        assert!(p.add_replica().is_err(), "assembled pools cannot grow");
    }

    #[test]
    fn add_replica_drains_backlog_onto_newcomer() {
        // 1 replica x 1 slot under QueueSched: the second request
        // pool-queues; growth must flush it onto the new replica
        let p = elastic_pool(1, 0, &cfg(1, RoutePolicy::QueueSched, 1));
        let (_a, _rx_a) = p.generate(vec![1], 4);
        let (_b, _rx_b) = p.generate(vec![2], 4);
        assert_eq!(p.pool_queue_len(), 1);
        p.add_replica().unwrap();
        assert_eq!(p.pool_queue_len(), 0, "backlog flows onto the new replica");
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
    }

    #[test]
    fn retire_replica_salvages_and_redispatches() {
        let p = elastic_pool(2, 5, &cfg(2, RoutePolicy::RoundRobin, 8));
        let (_a, _rx_a) = p.generate(vec![1], 32); // RR -> replica 0
        let (_b, _rx_b) = p.generate(vec![2], 32); // RR -> replica 1
        assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
        assert!(p.retire_replica(0), "retire must be accepted");
        assert_eq!(p.serving_replicas(), 1, "the router drops the slot instantly");
        p.settle(SETTLE); // collector-absorbed salvage + archive
        // the drained request moved to replica 1 as a resumed task
        assert_eq!(p.outstanding_per_replica(), vec![0, 2]);
        let stats = p.token_stats();
        assert_eq!(stats.salvaged_tokens, 5, "drain must salvage, not burn: {stats:?}");
        assert_eq!(stats.wasted_tokens, 0, "scale-down must waste nothing: {stats:?}");
        assert_eq!(p.resumed_dispatches(), 1);
        // retiring an already-retired slot is a no-op
        assert!(!p.retire_replica(0));
        // retiring the last serving replica is refused
        assert!(!p.retire_replica(1));
        let report = p.shutdown().unwrap();
        assert_eq!(report.retired.len(), 1);
        assert_eq!(report.retired[0].slot, 0);
        assert_eq!(report.replicas.len(), 1, "only the survivor is live at shutdown");
    }

    #[test]
    fn retired_slot_is_reused_with_bumped_generation() {
        let p = elastic_pool(2, 0, &cfg(2, RoutePolicy::LeastOutstanding, 8));
        assert!(p.retire_replica(0));
        p.settle(SETTLE); // slot must be archived (phase Retired) to be reusable
        assert_eq!(p.serving_replicas(), 1);
        let slot = p.add_replica().unwrap();
        assert_eq!(slot, 0, "the retired slot is reused, not leaked");
        assert_eq!(p.num_replicas(), 2, "no new slot appended");
        assert_eq!(p.serving_replicas(), 2);
        let _a = p.generate(vec![1], 4);
        let _b = p.generate(vec![2], 4);
        assert_eq!(p.outstanding_per_replica(), vec![1, 1], "reused slot serves again");
        let report = p.shutdown().unwrap();
        assert_eq!(report.retired.len(), 1);
        assert_eq!(report.retired[0].generation, 0, "first occupant archived");
        let reused = report.replicas.iter().find(|r| r.slot == 0).unwrap();
        assert_eq!(reused.generation, 1, "second occupant is generation 1");
        assert_eq!(reused.routed, 1, "stats reset for the new occupant");
        assert_eq!(report.grown, 1);
    }

    #[test]
    fn autoscaler_grows_and_drains_stub_pool() {
        // burst -> grow to max; abort the load -> shrink back to min,
        // with every drain salvaging instead of wasting
        let p = elastic_pool(1, 0, &cfg(1, RoutePolicy::LeastOutstanding, 8));
        let mut scaler = Autoscaler::new(AutoscaleCfg {
            enabled: true,
            min_replicas: 1,
            max_replicas: 3,
            target_queue_depth: 2.0,
            interval: 0.0001,
            cooldown: 0.0001,
            hysteresis: 0.2,
            adaptive_target: false,
            decode_knee: 16.0,
        });
        let mut ids = Vec::new();
        for i in 0..12 {
            let (id, _rx) = p.generate(vec![i], 4);
            ids.push(id);
        }
        std::thread::sleep(Duration::from_millis(2));
        let d = scaler.tick(&p);
        assert!(matches!(d, ScaleDecision::Grow(_)), "burst must grow: {d:?}");
        assert_eq!(p.serving_replicas(), 3, "clamped to max_replicas");
        // load vanishes: the fleet collapses back to the floor
        for id in ids {
            p.abort(id);
        }
        std::thread::sleep(Duration::from_millis(2));
        while p.serving_replicas() > 1 {
            let d = scaler.tick(&p);
            assert!(
                !matches!(d, ScaleDecision::Grow(_)),
                "idle fleet must not grow: {d:?}"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(p.serving_replicas(), 1);
        p.settle(SETTLE);
        assert_eq!(p.token_stats().wasted_tokens, 0, "scale-down must waste nothing");
        let report = p.shutdown().unwrap();
        assert_eq!(report.grown, 2);
        assert_eq!(report.retired.len(), 2);
        assert!(report.replica_seconds() > 0.0);
    }

    #[test]
    fn merged_queue_depth_spans_retired_occupants() {
        let p = elastic_pool(2, 0, &cfg(2, RoutePolicy::RoundRobin, 8));
        let (_a, _rx_a) = p.generate(vec![1], 4); // RR -> 0
        let (_b, _rx_b) = p.generate(vec![2], 4); // RR -> 1
        assert!(p.retire_replica(0));
        p.settle(SETTLE); // the redispatch must land before shutdown
        let report = p.shutdown().unwrap();
        let live: u64 = report.replicas.iter().map(|r| r.queue_depth.count()).sum();
        let merged = report.merged_queue_depth();
        assert_eq!(
            merged.count(),
            live + report.retired[0].queue_depth.count(),
            "merge must span live and retired occupants"
        );
        // the redispatch landed on the survivor, so at least 3 dispatch
        // samples exist fleet-wide
        assert!(merged.count() >= 3, "{merged:?}");
    }

    // --- observability -----------------------------------------------

    #[test]
    fn trace_covers_every_request_and_round_trips() {
        use crate::metrics::trace::check_span_nesting;
        use crate::util::json::Json;
        let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
        c.trace = TraceCfg { enabled: true, ring_capacity: 4096, export_path: None };
        let p = pool_with_progress(2, 3, &c);
        let mut ids = Vec::new();
        for i in 0..4 {
            let (id, _rx) = p.generate(vec![i], 8);
            ids.push(id);
        }
        assert!(p.migrate(ids[0]));
        p.settle(SETTLE);
        for &id in &ids {
            p.abort(id);
        }
        p.settle(SETTLE);
        let rec = p.recorder();
        let events = rec.events();
        for &id in &ids {
            assert!(
                events.iter().any(|e| e.req == id && e.name == "submit"),
                "request {id} missing from the trace"
            );
        }
        // the migrated request's full story is on record
        let names: Vec<&str> =
            events.iter().filter(|e| e.req == ids[0]).map(|e| e.name).collect();
        for expect in ["submit", "route", "prefill", "decode", "park", "salvage", "redispatch", "abort"]
        {
            assert!(names.contains(&expect), "{expect} missing from {names:?}");
        }
        // every span closed, none interleaved
        check_span_nesting(&events).unwrap();
        assert_eq!(rec.dropped(), 0);
        // exports round-trip through the JSON parser
        let chrome = Json::parse(&rec.export_chrome_trace()).expect("chrome trace parses");
        let evs = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), events.len());
        for line in rec.export_jsonl().lines() {
            Json::parse(line).expect("every JSONL line parses");
        }
        // the registry counted the same story
        let snap = p.metrics().snapshot();
        let counter = |name: &str| {
            snap.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap()
        };
        assert_eq!(counter("pool.submitted"), 4);
        assert_eq!(counter("pool.migrated"), 1);
    }

    #[test]
    fn attribution_sums_to_serving_replica_seconds() {
        let p = pool(2, RoutePolicy::RoundRobin, 8);
        let base = p.attribution();
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(300));
        let delta = p.attribution().delta(&base);
        let wall = t0.elapsed().as_secs_f64();
        let expect = 2.0 * wall; // serving_replicas × wall_secs
        let got = delta.serving_total();
        assert!(
            (got - expect).abs() <= 0.4 * expect + 0.05,
            "attribution drifted: {got:.3}s attributed vs {expect:.3}s of replica time"
        );
        assert!(
            delta.idle_bubble >= 0.8 * got,
            "stub replicas never decode: idle must dominate: {delta:?}"
        );
        assert!(delta.draining.abs() < 1e-6, "nothing drained: {delta:?}");
        // the per-occupant split survives into the report and table
        let report = p.shutdown().unwrap();
        assert!(report.attribution().serving_total() >= got - 0.05);
        assert!(report.format_table().contains("attr b/s/i"));
    }

    #[test]
    fn shutdown_exports_trace_and_metrics_files() {
        let dir = std::env::temp_dir().join(format!("fleet-trace-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut c = cfg(1, RoutePolicy::RoundRobin, 4);
        c.trace =
            TraceCfg { enabled: true, ring_capacity: 1024, export_path: Some(dir.clone()) };
        let p = pool_with_progress(1, 0, &c);
        let _g = p.generate(vec![1], 4);
        p.shutdown().unwrap();
        for f in ["trace.json", "trace.jsonl", "metrics.txt", "metrics.csv"] {
            assert!(dir.join(f).exists(), "{f} must be exported at shutdown");
        }
        let text = std::fs::read_to_string(dir.join("trace.json")).unwrap();
        crate::util::json::Json::parse(&text).expect("exported chrome trace parses");
        let metrics = std::fs::read_to_string(dir.join("metrics.txt")).unwrap();
        assert!(metrics.contains("counter pool.submitted 1"), "{metrics}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    // --- length-aware scheduling -------------------------------------

    #[test]
    fn tail_aware_pool_serves_cold_start_without_starvation() {
        // a cold predictor classifies everything short; TailAware must
        // still place every request (spill keeps it work-conserving)
        let p = pool(3, RoutePolicy::TailAware, 2);
        for i in 0..6 {
            let _ = p.generate(vec![i], 4);
        }
        assert_eq!(p.outstanding_per_replica().iter().sum::<usize>(), 6);
        assert_eq!(p.pool_queue_len(), 0);
        let sig = p.autoscale_signals();
        assert_eq!(sig.pred_mean_len, 0.0, "nothing completed yet");
        let (p50, p99) = p.latency_percentiles();
        assert_eq!((p50, p99), (0.0, 0.0), "no completions: empty latency window");
    }

    #[test]
    fn retire_idlest_prefers_predicted_cheapest_victim() {
        // equal request counts: the tie must break on
        // predicted-remaining tokens (budget-clamped default
        // predictions here), not slot order
        let p = elastic_pool(3, 0, &cfg(3, RoutePolicy::RoundRobin, 8));
        let (_a, _rx_a) = p.generate(vec![1], 400); // RR -> replica 0, predicted 256
        let (_b, _rx_b) = p.generate(vec![2], 4); // RR -> replica 1, predicted 4
        let (_c, _rx_c) = p.generate(vec![3], 400); // RR -> replica 2, predicted 256
        assert!(p.retire_idlest());
        p.settle(SETTLE);
        assert_eq!(p.serving_replicas(), 2);
        assert_eq!(
            p.outstanding_per_replica()[1],
            0,
            "replica 1 held the fewest predicted-remaining tokens and must drain"
        );
        p.check_invariants();
    }

    #[test]
    fn predictor_rejects_invalid_cfg_at_spawn() {
        let mut c = PoolCfg::single(4);
        c.predictor.ewma_beta = 0.0;
        assert!(LlmProxyPool::spawn(&c, PathBuf::from("/x"), vec![], 2, 0).is_err());
    }
}
