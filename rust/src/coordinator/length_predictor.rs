//! Generation-length prediction for tail-aware scheduling (RollPacker,
//! arxiv 2509.21009; ROADMAP "Continuous batching + long-tail length
//! scheduling").
//!
//! The long-tail stall is a *scheduling* problem: a 30k-token straggler
//! admitted late pins its decode batch long after the short work around
//! it finished. But generation length is predictable enough to schedule
//! by — rollouts of the same prompt group (GRPO members, retries of the
//! same env task) have strongly correlated lengths. This module keeps a
//! per-group [`GroupStats`] (EWMA mean + a fixed-size reservoir
//! [`QuantileSketch`] for p50/p90) updated on every completion, plus a
//! global fallback for cold groups, and serves three consumers:
//!
//!   * **routing** — `RoutePolicy::TailAware` scores replicas by
//!     predicted-*remaining*-tokens and packs predicted-long rollouts
//!     onto a dedicated sub-pool (see `routing.rs`);
//!   * **proxy admission** — the decode loop admits
//!     shortest-predicted-first within a long-work reservation
//!     (`llm_proxy.rs::pick_admission`);
//!   * **autoscaler** — `target_queue_depth` is derived from the decode
//!     knee x the live mean/p90 length ratio instead of a hand-tuned
//!     constant (`autoscaler.rs::decide`).
//!
//! Everything here is deterministic: the reservoir uses a fixed-seed
//! LCG (never wall clock or thread identity), so the virtual-time sim
//! mirror replays identically — the same property every other shared
//! policy in this codebase holds.
//!
//! Guard rails (the "bad prediction" bugfix): a prediction is always
//! >= 1 token, a zero-sample group falls back global-then-default
//! instead of predicting 0, and [`predict_for`](LengthPredictor::predict_for)
//! clamps to the task's budget — so a wild estimate can bias *ordering*
//! but can never size a task past the `max_seq` its budget already
//! respects.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::Result;

/// Predictor shape (`length_predictor: {…}` in YAML / CLI).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PredictorCfg {
    /// EWMA smoothing weight for per-group mean length
    pub ewma_beta: f64,
    /// reservoir size of each quantile sketch (fixed memory per group)
    pub sketch_capacity: usize,
    /// completions quantile above which a rollout is classified "long"
    /// (the admission reservation + dedicated-replica class boundary)
    pub long_quantile: f64,
    /// observations before a group's own stats are trusted over the
    /// global fallback (cold-start guard)
    pub min_samples: u64,
    /// prediction when nothing has ever completed (tokens)
    pub default_len: f64,
}

impl PredictorCfg {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.ewma_beta > 0.0 && self.ewma_beta <= 1.0,
            "length_predictor.ewma_beta must be in (0, 1]"
        );
        anyhow::ensure!(
            self.sketch_capacity >= 8,
            "length_predictor.sketch_capacity must be >= 8"
        );
        anyhow::ensure!(
            self.long_quantile > 0.0 && self.long_quantile < 1.0,
            "length_predictor.long_quantile must be in (0, 1)"
        );
        anyhow::ensure!(self.min_samples >= 1, "length_predictor.min_samples must be >= 1");
        anyhow::ensure!(
            self.default_len.is_finite() && self.default_len >= 1.0,
            "length_predictor.default_len must be >= 1"
        );
        Ok(())
    }
}

impl Default for PredictorCfg {
    fn default() -> Self {
        PredictorCfg {
            ewma_beta: 0.2,
            sketch_capacity: 64,
            long_quantile: 0.8,
            min_samples: 8,
            default_len: 256.0,
        }
    }
}

/// Fixed-size reservoir sampler with quantile reads (Vitter's
/// algorithm R over a deterministic LCG). Memory is O(capacity)
/// regardless of stream length; quantiles are computed by sorting the
/// <= capacity retained samples on read.
#[derive(Clone, Debug)]
pub struct QuantileSketch {
    cap: usize,
    samples: Vec<f64>,
    seen: u64,
    /// deterministic replacement stream — NEVER wall clock or a
    /// thread-local RNG, so sim replays are bit-identical
    lcg: u64,
}

impl QuantileSketch {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(1);
        QuantileSketch {
            cap,
            samples: Vec::with_capacity(cap),
            seen: 0,
            lcg: 0x9e3779b97f4a7c15,
        }
    }

    fn next_rand(&mut self) -> u64 {
        // Knuth MMIX LCG; low bits discarded
        self.lcg = self.lcg.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        self.lcg >> 11
    }

    pub fn insert(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // each of the `seen` stream elements survives with equal
            // probability cap/seen
            let j = (self.next_rand() % self.seen) as usize;
            if j < self.cap {
                self.samples[j] = v;
            }
        }
    }

    /// Total values ever inserted (not the retained count).
    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// Approximate `q`-quantile (q in [0, 1]) of the stream; 0.0 when
    /// empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = (q.clamp(0.0, 1.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx]
    }
}

/// Per-group observation state: EWMA mean + quantile reservoir.
#[derive(Clone, Debug)]
struct GroupStats {
    ewma: f64,
    count: u64,
    sketch: QuantileSketch,
}

impl GroupStats {
    fn new(capacity: usize) -> Self {
        GroupStats { ewma: 0.0, count: 0, sketch: QuantileSketch::new(capacity) }
    }

    fn record(&mut self, len: f64, beta: f64) {
        self.count += 1;
        self.ewma = if self.count == 1 { len } else { beta * len + (1.0 - beta) * self.ewma };
        self.sketch.insert(len);
    }
}

struct Inner {
    groups: HashMap<u64, GroupStats>,
    global: GroupStats,
}

/// What the fleet-wide length profile looks like right now — the
/// autoscaler's `pred_mean_len`/`pred_p90_len` signals and the
/// diagnostics surface.
#[derive(Clone, Copy, Debug, Default)]
pub struct LengthSnapshot {
    /// EWMA mean generation length across all completions (tokens)
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    /// completions observed fleet-wide
    pub samples: u64,
}

/// Shared generation-length predictor. One instance per pool (and one
/// per sim run), behind a mutex so the collectors, the submit path, and
/// the autoscaler read a consistent state. All operations are O(1)
/// except quantile reads, which sort <= sketch_capacity samples.
pub struct LengthPredictor {
    cfg: PredictorCfg,
    inner: Mutex<Inner>,
}

impl LengthPredictor {
    pub fn new(cfg: PredictorCfg) -> Self {
        LengthPredictor {
            inner: Mutex::new(Inner {
                groups: HashMap::new(),
                global: GroupStats::new(cfg.sketch_capacity),
            }),
            cfg,
        }
    }

    pub fn cfg(&self) -> PredictorCfg {
        self.cfg
    }

    /// Feed one completed generation: `len` tokens for prompt-group
    /// `group`. Called by the pool's collectors on every `Done` and by
    /// the sim on every virtual completion.
    pub fn record(&self, group: u64, len: usize) {
        let mut g = self.inner.lock().unwrap();
        let len = (len as f64).max(1.0);
        g.global.record(len, self.cfg.ewma_beta);
        g.groups
            .entry(group)
            .or_insert_with(|| GroupStats::new(self.cfg.sketch_capacity))
            .record(len, self.cfg.ewma_beta);
    }

    /// Predicted total generation length for the next rollout of
    /// `group`, in tokens. Fallback chain: the group's own EWMA once it
    /// has `min_samples` observations, else the global EWMA once *it*
    /// does, else `default_len`. Always >= 1.
    pub fn predict(&self, group: u64) -> f64 {
        let g = self.inner.lock().unwrap();
        let v = match g.groups.get(&group) {
            Some(st) if st.count >= self.cfg.min_samples => st.ewma,
            _ if g.global.count >= self.cfg.min_samples => g.global.ewma,
            _ => self.cfg.default_len,
        };
        v.max(1.0)
    }

    /// [`predict`](Self::predict) clamped to a task's new-token budget:
    /// the value that may size scheduling decisions. The budget already
    /// respects the replica's `max_seq` (the proxy clamps rows to it),
    /// so a runaway estimate can never imply an overflowing placement.
    pub fn predict_for(&self, group: u64, budget: usize) -> usize {
        (self.predict(group).round() as usize).clamp(1, budget.max(1))
    }

    /// Is a rollout with this predicted length in the long class? True
    /// once the fleet has seen `min_samples` completions and the
    /// prediction clears the global `long_quantile`. Cold start
    /// classifies everything short, so scheduling degrades to FIFO
    /// until there is data to act on.
    pub fn classify(&self, predicted: f64) -> bool {
        let g = self.inner.lock().unwrap();
        g.global.count >= self.cfg.min_samples
            && predicted >= g.global.sketch.quantile(self.cfg.long_quantile)
    }

    /// Fleet-wide length profile (autoscaler signals + diagnostics).
    pub fn snapshot(&self) -> LengthSnapshot {
        let g = self.inner.lock().unwrap();
        LengthSnapshot {
            mean: g.global.ewma,
            p50: g.global.sketch.quantile(0.5),
            p90: g.global.sketch.quantile(0.9),
            samples: g.global.count,
        }
    }
}

impl std::fmt::Debug for LengthPredictor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let snap = self.snapshot();
        f.debug_struct("LengthPredictor").field("cfg", &self.cfg).field("global", &snap).finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn cfg_validation_rejects_nonsense() {
        assert!(PredictorCfg::default().validate().is_ok());
        for mutate in [
            (|c: &mut PredictorCfg| c.ewma_beta = 0.0) as fn(&mut PredictorCfg),
            |c| c.ewma_beta = 1.5,
            |c| c.sketch_capacity = 4,
            |c| c.long_quantile = 0.0,
            |c| c.long_quantile = 1.0,
            |c| c.min_samples = 0,
            |c| c.default_len = 0.0,
            |c| c.default_len = f64::NAN,
        ] {
            let mut c = PredictorCfg::default();
            mutate(&mut c);
            assert!(c.validate().is_err(), "{c:?} should be rejected");
        }
    }

    #[test]
    fn sketch_tracks_quantiles_of_heavy_tailed_stream() {
        // lognormal sigma=1.1 (the qwen3_base tail factor): a 128-slot
        // reservoir over 20k samples must land near the exact p50/p90
        let mut rng = Rng::new(11);
        let (mu, sigma) = crate::util::rng::lognormal_params(2000.0, 1.1);
        let mut sketch = QuantileSketch::new(128);
        let mut xs = Vec::new();
        for _ in 0..20_000 {
            let v = rng.lognormal(mu, sigma);
            sketch.insert(v);
            xs.push(v);
        }
        assert_eq!(sketch.seen(), 20_000);
        for q in [50.0, 90.0] {
            let exact = crate::util::percentile(&xs, q);
            let approx = sketch.quantile(q / 100.0);
            assert!(
                (approx - exact).abs() / exact < 0.35,
                "p{q}: sketch {approx:.0} vs exact {exact:.0}"
            );
        }
    }

    #[test]
    fn sketch_is_deterministic_and_bounded() {
        let feed = |seed: u64| {
            let mut rng = Rng::new(seed);
            let mut s = QuantileSketch::new(32);
            for _ in 0..5000 {
                s.insert(rng.lognormal(7.0, 1.0));
            }
            (s.quantile(0.5), s.quantile(0.9), s.samples.len())
        };
        let a = feed(3);
        let b = feed(3);
        assert_eq!(a, b, "same stream must reproduce the same sketch");
        assert_eq!(a.2, 32, "memory stays at capacity");
        // non-finite values are ignored, not stored
        let mut s = QuantileSketch::new(8);
        s.insert(f64::NAN);
        s.insert(f64::INFINITY);
        assert_eq!(s.seen(), 0);
        assert_eq!(s.quantile(0.5), 0.0);
    }

    #[test]
    fn ewma_converges_to_a_shifted_mean() {
        let p = LengthPredictor::new(PredictorCfg::default());
        for _ in 0..50 {
            p.record(1, 100);
        }
        assert!((p.predict(1) - 100.0).abs() < 1e-9, "constant stream converges exactly");
        // the group's generations get 10x longer (curriculum shift):
        // the EWMA must track within ~20 completions at beta=0.2
        for _ in 0..20 {
            p.record(1, 1000);
        }
        let est = p.predict(1);
        assert!(est > 900.0, "EWMA must converge toward the new regime: {est}");
    }

    #[test]
    fn cold_start_falls_back_group_then_global_then_default() {
        let cfg = PredictorCfg { min_samples: 4, default_len: 256.0, ..Default::default() };
        let p = LengthPredictor::new(cfg);
        // nothing observed anywhere: the default
        assert_eq!(p.predict(7), 256.0);
        // global warm, group 7 cold: the global estimate
        for _ in 0..6 {
            p.record(1, 5000);
        }
        assert!((p.predict(7) - 5000.0).abs() < 1e-9, "cold group uses the global fallback");
        // group 7 crosses min_samples: its own stats take over
        for _ in 0..4 {
            p.record(7, 40);
        }
        assert!((p.predict(7) - 40.0).abs() < 1e-9, "warm group trusts itself");
        // a group below min_samples still uses the fallback
        p.record(9, 9999);
        assert!((p.predict(9) - p.snapshot().mean).abs() < 1e-9);
    }

    #[test]
    fn prediction_never_exceeds_budget_or_drops_below_one() {
        // the bugfix regression: a wild estimate (or an empty group)
        // must clamp into [1, budget] so no placement can imply more
        // tokens than the row the budget was sized for
        let p = LengthPredictor::new(PredictorCfg::default());
        for _ in 0..20 {
            p.record(1, 1_000_000); // pathological observations
        }
        assert_eq!(p.predict_for(1, 128), 128, "clamped to the budget");
        assert_eq!(p.predict_for(1, 0), 1, "degenerate budget still yields a sane value");
        // zero-sample group: default_len, clamped the same way
        assert_eq!(p.predict_for(42, 64), 64);
        let tiny = LengthPredictor::new(PredictorCfg {
            default_len: 1.0,
            ..PredictorCfg::default()
        });
        assert_eq!(tiny.predict_for(42, 64), 1, "floor holds at 1 token");
    }

    #[test]
    fn classify_splits_the_tail_and_is_cold_start_safe() {
        let cfg = PredictorCfg { min_samples: 8, long_quantile: 0.8, ..Default::default() };
        let p = LengthPredictor::new(cfg);
        assert!(!p.classify(1e9), "cold start classifies everything short (FIFO degrade)");
        // 100 short + 10 long completions: the p80 sits inside the
        // short mass, so only the tail classifies long
        for i in 0..100 {
            p.record(i % 4, 100 + i);
        }
        for _ in 0..10 {
            p.record(99, 30_000);
        }
        assert!(p.classify(30_000.0), "tail lengths are long");
        assert!(!p.classify(50.0), "short lengths are short");
        let snap = p.snapshot();
        assert_eq!(snap.samples, 110);
        assert!(snap.p90 >= snap.p50, "{snap:?}");
        assert!(snap.mean > 0.0);
    }
}
