//! Event-driven rollout execution (replaces the thread-per-episode
//! EnvManager):
//!
//!   * [`episode`] — per-lane episode state machines
//!     (WaitingTicket -> Generating -> SteppingEnv -> Scoring) and the
//!     shared [`GroupTasks`] episode numbering,
//!   * [`engine`] — the [`RolloutEngine`] that multiplexes hundreds of
//!     lanes over a fixed worker pool, driven by fleet completion
//!     events, a timer wheel, and SampleBuffer hooks; home of the real
//!     redundant-environment-rollout policy (Section 5.2.2).

pub mod engine;
pub mod episode;

pub use engine::{EngineCfg, EngineReport, GenBackend, RolloutEngine};
pub use episode::{pack_group_key, Episode, EpisodeState, GroupTasks};
