//! The event-driven RolloutEngine: hundreds of episode state machines
//! multiplexed over a small fixed worker pool.
//!
//! The thread-per-episode EnvManager capped concurrency at the OS
//! thread count and burned it on blocking `recv`s and real `sleep`s.
//! The engine inverts the control flow (the Laminar/AsyncFlow
//! trajectory-level execution model): one coordinator thread reacts to
//! completion events —
//!
//!   * generation results from the inference fleet, delivered on ONE
//!     shared reply channel and demultiplexed by pool id,
//!   * env `reset`/`poll_step` outcomes computed by `num_workers`
//!     pool threads (the only place environment code runs),
//!   * a hashed timer wheel for simulated env latency and generation
//!     hang watchdogs (no thread ever sleeps on behalf of an episode),
//!   * SampleBuffer hooks: capacity (admission tickets freed) and
//!     group completion.
//!
//! Redundant environment rollout (Section 5.2.2) is native here: with
//! `redundancy_factor > 1` each group gets spare lanes racing the same
//! (group, episode) task; the first `group_size` completions win and
//! the group-completion hook aborts the losers' in-flight generations
//! via the backend (`proxy.abort`), reclaiming their tickets — surplus
//! work is cancelled, not completed.
//!
//! Generations go to the backend as resumable [`GenerationTask`]s: the
//! hang watchdog's `migrate` is a *non-blocking* reclaim — the fleet
//! parks the request, salvages the decoded prefix via its own
//! collectors (or reclaims it in place when every peer is saturated),
//! and the episode keeps waiting on the same reply; the watchdog call
//! itself never stalls the event thread. Redundancy losers and
//! shutdown use plain `abort` — there is no episode left to resume
//! for, so the work is reclaimed outright.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::fleet::LlmProxyPool;
use crate::coordinator::llm_proxy::{GenResult, GenerationTask, ProxyEvent};
use crate::coordinator::rollout::episode::{Episode, EpisodeState, GroupTasks};
use crate::coordinator::sample_buffer::{Admission, SampleBuffer};
use crate::env::{BaseEnv, PendingStep, StepResult};
use crate::metrics::registry::{Counter, Gauge, MetricsRegistry};

/// Give up on an episode after this many generation-hang strikes.
const MAX_GEN_MIGRATIONS: u32 = 3;

/// Timer wheel resolution; also the engine's idle heartbeat.
const WHEEL_GRANULARITY: Duration = Duration::from_millis(1);
const WHEEL_SLOTS: usize = 256;

/// Longest the engine blocks for events before re-checking stop.
const HEARTBEAT: Duration = Duration::from_millis(50);

/// The slice of the inference fleet the engine needs. `LlmProxyPool`
/// is the production backend; tests substitute deterministic mocks.
pub trait GenBackend: Send + Sync {
    /// Route a resumable [`GenerationTask`]; the result arrives on the
    /// task's reply sender carrying the returned id. `None` means the
    /// request cannot be accepted at all (the whole fleet is dead) and
    /// was dropped — callers must not wait for a reply.
    fn submit(&self, task: GenerationTask) -> Option<u64>;
    /// Interrupt and reclaim a request outright (no-op for finished
    /// ids). Used where the episode is over — redundancy losers,
    /// shutdown — so there is nothing to salvage *for*.
    fn abort(&self, id: u64);
    /// Move a presumed-hung request off its replica (or reclaim it in
    /// place when the pool is saturated), keeping its reply channel;
    /// the backend salvages the decoded prefix asynchronously when
    /// configured to — the call never blocks on the salvage. `false` =
    /// nowhere to move it.
    fn migrate(&self, id: u64) -> bool {
        let _ = id;
        false
    }
}

impl GenBackend for LlmProxyPool {
    fn submit(&self, task: GenerationTask) -> Option<u64> {
        LlmProxyPool::try_submit(self, task)
    }

    fn abort(&self, id: u64) {
        LlmProxyPool::abort(self, id)
    }

    fn migrate(&self, id: u64) -> bool {
        LlmProxyPool::migrate(self, id)
    }
}

/// Engine shape and behavior knobs (`num_workers`, `redundancy_factor`
/// in YAML / CLI).
#[derive(Clone, Copy, Debug)]
pub struct EngineCfg {
    /// env fleet: groups x members (the consumption-facing shape)
    pub num_env_groups: usize,
    pub env_group_size: usize,
    /// env worker pool size — the ONLY threads that run env code
    pub num_workers: usize,
    /// episodes provisioned per group, as a multiple of group size:
    /// lanes_per_group = ceil(env_group_size * redundancy_factor).
    /// 1.0 = exact provisioning; > 1.0 enables redundant rollout
    pub redundancy_factor: f64,
    /// scale simulated env latency into real timer deadlines
    /// (0.0 = observations are ready immediately)
    pub latency_scale: f64,
    /// generation hang watchdog: migrate after this many wall seconds,
    /// abandon after MAX_GEN_MIGRATIONS strikes
    pub hang_timeout: f64,
    pub seed: u64,
}

impl EngineCfg {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_env_groups > 0, "num_env_groups must be > 0");
        anyhow::ensure!(self.env_group_size > 0, "env_group_size must be > 0");
        anyhow::ensure!(self.num_workers > 0, "num_workers must be > 0 (empty worker pool)");
        anyhow::ensure!(
            self.redundancy_factor.is_finite() && self.redundancy_factor >= 1.0,
            "redundancy_factor must be >= 1.0 (got {})",
            self.redundancy_factor
        );
        anyhow::ensure!(self.latency_scale >= 0.0, "latency_scale must be >= 0");
        Ok(())
    }

    /// Lanes per group including redundant spares. The epsilon keeps
    /// f64 round-up noise (e.g. 10 * 1.1 = 11.000000000000002) from
    /// silently over-provisioning an extra lane.
    pub fn lanes_per_group(&self) -> usize {
        (self.env_group_size as f64 * self.redundancy_factor - 1e-9).ceil() as usize
    }

    /// Total episode lanes the engine multiplexes.
    pub fn total_lanes(&self) -> usize {
        self.num_env_groups * self.lanes_per_group()
    }
}

/// Engine statistics, folded into the FleetReport at shutdown.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineReport {
    /// trajectories pushed into the SampleBuffer
    pub episodes: usize,
    /// in-flight generations aborted because their group completed
    /// first (redundant rollout losers)
    pub redundant_aborts: u64,
    /// episodes cancelled in env/timer states for the same reason
    pub redundant_cancels: u64,
    /// hung generations migrated to another replica
    pub gen_migrations: u64,
    /// episodes abandoned (hung past all strikes, env fail-stop, or
    /// the whole inference fleet gone)
    pub abandoned: u64,
    /// lanes permanently lost to a panicking environment
    pub lane_failures: u64,
    /// completed episodes won by a redundant spare lane — how often
    /// over-provisioning actually rescued a group
    pub spare_wins: u64,
    /// timer-wheel deadlines that fired (obs latency + hang watchdog)
    pub timers_fired: u64,
    /// peak concurrently admitted episodes (tickets held at once)
    pub peak_inflight: usize,
    /// adaptive `redundancy_factor` hint (log-only, no behavior
    /// change): the factor that would hide the observed
    /// fail-slow/fail-stop rate — `1/(1-p)` over this run's hang
    /// migrations, abandonments, and lane deaths, floored at the
    /// configured factor and capped at 3x
    /// (`metrics::telemetry::redundancy_hint`). Equals the configured
    /// factor on a clean run.
    pub redundancy_hint: f64,
}

/// Engine-side handles into the fleet's central [`MetricsRegistry`]:
/// the same tallies as [`EngineReport`], but live — windowed snapshots
/// and the shutdown metrics export see them without waiting for the
/// engine to join. Absent when the caller has no registry (mock-backend
/// tests).
struct EngineMetrics {
    episodes: Counter,
    redundant_aborts: Counter,
    redundant_cancels: Counter,
    gen_migrations: Counter,
    abandoned: Counter,
    lane_failures: Counter,
    spare_wins: Counter,
    timers_fired: Counter,
    tickets_held: Gauge,
    /// adaptive redundancy hint published at engine shutdown
    redundancy_hint: Gauge,
}

impl EngineMetrics {
    fn new(reg: &MetricsRegistry) -> Self {
        EngineMetrics {
            episodes: reg.counter("engine.episodes"),
            redundant_aborts: reg.counter("engine.redundant_aborts"),
            redundant_cancels: reg.counter("engine.redundant_cancels"),
            gen_migrations: reg.counter("engine.gen_migrations"),
            abandoned: reg.counter("engine.abandoned"),
            lane_failures: reg.counter("engine.lane_failures"),
            spare_wins: reg.counter("engine.spare_wins"),
            timers_fired: reg.counter("engine.timers_fired"),
            tickets_held: reg.gauge("engine.tickets_held"),
            redundancy_hint: reg.gauge("engine.redundancy_hint"),
        }
    }
}

/// Everything that wakes the engine.
enum Event {
    /// a generation finished (forwarded from the shared reply channel)
    Gen(GenResult),
    /// a worker finished `reset`
    ResetDone { lane: usize, env: Box<dyn BaseEnv>, prompt: Vec<i32> },
    /// a worker finished `poll_step`
    Stepped { lane: usize, env: Box<dyn BaseEnv>, step: PendingStep },
    /// admission capacity may be available (or the buffer shut down)
    Tickets,
    /// group `key` completed (or was burned) — cancel surplus members
    GroupDone(u64),
    /// the lane's environment panicked on a worker; its env is lost and
    /// the lane can never run again
    LaneFailed { lane: usize },
}

/// Work shipped to the env worker pool. The env travels with the item
/// and comes home inside the completion event.
enum Work {
    Reset { lane: usize, env: Box<dyn BaseEnv>, seed: u64 },
    Step { lane: usize, env: Box<dyn BaseEnv>, action: Vec<i32> },
}

fn worker_loop(rx: Arc<Mutex<Receiver<Work>>>, tx: Sender<Event>) {
    loop {
        let work = { rx.lock().unwrap().recv() };
        let Ok(work) = work else { return };
        // a panicking env must not wedge the engine: catch it, drop the
        // (possibly corrupt) env, and report the lane as failed so its
        // ticket is reclaimed and shutdown still converges
        let event = match work {
            Work::Reset { lane, mut env, seed } => {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| env.reset(seed))) {
                    Ok(prompt) => Event::ResetDone { lane, env, prompt },
                    Err(_) => Event::LaneFailed { lane },
                }
            }
            Work::Step { lane, mut env, action } => {
                match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    env.poll_step(&action)
                })) {
                    Ok(step) => Event::Stepped { lane, env, step },
                    Err(_) => Event::LaneFailed { lane },
                }
            }
        };
        if tx.send(event).is_err() {
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Timer wheel
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TimerKind {
    /// a parked env observation becomes visible
    ObsReady,
    /// generation hang watchdog
    GenHang,
}

#[derive(Clone, Copy, Debug)]
struct Timer {
    due_tick: u64,
    lane: usize,
    kind: TimerKind,
    /// must match the lane's `timer_epoch` to fire
    epoch: u64,
}

/// Hashed timer wheel: WHEEL_SLOTS buckets of WHEEL_GRANULARITY each;
/// entries farther out than one revolution stay bucketed by
/// `due_tick % slots` and are skipped until their round arrives.
struct TimerWheel {
    slots: Vec<Vec<Timer>>,
    origin: Instant,
    /// next tick index to collect (all earlier ticks have fired)
    cursor: u64,
    len: usize,
}

impl TimerWheel {
    fn new() -> Self {
        TimerWheel {
            slots: (0..WHEEL_SLOTS).map(|_| Vec::new()).collect(),
            origin: Instant::now(),
            cursor: 0,
            len: 0,
        }
    }

    fn tick_of(&self, t: Instant) -> u64 {
        (t.saturating_duration_since(self.origin).as_nanos() / WHEEL_GRANULARITY.as_nanos()) as u64
    }

    fn schedule(&mut self, delay: Duration, lane: usize, kind: TimerKind, epoch: u64) {
        let due_tick = self.tick_of(Instant::now() + delay).max(self.cursor);
        let slot = (due_tick % WHEEL_SLOTS as u64) as usize;
        self.slots[slot].push(Timer { due_tick, lane, kind, epoch });
        self.len += 1;
    }

    /// Collect every timer due at or before `now` into `out`. Entries
    /// rejected by `keep` (stale epochs: the awaited thing already
    /// happened) are pruned as their slot is revisited — each slot
    /// comes around once per wheel revolution, so a long-dated watchdog
    /// whose generation already finished does not linger for its full
    /// nominal delay.
    fn expire(&mut self, now: Instant, keep: impl Fn(&Timer) -> bool, out: &mut Vec<Timer>) {
        if self.len == 0 {
            self.cursor = self.tick_of(now) + 1;
            return;
        }
        let target = self.tick_of(now);
        if target < self.cursor {
            return;
        }
        // cap the walk at one revolution: a longer sleep visits every
        // slot exactly once either way
        let steps = (target - self.cursor + 1).min(WHEEL_SLOTS as u64);
        let walk_all = steps == WHEEL_SLOTS as u64;
        for k in 0..steps {
            let slot = if walk_all { k } else { (self.cursor + k) % WHEEL_SLOTS as u64 } as usize;
            let bucket = &mut self.slots[slot];
            let mut i = 0;
            while i < bucket.len() {
                if !keep(&bucket[i]) {
                    bucket.swap_remove(i);
                    self.len -= 1;
                } else if bucket[i].due_tick <= target {
                    out.push(bucket.swap_remove(i));
                    self.len -= 1;
                } else {
                    i += 1;
                }
            }
        }
        self.cursor = target + 1;
        out.sort_by_key(|t| t.due_tick);
    }

    /// Earliest pending deadline (end of its tick), if any.
    fn next_deadline(&self) -> Option<Instant> {
        if self.len == 0 {
            return None;
        }
        self.slots.iter().flatten().map(|t| t.due_tick).min().map(|tick| {
            self.origin + Duration::from_nanos((WHEEL_GRANULARITY.as_nanos() as u64) * (tick + 1))
        })
    }
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// Handle to the running engine thread.
pub struct RolloutEngine {
    join: Option<JoinHandle<EngineReport>>,
    event_tx: Sender<Event>,
}

impl RolloutEngine {
    /// Spawn the engine: one coordinator thread, `num_workers` env
    /// workers, and a completion forwarder. `envs` supplies one
    /// environment per lane, in (group-major, member-minor) order with
    /// `cfg.lanes_per_group()` members per group.
    pub fn start(
        cfg: EngineCfg,
        backend: Arc<dyn GenBackend>,
        buffer: Arc<SampleBuffer>,
        stop: Arc<AtomicBool>,
        envs: Vec<Box<dyn BaseEnv>>,
    ) -> Result<Self> {
        Self::start_with_metrics(cfg, backend, buffer, stop, envs, None)
    }

    /// Like [`Self::start`], but the engine also mirrors its report
    /// tallies into `registry` counters (`engine.*`) as they happen —
    /// `RolloutSystem` hands over the pool's central registry so one
    /// metrics export covers both layers.
    pub fn start_with_metrics(
        cfg: EngineCfg,
        backend: Arc<dyn GenBackend>,
        buffer: Arc<SampleBuffer>,
        stop: Arc<AtomicBool>,
        envs: Vec<Box<dyn BaseEnv>>,
        registry: Option<Arc<MetricsRegistry>>,
    ) -> Result<Self> {
        cfg.validate()?;
        anyhow::ensure!(
            envs.len() == cfg.total_lanes(),
            "expected {} envs ({} groups x {} lanes), got {}",
            cfg.total_lanes(),
            cfg.num_env_groups,
            cfg.lanes_per_group(),
            envs.len()
        );
        let (event_tx, event_rx) = channel::<Event>();
        let (gen_tx, gen_rx) = channel::<ProxyEvent>();
        let (work_tx, work_rx) = channel::<Work>();
        let work_rx = Arc::new(Mutex::new(work_rx));

        // buffer hooks wake the engine instead of blocking producers
        let tx = event_tx.clone();
        buffer.set_capacity_hook(Box::new(move || {
            let _ = tx.send(Event::Tickets);
        }));
        let tx = event_tx.clone();
        buffer.set_group_hook(Box::new(move |key| {
            let _ = tx.send(Event::GroupDone(key));
        }));

        // completion forwarder: shared reply channel -> event stream.
        // The engine never issues RECLAIMs against its own channel
        // (the pool's collectors absorb those internally), so reclaim
        // answers are structurally absent; only completions flow.
        let tx = event_tx.clone();
        std::thread::Builder::new()
            .name("rollout-gen-fwd".into())
            .spawn(move || {
                while let Ok(ev) = gen_rx.recv() {
                    let ProxyEvent::Done(res) = ev else { continue };
                    if tx.send(Event::Gen(res)).is_err() {
                        return;
                    }
                }
            })
            .expect("spawn rollout gen forwarder");

        // the fixed env worker pool
        for w in 0..cfg.num_workers {
            let rx = work_rx.clone();
            let tx = event_tx.clone();
            std::thread::Builder::new()
                .name(format!("rollout-worker-{w}"))
                .spawn(move || worker_loop(rx, tx))
                .expect("spawn rollout worker");
        }

        let lanes_per_group = cfg.lanes_per_group();
        let episodes: Vec<Episode> = envs
            .into_iter()
            .enumerate()
            .map(|(lane, env)| {
                let (group, member) = (lane / lanes_per_group, lane % lanes_per_group);
                Episode::new(group, member, member >= cfg.env_group_size, env)
            })
            .collect();
        let tasks = GroupTasks::new(cfg.num_env_groups, lanes_per_group, cfg.seed);

        let mut inner = EngineLoop {
            cfg,
            backend,
            buffer,
            tasks,
            stop,
            episodes,
            retired: vec![false; cfg.total_lanes()],
            idle: 0,
            gen_map: HashMap::new(),
            by_key: HashMap::new(),
            waiting: VecDeque::new(),
            tickets_held: 0,
            work_tx,
            gen_tx,
            wheel: TimerWheel::new(),
            report: EngineReport::default(),
            metrics: registry.map(|r| EngineMetrics::new(&r)),
        };
        let join = std::thread::Builder::new()
            .name("rollout-engine".into())
            .spawn(move || inner.run(event_rx))
            .expect("spawn rollout engine");
        Ok(RolloutEngine { join: Some(join), event_tx })
    }

    /// Join the engine (the caller must have set the stop flag and shut
    /// the buffer down first; this just wakes and waits).
    pub fn shutdown(mut self) -> Result<EngineReport> {
        let _ = self.event_tx.send(Event::Tickets); // wake to observe stop
        match self.join.take() {
            Some(h) => h.join().map_err(|_| anyhow::anyhow!("rollout engine panicked")),
            None => anyhow::bail!("engine already shut down"),
        }
    }
}

struct EngineLoop {
    cfg: EngineCfg,
    backend: Arc<dyn GenBackend>,
    buffer: Arc<SampleBuffer>,
    tasks: GroupTasks,
    stop: Arc<AtomicBool>,
    episodes: Vec<Episode>,
    /// lanes permanently idled (shutdown); engine exits when all are
    retired: Vec<bool>,
    idle: usize,
    /// generation pool id -> lane
    gen_map: HashMap<u64, usize>,
    /// group key -> lanes currently rolling it (redundancy bookkeeping)
    by_key: HashMap<u64, Vec<usize>>,
    /// lanes waiting for an admission ticket, FIFO
    waiting: VecDeque<usize>,
    tickets_held: usize,
    work_tx: Sender<Work>,
    gen_tx: Sender<ProxyEvent>,
    wheel: TimerWheel,
    report: EngineReport,
    metrics: Option<EngineMetrics>,
}

impl EngineLoop {
    fn run(&mut self, event_rx: Receiver<Event>) -> EngineReport {
        for lane in 0..self.episodes.len() {
            self.start_next(lane);
        }
        let mut due: Vec<Timer> = Vec::new();
        while self.idle < self.episodes.len() {
            due.clear();
            {
                let (episodes, retired) = (&self.episodes, &self.retired);
                self.wheel.expire(
                    Instant::now(),
                    |t| !retired[t.lane] && episodes[t.lane].timer_epoch == t.epoch,
                    &mut due,
                );
            }
            for t in due.drain(..) {
                self.handle_timer(t);
            }
            let timeout = self
                .wheel
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(HEARTBEAT)
                .min(HEARTBEAT);
            match event_rx.recv_timeout(timeout) {
                Ok(ev) => {
                    self.handle(ev);
                    while let Ok(ev) = event_rx.try_recv() {
                        self.handle(ev);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
            if self.stop.load(Ordering::Relaxed) {
                self.drain_on_stop();
            }
        }
        // If the engine wound down on its own (fleet dead, every lane
        // failed), unblock the consumer: get_batch must error out, not
        // wait forever for producers that no longer exist. Idempotent
        // on the normal stop path (the caller already shut it down).
        self.buffer.shutdown();
        // observed fail-slow/fail-stop rate -> adaptive redundancy
        // hint (log-only): failures over attempts, where an attempt is
        // a completed episode or a failure event
        let failures =
            self.report.gen_migrations + self.report.abandoned + self.report.lane_failures;
        let attempts = self.report.episodes as u64 + failures;
        let rate = if attempts == 0 { 0.0 } else { failures as f64 / attempts as f64 };
        self.report.redundancy_hint =
            crate::metrics::telemetry::redundancy_hint(self.cfg.redundancy_factor, rate);
        self.bump(|m| m.redundancy_hint.set(self.report.redundancy_hint));
        self.report
    }

    /// Mirror a report increment into the live registry, if attached.
    fn bump(&self, f: impl Fn(&EngineMetrics)) {
        if let Some(m) = &self.metrics {
            f(m);
        }
    }

    fn note_tickets(&self) {
        self.bump(|m| m.tickets_held.set(self.tickets_held as f64));
    }

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::Gen(res) => self.on_generation(res),
            Event::ResetDone { lane, env, prompt } => self.on_reset_done(lane, env, prompt),
            Event::Stepped { lane, env, step } => self.on_stepped(lane, env, step),
            Event::Tickets => self.on_tickets(),
            Event::GroupDone(key) => self.on_group_done(key),
            Event::LaneFailed { lane } => self.on_lane_failed(lane),
        }
    }

    // --- state machine transitions -------------------------------------

    fn on_generation(&mut self, res: GenResult) {
        let Some(lane) = self.gen_map.remove(&res.id) else {
            return; // aborted/abandoned: stale completion
        };
        let ep = &mut self.episodes[lane];
        ep.timer_epoch += 1; // disarm the hang watchdog
        if ep.cancelled {
            self.cancel_episode(lane);
            return;
        }
        ep.absorb_action(&res);
        ep.state = EpisodeState::SteppingEnv;
        let env = ep.env.take().expect("env home while generating");
        let _ = self.work_tx.send(Work::Step { lane, env, action: res.tokens });
    }

    fn on_reset_done(&mut self, lane: usize, env: Box<dyn BaseEnv>, prompt: Vec<i32>) {
        let ep = &mut self.episodes[lane];
        ep.env = Some(env);
        if ep.cancelled || self.stop.load(Ordering::Relaxed) {
            self.cancel_episode(lane);
            return;
        }
        ep.absorb_prompt(prompt);
        self.submit_generation(lane);
    }

    fn on_stepped(&mut self, lane: usize, env: Box<dyn BaseEnv>, step: PendingStep) {
        let ep = &mut self.episodes[lane];
        ep.env = Some(env);
        if ep.cancelled || self.stop.load(Ordering::Relaxed) {
            self.cancel_episode(lane);
            return;
        }
        if step.result.latency > self.cfg.hang_timeout {
            // fail-stop env: the step took longer than we tolerate
            self.report.abandoned += 1;
            self.bump(|m| m.abandoned.inc());
            self.cancel_episode(lane);
            return;
        }
        if self.cfg.latency_scale > 0.0 && step.ready_in > 0.0 {
            // park the observation behind its latency deadline
            ep.pending = Some(step.result);
            ep.timer_epoch += 1;
            let delay = Duration::from_secs_f64(step.ready_in * self.cfg.latency_scale);
            let epoch = ep.timer_epoch;
            self.wheel.schedule(delay, lane, TimerKind::ObsReady, epoch);
            return;
        }
        self.finish_step(lane, step.result);
    }

    fn finish_step(&mut self, lane: usize, result: StepResult) {
        let ep = &mut self.episodes[lane];
        ep.turn += 1;
        if result.done {
            self.complete_episode(lane, result.reward.unwrap_or(0.0));
        } else if ep.turn >= ep.max_steps {
            // turn budget exhausted without a terminal signal
            self.complete_episode(lane, 0.0);
        } else {
            ep.absorb_obs(&result.obs);
            self.submit_generation(lane);
        }
    }

    fn submit_generation(&mut self, lane: usize) {
        let ep = &mut self.episodes[lane];
        let mut task =
            GenerationTask::fresh(ep.context.clone(), ep.max_new_tokens, self.gen_tx.clone());
        // prompt-group identity for the pool's length predictor: members
        // of the same env group share a generation-length distribution
        task.group = ep.group as u64;
        // conversation identity for the pool's KV-prefix index: one
        // multi-turn episode instance is (group_key, member), so every
        // turn of the same conversation carries the same stamp and the
        // cache-aware router can send its growing context back to the
        // replica that already holds it
        task.conversation =
            ep.group_key.wrapping_mul(0x9e3779b97f4a7c15) ^ (ep.member as u64 + 1);
        let submitted = self.backend.submit(task);
        let Some(gen_id) = submitted else {
            // the whole inference fleet is dead: this lane can never
            // make progress — reclaim the ticket and retire it so the
            // engine winds down instead of waiting on a reply that was
            // dropped without a disconnect signal
            self.report.abandoned += 1;
            self.bump(|m| m.abandoned.inc());
            self.fail_lane(lane);
            return;
        };
        let ep = &mut self.episodes[lane];
        ep.state = EpisodeState::Generating { gen_id, strikes: 0 };
        ep.timer_epoch += 1;
        self.gen_map.insert(gen_id, lane);
        if self.cfg.hang_timeout.is_finite() && self.cfg.hang_timeout > 0.0 {
            let epoch = self.episodes[lane].timer_epoch;
            self.wheel.schedule(
                Duration::from_secs_f64(self.cfg.hang_timeout),
                lane,
                TimerKind::GenHang,
                epoch,
            );
        }
    }

    fn handle_timer(&mut self, t: Timer) {
        let ep = &mut self.episodes[t.lane];
        if self.retired[t.lane] || ep.timer_epoch != t.epoch {
            return; // stale: the awaited thing already happened
        }
        self.report.timers_fired += 1;
        self.bump(|m| m.timers_fired.inc());
        match t.kind {
            TimerKind::ObsReady => {
                if ep.cancelled {
                    self.cancel_episode(t.lane);
                    return;
                }
                let Some(result) = ep.pending.take() else { return };
                self.finish_step(t.lane, result);
            }
            TimerKind::GenHang => {
                let EpisodeState::Generating { gen_id, strikes } = ep.state else { return };
                let strikes = strikes + 1;
                if strikes > MAX_GEN_MIGRATIONS {
                    self.backend.abort(gen_id);
                    self.gen_map.remove(&gen_id);
                    self.report.abandoned += 1;
                    self.bump(|m| m.abandoned.inc());
                    self.cancel_episode(t.lane);
                    return;
                }
                // migrate() is false when there is nowhere to move the
                // request (single replica, peers suspended) or it raced
                // a completion; either way keep watching
                if self.backend.migrate(gen_id) {
                    self.report.gen_migrations += 1;
                    self.bump(|m| m.gen_migrations.inc());
                }
                self.episodes[t.lane].state = EpisodeState::Generating { gen_id, strikes };
                self.wheel.schedule(
                    Duration::from_secs_f64(self.cfg.hang_timeout),
                    t.lane,
                    TimerKind::GenHang,
                    t.epoch,
                );
            }
        }
    }

    // --- admission and redundancy --------------------------------------

    fn on_tickets(&mut self) {
        while let Some(&lane) = self.waiting.front() {
            if self.retired[lane] {
                self.waiting.pop_front();
                continue;
            }
            match self.buffer.try_begin_sample() {
                Admission::Granted(version) => {
                    self.waiting.pop_front();
                    self.begin_episode(lane, version);
                }
                Admission::Full => break,
                Admission::Shutdown => {
                    while let Some(lane) = self.waiting.pop_front() {
                        if !self.retired[lane] {
                            self.retire(lane);
                        }
                    }
                    break;
                }
            }
        }
    }

    fn begin_episode(&mut self, lane: usize, init_version: u64) {
        let (group, member) = (self.episodes[lane].group, self.episodes[lane].member);
        // skip keys whose group already completed/burned (a lagging
        // spare lane would only ever produce surplus there)
        let (key, seed) = loop {
            let (key, seed) = self.tasks.next(group, member);
            if !self.buffer.group_completed(key) {
                break (key, seed);
            }
        };
        self.episodes[lane].begin(key, init_version);
        self.by_key.entry(key).or_default().push(lane);
        self.tickets_held += 1;
        self.note_tickets();
        self.report.peak_inflight = self.report.peak_inflight.max(self.tickets_held);
        let env = self.episodes[lane].env.take().expect("env home between episodes");
        let _ = self.work_tx.send(Work::Reset { lane, env, seed });
    }

    fn on_group_done(&mut self, key: u64) {
        let Some(lanes) = self.by_key.remove(&key) else { return };
        for lane in lanes {
            if self.retired[lane] || self.episodes[lane].group_key != key {
                continue;
            }
            match self.episodes[lane].state {
                EpisodeState::Generating { gen_id, .. } => {
                    // the headline redundancy mechanism: losers' decode
                    // work is reclaimed the moment the group completes
                    self.backend.abort(gen_id);
                    self.gen_map.remove(&gen_id);
                    self.report.redundant_aborts += 1;
                    self.bump(|m| m.redundant_aborts.inc());
                    self.cancel_episode(lane);
                }
                EpisodeState::SteppingEnv => {
                    self.report.redundant_cancels += 1;
                    self.bump(|m| m.redundant_cancels.inc());
                    if self.episodes[lane].env.is_some() {
                        self.cancel_episode(lane); // parked on a timer
                    } else {
                        self.episodes[lane].cancelled = true; // worker busy
                    }
                }
                EpisodeState::WaitingTicket | EpisodeState::Scoring => {}
            }
        }
    }

    // --- lane lifecycle -------------------------------------------------

    /// Finished episode: push the trajectory and roll the lane over.
    fn complete_episode(&mut self, lane: usize, reward: f32) {
        let key = self.episodes[lane].group_key;
        self.remove_from_key(lane, key);
        let traj = self.episodes[lane].finish(reward);
        self.tickets_held -= 1;
        self.report.episodes += 1;
        self.bump(|m| m.episodes.inc());
        self.note_tickets();
        if self.episodes[lane].redundant {
            self.report.spare_wins += 1;
            self.bump(|m| m.spare_wins.inc());
        }
        self.buffer.push(traj); // may fire capacity/group hooks
        self.start_next(lane);
    }

    /// The lane is permanently unusable (env panicked, or the fleet is
    /// gone): reclaim its ticket (if held) and retire it for good.
    fn fail_lane(&mut self, lane: usize) {
        let key = self.episodes[lane].group_key;
        self.remove_from_key(lane, key);
        self.tickets_held -= 1;
        self.note_tickets();
        self.buffer.cancel();
        if !self.retired[lane] {
            self.retire(lane);
        }
    }

    fn on_lane_failed(&mut self, lane: usize) {
        self.report.lane_failures += 1;
        self.bump(|m| m.lane_failures.inc());
        self.fail_lane(lane);
    }

    /// Abandoned/aborted episode: reclaim the ticket and roll over.
    fn cancel_episode(&mut self, lane: usize) {
        let key = self.episodes[lane].group_key;
        self.remove_from_key(lane, key);
        self.episodes[lane].cancelled = false;
        self.episodes[lane].pending = None;
        self.episodes[lane].timer_epoch += 1;
        self.tickets_held -= 1;
        self.note_tickets();
        self.buffer.cancel();
        self.start_next(lane);
    }

    /// Begin the lane's next episode (or park/retire it).
    fn start_next(&mut self, lane: usize) {
        if self.stop.load(Ordering::Relaxed) {
            self.retire(lane);
            return;
        }
        match self.buffer.try_begin_sample() {
            Admission::Granted(version) => self.begin_episode(lane, version),
            Admission::Full => {
                self.episodes[lane].state = EpisodeState::WaitingTicket;
                self.waiting.push_back(lane);
            }
            Admission::Shutdown => self.retire(lane),
        }
    }

    fn retire(&mut self, lane: usize) {
        debug_assert!(!self.retired[lane]);
        self.retired[lane] = true;
        self.idle += 1;
        let ep = &mut self.episodes[lane];
        ep.state = EpisodeState::WaitingTicket;
        ep.timer_epoch += 1;
    }

    fn remove_from_key(&mut self, lane: usize, key: u64) {
        if let Some(v) = self.by_key.get_mut(&key) {
            v.retain(|&l| l != lane);
            if v.is_empty() {
                self.by_key.remove(&key);
            }
        }
    }

    /// Stop requested: unwind every lane that is not mid-worker. Lanes
    /// whose env is on a worker finish via their completion event.
    fn drain_on_stop(&mut self) {
        for lane in 0..self.episodes.len() {
            if self.retired[lane] {
                continue;
            }
            match self.episodes[lane].state {
                EpisodeState::WaitingTicket => self.retire(lane),
                EpisodeState::Generating { gen_id, .. } => {
                    self.backend.abort(gen_id);
                    self.gen_map.remove(&gen_id);
                    self.tickets_held -= 1;
                    self.note_tickets();
                    self.buffer.cancel();
                    self.retire(lane);
                }
                EpisodeState::SteppingEnv => {
                    if self.episodes[lane].env.is_some() {
                        self.episodes[lane].pending = None;
                        self.tickets_held -= 1;
                        self.note_tickets();
                        self.buffer.cancel();
                        self.retire(lane);
                    } else {
                        self.episodes[lane].cancelled = true;
                    }
                }
                EpisodeState::Scoring => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::math::MathEnv;
    use crate::env::vocab;
    use std::sync::atomic::AtomicU64;

    /// Replies to every submission immediately with a fixed completion.
    struct InstantBackend {
        next: AtomicU64,
        aborted: AtomicU64,
    }

    impl InstantBackend {
        fn new() -> Self {
            InstantBackend { next: AtomicU64::new(1), aborted: AtomicU64::new(0) }
        }
    }

    impl GenBackend for InstantBackend {
        fn submit(&self, task: GenerationTask) -> Option<u64> {
            let id = self.next.fetch_add(1, Ordering::Relaxed);
            let _ = task.reply.send(ProxyEvent::Done(GenResult {
                id,
                tokens: vec![vocab::digit(3), vocab::EOS],
                logps: vec![-0.1, -0.1],
                version: 0,
                prefix_version: 0,
            }));
            Some(id)
        }

        fn abort(&self, _id: u64) {
            self.aborted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Completes requests one at a time on a pacing thread, so a group
    /// race has deterministic winners and in-flight losers.
    struct PacedBackend {
        held: Mutex<VecDeque<(u64, Sender<ProxyEvent>)>>,
        next: AtomicU64,
        aborted: AtomicU64,
    }

    impl PacedBackend {
        fn new() -> Arc<Self> {
            Arc::new(PacedBackend {
                held: Mutex::new(VecDeque::new()),
                next: AtomicU64::new(1),
                aborted: AtomicU64::new(0),
            })
        }

        /// Release one held request (FIFO); true if one was released.
        fn release_one(&self) -> bool {
            let Some((id, reply)) = self.held.lock().unwrap().pop_front() else {
                return false;
            };
            let _ = reply.send(ProxyEvent::Done(GenResult {
                id,
                tokens: vec![vocab::digit(7), vocab::EOS],
                logps: vec![-0.2, -0.2],
                version: 0,
                prefix_version: 0,
            }));
            true
        }
    }

    impl GenBackend for PacedBackend {
        fn submit(&self, task: GenerationTask) -> Option<u64> {
            let id = self.next.fetch_add(1, Ordering::Relaxed);
            self.held.lock().unwrap().push_back((id, task.reply));
            Some(id)
        }

        fn abort(&self, id: u64) {
            self.held.lock().unwrap().retain(|(h, _)| *h != id);
            self.aborted.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Never replies; migrate always fails.
    struct BlackholeBackend {
        next: AtomicU64,
        aborted: AtomicU64,
    }

    impl GenBackend for BlackholeBackend {
        fn submit(&self, _task: GenerationTask) -> Option<u64> {
            Some(self.next.fetch_add(1, Ordering::Relaxed))
        }

        fn abort(&self, _id: u64) {
            self.aborted.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn cfg(groups: usize, group_size: usize, workers: usize, rf: f64) -> EngineCfg {
        EngineCfg {
            num_env_groups: groups,
            env_group_size: group_size,
            num_workers: workers,
            redundancy_factor: rf,
            latency_scale: 0.0,
            hang_timeout: f64::INFINITY,
            seed: 11,
        }
    }

    fn math_envs(n: usize) -> Vec<Box<dyn BaseEnv>> {
        (0..n).map(|_| Box::new(MathEnv::new()) as Box<dyn BaseEnv>).collect()
    }

    #[test]
    fn cfg_validation_and_lane_math() {
        assert!(cfg(4, 4, 8, 1.0).validate().is_ok());
        assert!(cfg(0, 4, 8, 1.0).validate().is_err());
        assert!(cfg(4, 4, 0, 1.0).validate().is_err());
        assert!(cfg(4, 4, 8, 0.5).validate().is_err());
        assert!(cfg(4, 4, 8, f64::NAN).validate().is_err());
        assert_eq!(cfg(4, 4, 8, 1.0).lanes_per_group(), 4);
        assert_eq!(cfg(4, 4, 8, 1.25).lanes_per_group(), 5);
        assert_eq!(cfg(4, 4, 8, 2.0).total_lanes(), 32);
        // f64 round-up noise must not over-provision: 10 * 1.1 is
        // 11.000000000000002 in binary floating point
        assert_eq!(cfg(1, 10, 8, 1.1).lanes_per_group(), 11);
        assert_eq!(cfg(1, 20, 8, 1.05).lanes_per_group(), 21);
    }

    #[test]
    fn wheel_orders_and_invalidates_by_round() {
        let mut w = TimerWheel::new();
        w.schedule(Duration::from_millis(2), 1, TimerKind::ObsReady, 0);
        w.schedule(Duration::from_millis(600), 2, TimerKind::ObsReady, 0); // > 1 revolution
        w.schedule(Duration::from_millis(5), 3, TimerKind::GenHang, 0);
        assert!(w.next_deadline().is_some());
        let mut out = Vec::new();
        w.expire(Instant::now() + Duration::from_millis(20), |_| true, &mut out);
        let lanes: Vec<usize> = out.iter().map(|t| t.lane).collect();
        assert_eq!(lanes, vec![1, 3], "future rounds must not fire early");
        out.clear();
        w.expire(Instant::now() + Duration::from_millis(700), |_| true, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].lane, 2);
        assert!(w.next_deadline().is_none());
    }

    #[test]
    fn wheel_prunes_stale_entries_without_firing_them() {
        let mut w = TimerWheel::new();
        // a long-dated watchdog whose generation already finished must
        // not survive for its nominal delay
        w.schedule(Duration::from_secs(3600), 1, TimerKind::GenHang, 0);
        w.schedule(Duration::from_millis(2), 2, TimerKind::ObsReady, 0);
        let mut out = Vec::new();
        // lane 1's epoch moved on: prune it while walking the slots
        w.expire(Instant::now() + Duration::from_millis(300), |t| t.lane != 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].lane, 2);
        assert!(w.next_deadline().is_none(), "stale watchdog must be gone");
    }

    /// The headline concurrency claim: 256 concurrent episodes on a
    /// worker pool of 8 threads, no artifacts needed.
    #[test]
    fn multiplexes_256_episodes_on_8_workers() {
        let groups = 64;
        let group_size = 4;
        let backend = Arc::new(InstantBackend::new());
        let buffer = Arc::new(SampleBuffer::new(groups * group_size, group_size, 0.0));
        let stop = Arc::new(AtomicBool::new(false));
        let engine = RolloutEngine::start(
            cfg(groups, group_size, 8, 1.0),
            backend.clone(),
            buffer.clone(),
            stop.clone(),
            math_envs(groups * group_size),
        )
        .unwrap();

        let samples = buffer.get_batch(groups).expect("full batch");
        assert_eq!(samples.len(), 256);
        let mut counts = std::collections::BTreeMap::new();
        for s in &samples {
            *counts.entry(s.group).or_insert(0usize) += 1;
            assert_eq!(s.response_mask.len(), s.response.len());
        }
        assert!(counts.values().all(|&c| c == group_size), "complete groups only");

        stop.store(true, Ordering::Relaxed);
        buffer.shutdown();
        let report = engine.shutdown().unwrap();
        assert!(report.episodes >= 256, "{report:?}");
        assert_eq!(
            report.peak_inflight, 256,
            "all 256 episodes must be admitted concurrently"
        );
    }

    /// Redundant rollout: spares race, winners fill the group, and the
    /// engine ABORTS the losers' in-flight generations — the buffer
    /// sees (almost) no surplus because losers never complete.
    #[test]
    fn redundancy_aborts_surplus_generations() {
        let groups = 2;
        let group_size = 4;
        let backend = PacedBackend::new();
        // alpha 3 => capacity 32 admits every lane (2 groups x 8 lanes)
        let buffer = Arc::new(SampleBuffer::new(groups * group_size, group_size, 3.0));
        let stop = Arc::new(AtomicBool::new(false));
        let engine = RolloutEngine::start(
            cfg(groups, group_size, 4, 2.0),
            backend.clone(),
            buffer.clone(),
            stop.clone(),
            math_envs(groups * group_size * 2),
        )
        .unwrap();

        // release generations one at a time until both groups complete
        let deadline = Instant::now() + Duration::from_secs(20);
        while buffer.ready_groups() < groups {
            assert!(Instant::now() < deadline, "groups never completed");
            backend.release_one();
            std::thread::sleep(Duration::from_micros(300));
        }
        let samples = buffer.get_batch(groups).expect("batch");
        assert_eq!(samples.len(), 8);

        stop.store(true, Ordering::Relaxed);
        buffer.shutdown();
        let report = engine.shutdown().unwrap();
        let stats = buffer.stats();
        assert!(
            report.redundant_aborts + report.redundant_cancels >= 1,
            "losers must be reclaimed: {report:?}"
        );
        assert!(backend.aborted.load(Ordering::Relaxed) >= 1, "proxy.abort must fire");
        assert!(
            stats.surplus <= 2,
            "losers are cancelled, not completed: surplus {} ({stats:?})",
            stats.surplus
        );
    }

    /// A fleet with zero live replicas must wind the engine down and
    /// unblock the consumer, not leave lanes waiting on replies that
    /// were silently dropped.
    #[test]
    fn dead_fleet_winds_down_instead_of_deadlocking() {
        struct DeadBackend;
        impl GenBackend for DeadBackend {
            fn submit(&self, _task: GenerationTask) -> Option<u64> {
                None
            }
            fn abort(&self, _id: u64) {}
        }
        let buffer = Arc::new(SampleBuffer::new(4, 4, 0.0));
        let stop = Arc::new(AtomicBool::new(false));
        let engine = RolloutEngine::start(
            cfg(1, 4, 2, 1.0),
            Arc::new(DeadBackend),
            buffer.clone(),
            stop,
            math_envs(4),
        )
        .unwrap();
        // the engine retires every lane and shuts the buffer down, so
        // the consumer errors out instead of waiting forever
        assert!(buffer.get_batch(1).is_none(), "get_batch must unblock, not hang");
        let report = engine.shutdown().unwrap();
        assert!(report.abandoned >= 4, "{report:?}");
        assert_eq!(report.episodes, 0);
    }

    /// An env that panics on a worker loses its lane but must not wedge
    /// the engine (the old thread-per-episode design surfaced this as a
    /// join error; the engine reports it and keeps going).
    #[test]
    fn panicking_env_fails_lane_without_wedging_shutdown() {
        struct PanicEnv;
        impl BaseEnv for PanicEnv {
            fn reset(&mut self, _s: u64) -> Vec<i32> {
                vec![vocab::BOS]
            }
            fn step(&mut self, _a: &[i32]) -> StepResult {
                panic!("env exploded")
            }
            fn max_steps(&self) -> usize {
                2
            }
            fn max_new_tokens(&self) -> usize {
                2
            }
            fn prompt_len(&self) -> usize {
                1
            }
        }
        let backend = Arc::new(InstantBackend::new());
        let buffer = Arc::new(SampleBuffer::new(1, 1, 0.0));
        let stop = Arc::new(AtomicBool::new(false));
        let engine = RolloutEngine::start(
            cfg(1, 1, 1, 1.0),
            backend,
            buffer.clone(),
            stop,
            vec![Box::new(PanicEnv)],
        )
        .unwrap();
        // reset succeeds, the instant generation lands, step panics:
        // the lane is failed, its ticket reclaimed, the engine exits
        let report = engine.shutdown().unwrap();
        assert_eq!(report.lane_failures, 1, "{report:?}");
        assert!(buffer.get_batch(1).is_none(), "no producers left: consumer unblocks");
        assert!(buffer.stats().cancelled >= 1, "the failed lane's ticket is reclaimed");
        // all attempts failed -> the adaptive hint saturates at its cap
        assert_eq!(report.redundancy_hint, 3.0, "{report:?}");
    }

    /// The hang watchdog abandons a generation after its strikes and
    /// reclaims the admission ticket.
    #[test]
    fn hang_watchdog_abandons_blackholed_generation() {
        let backend =
            Arc::new(BlackholeBackend { next: AtomicU64::new(1), aborted: AtomicU64::new(0) });
        let buffer = Arc::new(SampleBuffer::new(1, 1, 0.0));
        let stop = Arc::new(AtomicBool::new(false));
        let mut c = cfg(1, 1, 1, 1.0);
        c.hang_timeout = 0.01; // 4 strikes x 10ms
        let engine =
            RolloutEngine::start(c, backend.clone(), buffer.clone(), stop.clone(), math_envs(1))
                .unwrap();

        let deadline = Instant::now() + Duration::from_secs(10);
        while backend.aborted.load(Ordering::Relaxed) == 0 {
            assert!(Instant::now() < deadline, "watchdog never fired");
            std::thread::sleep(Duration::from_millis(5));
        }
        stop.store(true, Ordering::Relaxed);
        buffer.shutdown();
        let report = engine.shutdown().unwrap();
        assert!(report.abandoned >= 1, "{report:?}");
        assert!(report.timers_fired >= MAX_GEN_MIGRATIONS as u64 + 1);
        assert!(buffer.stats().cancelled >= 1, "ticket must be reclaimed");
    }
}
