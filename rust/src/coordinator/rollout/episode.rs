//! Episode state machines (paper Section 4.2 restructured for
//! event-driven execution): one [`Episode`] per rollout *lane*, owning
//! its environment and the partially assembled trajectory. The
//! [`RolloutEngine`](super::engine::RolloutEngine) multiplexes hundreds
//! of these over a fixed worker pool; every transition is driven by a
//! completion event (generation finished, env stepped, timer fired,
//! ticket freed), never by a blocking wait.
//!
//! Also home to [`GroupTasks`], the shared episode numbering that keeps
//! GRPO groups rolling the same task: members (and redundant spares) of
//! group g at episode e all derive the same `(group_key, task_seed)`.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::coordinator::llm_proxy::GenResult;
use crate::env::{BaseEnv, StepResult};
use crate::rl::Trajectory;

/// Bits of the packed group key reserved for the episode counter.
const EPISODE_BITS: u32 = 32;
const EPISODE_MASK: u64 = (1 << EPISODE_BITS) - 1;

/// Pack (group, episode) into the SampleBuffer group key. The episode
/// counter is masked into its 32-bit field so a runaway counter can
/// never silently bleed into (and collide with) another group's bits.
pub fn pack_group_key(grp: usize, episode: u64) -> u64 {
    debug_assert!(
        episode <= EPISODE_MASK,
        "episode counter {episode} overflows the {EPISODE_BITS}-bit key field (group {grp})"
    );
    ((grp as u64) << EPISODE_BITS) | (episode & EPISODE_MASK)
}

/// Shared episode numbering: members of a group must roll the same
/// task (GRPO needs multiple candidates per prompt), so the task seed
/// is derived from (group, episode-index-within-group). `members` is
/// the number of lanes per group *including* redundant spares — spare
/// lanes get their own counters but derive the same key/seed at the
/// same episode index, which is what makes their output interchangeable
/// with a regular member's (Section 5.2.2).
pub struct GroupTasks {
    base_seed: u64,
    members: usize,
    counters: Vec<AtomicU64>,
}

impl GroupTasks {
    pub fn new(num_groups: usize, members: usize, base_seed: u64) -> Self {
        GroupTasks {
            base_seed,
            members,
            counters: (0..num_groups * members).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Next (group_key, task_seed) for lane `member` of group `grp`.
    /// The lane's local episode counter picks the episode; all lanes at
    /// episode e of group g share a key and seed.
    pub fn next(&self, grp: usize, member: usize) -> (u64, u64) {
        let idx = grp * self.members + member;
        let episode = self.counters[idx].fetch_add(1, Ordering::Relaxed);
        let key = pack_group_key(grp, episode);
        let seed = self
            .base_seed
            .wrapping_mul(0x9e3779b97f4a7c15)
            .wrapping_add(key.wrapping_mul(0xd1342543de82ef95));
        (key, seed)
    }
}

/// Where an episode is in its lifecycle. Transitions:
/// `WaitingTicket -> SteppingEnv (reset) -> Generating -> SteppingEnv
/// -> ... -> Scoring`, then the lane restarts at `WaitingTicket`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EpisodeState {
    /// No admission ticket yet (freshness budget exhausted), or the
    /// lane is idle after shutdown.
    WaitingTicket,
    /// A generation request is in flight on the inference fleet.
    Generating {
        gen_id: u64,
        /// hang-timeout strikes accrued on this generation
        strikes: u32,
    },
    /// The env is applying reset/step on a worker, or its observation
    /// latency timer is pending.
    SteppingEnv,
    /// Terminal bookkeeping: the trajectory is being pushed.
    Scoring,
}

/// One rollout lane: a slot in the engine that runs episodes
/// back-to-back, each producing one trajectory for `group`.
pub struct Episode {
    pub group: usize,
    pub member: usize,
    /// spare lane (member index >= env_group_size): its episodes are
    /// expected to lose the race and be aborted (Section 5.2.2); wins
    /// are counted as `EngineReport::spare_wins`
    pub redundant: bool,
    pub state: EpisodeState,
    /// present when the env is "home"; None while a worker holds it
    pub env: Option<Box<dyn BaseEnv>>,
    /// env constants cached so they are readable while a worker holds it
    pub max_steps: usize,
    pub max_new_tokens: usize,
    /// group key of the current episode
    pub group_key: u64,
    pub init_version: u64,
    pub prompt: Vec<i32>,
    pub context: Vec<i32>,
    pub response: Vec<i32>,
    pub response_mask: Vec<f32>,
    pub logps: Vec<f32>,
    pub turn: usize,
    /// some absorbed generation straddled a weight update (a salvaged
    /// prefix resumed under newer weights, or an in-place swap landed
    /// mid-decode): the trajectory's behavior policy is piecewise
    pub cross_version: bool,
    /// a step outcome parked behind its latency-deadline timer
    pub pending: Option<StepResult>,
    /// the episode's group completed while its env work was in flight;
    /// cancel (reclaiming the ticket) at the next event for this lane
    pub cancelled: bool,
    /// invalidates timers scheduled for earlier episodes/generations
    pub timer_epoch: u64,
}

impl Episode {
    pub fn new(group: usize, member: usize, redundant: bool, env: Box<dyn BaseEnv>) -> Self {
        let (max_steps, max_new_tokens) = (env.max_steps(), env.max_new_tokens());
        Episode {
            group,
            member,
            redundant,
            state: EpisodeState::WaitingTicket,
            env: Some(env),
            max_steps,
            max_new_tokens,
            group_key: 0,
            init_version: 0,
            prompt: Vec::new(),
            context: Vec::new(),
            response: Vec::new(),
            response_mask: Vec::new(),
            logps: Vec::new(),
            turn: 0,
            cross_version: false,
            pending: None,
            cancelled: false,
            timer_epoch: 0,
        }
    }

    /// Start a fresh episode under an admission ticket.
    pub fn begin(&mut self, group_key: u64, init_version: u64) {
        self.group_key = group_key;
        self.init_version = init_version;
        self.prompt.clear();
        self.context.clear();
        self.response.clear();
        self.response_mask.clear();
        self.logps.clear();
        self.turn = 0;
        self.cross_version = false;
        self.pending = None;
        self.cancelled = false;
        self.timer_epoch += 1;
        self.state = EpisodeState::SteppingEnv; // reset runs on a worker
    }

    /// The env's reset finished: record the prompt and move to decode.
    pub fn absorb_prompt(&mut self, prompt: Vec<i32>) {
        self.context = prompt.clone();
        self.prompt = prompt;
    }

    /// A generation finished: action tokens are trainable and join the
    /// context. A completion whose salvaged prefix spans a weight
    /// update marks the whole trajectory piecewise-policy.
    pub fn absorb_action(&mut self, res: &GenResult) {
        if res.cross_version() {
            self.cross_version = true;
        }
        for (t, lp) in res.tokens.iter().zip(&res.logps) {
            self.response.push(*t);
            self.response_mask.push(1.0);
            self.logps.push(*lp);
        }
        self.context.extend(&res.tokens);
    }

    /// A non-terminal env step observed: observation tokens join the
    /// context, untrained.
    pub fn absorb_obs(&mut self, obs: &[i32]) {
        for &t in obs {
            self.response.push(t);
            self.response_mask.push(0.0);
            self.logps.push(0.0);
        }
        self.context.extend(obs);
    }

    /// Assemble the finished trajectory (state moves to Scoring).
    pub fn finish(&mut self, reward: f32) -> Trajectory {
        self.state = EpisodeState::Scoring;
        Trajectory {
            prompt: std::mem::take(&mut self.prompt),
            response: std::mem::take(&mut self.response),
            response_mask: std::mem::take(&mut self.response_mask),
            behavior_logps: std::mem::take(&mut self.logps),
            reward,
            group: self.group_key,
            init_version: self.init_version,
            cross_version: self.cross_version,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::math::MathEnv;

    #[test]
    fn group_tasks_share_seeds_within_group_episode() {
        let t = GroupTasks::new(2, 4, 42);
        let (k0, s0) = t.next(0, 0);
        let (k1, s1) = t.next(0, 1);
        // same group, same episode index => same key and seed
        assert_eq!(k0, k1);
        assert_eq!(s0, s1);
        // next episode for member 0 differs
        let (k2, s2) = t.next(0, 0);
        assert_ne!(k0, k2);
        assert_ne!(s0, s2);
        // other group differs
        let (k3, s3) = t.next(1, 0);
        assert_ne!(k0, k3);
        assert_ne!(s0, s3);
    }

    #[test]
    fn redundant_members_share_keys_with_regulars() {
        // 1 group, 4 regular members + 2 spares = 6 lanes
        let t = GroupTasks::new(1, 6, 7);
        let keys: Vec<u64> = (0..6).map(|m| t.next(0, m).0).collect();
        assert!(keys.iter().all(|&k| k == keys[0]), "{keys:?}");
    }

    #[test]
    fn key_packing_is_collision_free_across_groups() {
        // the old packing `(grp << 32) | episode` let an episode counter
        // >= 2^32 bleed into the group bits; the mask confines it
        assert_eq!(pack_group_key(0, 5), 5);
        assert_eq!(pack_group_key(3, 5), (3u64 << 32) | 5);
        assert_ne!(pack_group_key(0, u64::from(u32::MAX)), pack_group_key(1, 0));
        assert_eq!(pack_group_key(1, 0) - 1, pack_group_key(0, u64::from(u32::MAX)));
    }

    #[test]
    #[should_panic(expected = "overflows")]
    #[cfg(debug_assertions)]
    fn key_packing_asserts_on_episode_overflow() {
        let _ = pack_group_key(0, 1 << 32);
    }

    #[test]
    fn episode_assembles_masked_trajectory() {
        let mut ep = Episode::new(2, 1, false, Box::new(MathEnv::new()));
        assert_eq!(ep.state, EpisodeState::WaitingTicket);
        ep.begin(77, 4);
        assert_eq!(ep.state, EpisodeState::SteppingEnv);
        ep.absorb_prompt(vec![1, 2, 3]);
        ep.absorb_action(&GenResult {
            id: 9,
            tokens: vec![5, 6],
            logps: vec![-0.1, -0.2],
            version: 4,
            prefix_version: 4,
        });
        ep.absorb_obs(&[8]);
        ep.absorb_action(&GenResult {
            id: 10,
            tokens: vec![7],
            logps: vec![-0.3],
            version: 4,
            prefix_version: 4,
        });
        let traj = ep.finish(1.0);
        assert_eq!(ep.state, EpisodeState::Scoring);
        assert_eq!(traj.prompt, vec![1, 2, 3]);
        assert_eq!(traj.response, vec![5, 6, 8, 7]);
        assert_eq!(traj.response_mask, vec![1.0, 1.0, 0.0, 1.0]);
        assert_eq!(traj.behavior_logps, vec![-0.1, -0.2, 0.0, -0.3]);
        assert_eq!(traj.group, 77);
        assert_eq!(traj.init_version, 4);
        assert!(!traj.cross_version, "single-version actions are not piecewise");
        // begin() resets all per-episode buffers
        ep.begin(78, 5);
        assert!(ep.prompt.is_empty() && ep.response.is_empty() && ep.context.is_empty());
        assert_eq!(ep.turn, 0);
    }

    #[test]
    fn salvaged_prefix_spanning_update_marks_cross_version() {
        let mut ep = Episode::new(0, 0, false, Box::new(MathEnv::new()));
        ep.begin(5, 1);
        ep.absorb_prompt(vec![1]);
        // resumed generation: first token decoded at version 1, the
        // continuation finished at version 2
        ep.absorb_action(&GenResult {
            id: 1,
            tokens: vec![4, 5],
            logps: vec![-0.1, -0.2],
            version: 2,
            prefix_version: 1,
        });
        let traj = ep.finish(0.0);
        assert!(traj.cross_version, "salvage spanning an update must be surfaced");
        // the flag resets with the next episode
        ep.begin(6, 2);
        assert!(!ep.cross_version);
    }
}
