//! SampleBuffer (paper Section 4.2/4.3): the shared trajectory store
//! between EnvManager producers and the AsyncController consumer.
//!
//! Enforces the *per-sample* asynchronous ratio alpha: a producer must
//! acquire a ticket (`begin_sample` / `try_begin_sample`) before
//! starting generation; tickets are only granted while `outstanding <
//! (1 + alpha) * batch`, so any sample in the buffer was initiated by a
//! policy version no older than (n - alpha) when consumed at version n,
//! and no admitted sample is wasted. GRPO group completeness is tracked
//! here too: `get_batch` returns whole groups.
//!
//! Event-driven producers (the RolloutEngine) register two completion
//! hooks instead of blocking: a *capacity* hook fired whenever a ticket
//! is retired (or the buffer shuts down), and a *group* hook fired with
//! the group key whenever a group completes — including keys burned by
//! stale eviction, so redundant in-flight members can be cancelled the
//! moment their group can no longer use them (Section 5.2.2).

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};

use crate::rl::Trajectory;

/// Outcome of a non-blocking admission attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// Ticket granted; the value is the initiating policy version.
    Granted(u64),
    /// Freshness budget exhausted — retry after the capacity hook fires.
    Full,
    /// The buffer has shut down; no further tickets will be granted.
    Shutdown,
}

/// Fired when a ticket is retired or the buffer shuts down.
pub type CapacityHook = Box<dyn Fn() + Send + Sync>;
/// Fired with the group key when a group completes (or is burned).
pub type GroupHook = Box<dyn Fn(u64) + Send + Sync>;

#[derive(Clone, Copy, Debug, Default)]
pub struct BufferStats {
    pub produced: usize,
    pub consumed: usize,
    pub cancelled: usize,
    pub stale_evicted: usize,
    /// samples arriving for an already-complete group (redundant
    /// environment rollout surplus, Section 5.2.2)
    pub surplus: usize,
    /// consumed samples whose behavior policy was piecewise across a
    /// weight update (a salvaged prefix resumed under newer weights —
    /// partial migration). These sit inside the same alpha bound as
    /// everything else (the gap is measured from `init_version`), but
    /// importance ratios on the salvaged span use the older pi_old.
    pub cross_version_samples: usize,
    pub max_version_gap: u64,
    pub sum_version_gap: u64,
}

impl BufferStats {
    pub fn mean_version_gap(&self) -> f64 {
        if self.consumed == 0 {
            0.0
        } else {
            self.sum_version_gap as f64 / self.consumed as f64
        }
    }
}

struct Inner {
    version: u64,
    /// async ratio alpha, runtime-adjustable via `set_async_ratio`
    /// (the async governor retunes it on mode transitions)
    alpha: f64,
    /// sequences admissible at once: ceil((1 + alpha) * batch)
    capacity: usize,
    /// tickets issued and not yet retired. Retirement happens at
    /// `bump_version`, not `get_batch`: the batch being trained still
    /// occupies freshness budget, which is what makes the admission
    /// bound exact (a sample admitted at position p is consumed after
    /// floor(p / batch) further updates, so p < (1+alpha)*batch implies
    /// gap <= alpha).
    outstanding: usize,
    /// samples consumed by get_batch but not yet retired by bump
    pending_retire: usize,
    /// complete groups ready for consumption, FIFO
    ready: VecDeque<Vec<Trajectory>>,
    /// group key -> partial group
    partial: BTreeMap<u64, Vec<Trajectory>>,
    /// groups already completed (surplus detection for redundant envs)
    completed_keys: std::collections::BTreeSet<u64>,
    shutdown: bool,
    stats: BufferStats,
}

impl Inner {
    /// Oldest admissible init version at the current policy version.
    fn freshness_floor(&self, alpha: f64) -> u64 {
        (self.version as f64 - alpha).max(0.0).ceil() as u64
    }
}

/// Thread-safe, version-aware sample store.
pub struct SampleBuffer {
    inner: Mutex<Inner>,
    cv: Condvar,
    /// sequences consumed per training step — the N that capacity
    /// `(1 + alpha) * batch` scales from
    batch: usize,
    group_size: usize,
    /// observer hooks, held outside `inner` and always invoked with the
    /// inner lock released (hooks may immediately call back in)
    hooks: Mutex<Hooks>,
}

#[derive(Default)]
struct Hooks {
    capacity: Option<CapacityHook>,
    group: Option<GroupHook>,
}

impl SampleBuffer {
    /// Fire the capacity hook (inner lock must NOT be held).
    fn notify_capacity(&self) {
        if let Some(h) = &self.hooks.lock().unwrap().capacity {
            h();
        }
    }

    /// Fire the group hook for each completed/burned key (inner lock
    /// must NOT be held).
    fn notify_groups(&self, keys: &[u64]) {
        if keys.is_empty() {
            return;
        }
        if let Some(h) = &self.hooks.lock().unwrap().group {
            for &k in keys {
                h(k);
            }
        }
    }
}

impl SampleBuffer {
    /// `batch` = sequences consumed per training step
    /// (rollout_batch_size x group size); `alpha` = async ratio.
    pub fn new(batch: usize, group_size: usize, alpha: f64) -> Self {
        assert!(batch > 0 && group_size > 0 && batch % group_size == 0);
        let capacity = ((1.0 + alpha) * batch as f64).ceil() as usize;
        SampleBuffer {
            inner: Mutex::new(Inner {
                version: 0,
                alpha,
                capacity,
                outstanding: 0,
                pending_retire: 0,
                ready: VecDeque::new(),
                partial: BTreeMap::new(),
                completed_keys: std::collections::BTreeSet::new(),
                shutdown: false,
                stats: BufferStats::default(),
            }),
            cv: Condvar::new(),
            batch,
            group_size,
            hooks: Mutex::new(Hooks::default()),
        }
    }

    /// Register the capacity hook (event-driven producers). Fired after
    /// every ticket retirement and on shutdown; spurious firings are
    /// fine — callers re-check with `try_begin_sample`.
    pub fn set_capacity_hook(&self, hook: CapacityHook) {
        self.hooks.lock().unwrap().capacity = Some(hook);
    }

    /// Register the group-completion hook. Fired with the group key
    /// when a group becomes consumable, and for keys burned by stale
    /// eviction — in both cases further work on the key is wasted.
    pub fn set_group_hook(&self, hook: GroupHook) {
        self.hooks.lock().unwrap().group = Some(hook);
    }

    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().capacity
    }

    pub fn alpha(&self) -> f64 {
        self.inner.lock().unwrap().alpha
    }

    /// Retune the async ratio at runtime (the governor's mode
    /// transitions): recomputes `capacity = ceil((1 + alpha) * batch)`
    /// and wakes admission waiters, since a loosened window may now
    /// have room. Tightening never cancels already-granted tickets —
    /// outstanding work simply drains until admission reopens — and
    /// the tighter freshness floor takes effect at the next
    /// `bump_version`'s eviction sweep, exactly where the floor is
    /// always enforced.
    pub fn set_async_ratio(&self, alpha: f64) {
        assert!(alpha >= 0.0 && alpha.is_finite());
        {
            let mut g = self.inner.lock().unwrap();
            if g.alpha == alpha {
                return;
            }
            g.alpha = alpha;
            g.capacity = ((1.0 + alpha) * self.batch as f64).ceil() as usize;
            self.cv.notify_all();
        }
        // a loosened window is new capacity for event-driven producers
        self.notify_capacity();
    }

    /// Producer admission: blocks until a generation slot is available
    /// under the freshness bound. Returns the initiating policy version
    /// (the sample's tag), or None on shutdown.
    pub fn begin_sample(&self) -> Option<u64> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.shutdown {
                return None;
            }
            if g.outstanding < g.capacity {
                g.outstanding += 1;
                return Some(g.version);
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking admission for event-driven producers: grants a
    /// ticket when the freshness budget allows, otherwise reports why
    /// not. On `Full`, retry when the capacity hook fires.
    pub fn try_begin_sample(&self) -> Admission {
        let mut g = self.inner.lock().unwrap();
        if g.shutdown {
            Admission::Shutdown
        } else if g.outstanding < g.capacity {
            g.outstanding += 1;
            Admission::Granted(g.version)
        } else {
            Admission::Full
        }
    }

    /// Producer gave up on a ticket (aborted / failed env).
    pub fn cancel(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            debug_assert!(g.outstanding > 0);
            g.outstanding = g.outstanding.saturating_sub(1);
            g.stats.cancelled += 1;
            self.cv.notify_all();
        }
        self.notify_capacity();
    }

    /// Has this group already completed (or been burned)? Redundant
    /// producers consult this before starting an episode whose output
    /// could only ever be surplus.
    pub fn group_completed(&self, key: u64) -> bool {
        self.inner.lock().unwrap().completed_keys.contains(&key)
    }

    /// Producer completion: file the trajectory under its group; a
    /// complete group becomes consumable. Two reclamation paths mirror
    /// the paper's ABORT semantics (work is re-initiated, not wasted):
    /// samples arriving for an already-complete group (redundant env
    /// rollout surplus, Section 5.2.2) and samples whose generation
    /// straddled too many updates (init_version below the freshness
    /// floor) are dropped and their tickets reclaimed — the producer
    /// immediately regenerates under the current policy.
    pub fn push(&self, traj: Trajectory) {
        let mut completed: Option<u64> = None;
        let mut reclaimed = false;
        {
            let mut g = self.inner.lock().unwrap();
            let key = traj.group;
            if g.completed_keys.contains(&key) {
                g.stats.surplus += 1;
                g.outstanding = g.outstanding.saturating_sub(1);
                reclaimed = true;
            } else if traj.init_version < g.freshness_floor(g.alpha) {
                g.stats.stale_evicted += 1;
                g.outstanding = g.outstanding.saturating_sub(1);
                reclaimed = true;
            } else {
                g.stats.produced += 1;
                let entry = g.partial.entry(key).or_default();
                entry.push(traj);
                if entry.len() == self.group_size {
                    let grp = g.partial.remove(&key).unwrap();
                    g.ready.push_back(grp);
                    g.completed_keys.insert(key);
                    completed = Some(key);
                }
            }
            self.cv.notify_all();
        }
        if reclaimed {
            self.notify_capacity();
        }
        if let Some(key) = completed {
            self.notify_groups(&[key]);
        }
    }

    /// Blocking get_batch (paper Section 4.2): returns `n_groups`
    /// complete groups (flattened), FIFO. None on shutdown. Tickets of
    /// consumed samples stay outstanding until the matching
    /// `bump_version` — the in-training batch still counts against the
    /// freshness budget.
    pub fn get_batch(&self, n_groups: usize) -> Option<Vec<Trajectory>> {
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.ready.len() >= n_groups {
                let mut out = Vec::with_capacity(n_groups * self.group_size);
                for _ in 0..n_groups {
                    out.extend(g.ready.pop_front().unwrap());
                }
                g.pending_retire += out.len();
                let v = g.version;
                for t in &out {
                    let gap = v.saturating_sub(t.init_version);
                    g.stats.max_version_gap = g.stats.max_version_gap.max(gap);
                    g.stats.sum_version_gap += gap;
                    if t.cross_version {
                        g.stats.cross_version_samples += 1;
                    }
                }
                g.stats.consumed += out.len();
                self.cv.notify_all();
                return Some(out);
            }
            if g.shutdown {
                return None;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Non-blocking variant (tests / polling loops).
    pub fn try_get_batch(&self, n_groups: usize) -> Option<Vec<Trajectory>> {
        let g = self.inner.lock().unwrap();
        if g.ready.len() >= n_groups {
            drop(g);
            self.get_batch(n_groups)
        } else {
            None
        }
    }

    /// Consumer: policy advanced one version (after model_update).
    /// Retires the just-trained batch's tickets, then evicts whole
    /// groups containing samples below the new freshness floor —
    /// eviction is group-granular because a group missing a member can
    /// never complete (GRPO needs full groups); producers regenerate
    /// under the new policy, so no quota is lost.
    pub fn bump_version(&self) -> u64 {
        let (v, burned) = {
            let mut g = self.inner.lock().unwrap();
            g.version += 1;
            g.outstanding = g.outstanding.saturating_sub(g.pending_retire);
            g.pending_retire = 0;
            let v = g.version;
            let floor = g.freshness_floor(g.alpha);
            let mut evicted = 0usize;
            g.ready.retain(|grp| {
                if grp.iter().all(|t| t.init_version >= floor) {
                    true
                } else {
                    evicted += grp.len();
                    false
                }
            });
            let stale_keys: Vec<u64> = g
                .partial
                .iter()
                .filter(|(_, grp)| grp.iter().any(|t| t.init_version < floor))
                .map(|(k, _)| *k)
                .collect();
            for &k in &stale_keys {
                let grp = g.partial.remove(&k).unwrap();
                evicted += grp.len();
                // the key is burned; surviving members' future pushes for
                // it must be reclaimed as surplus rather than dangle
                g.completed_keys.insert(k);
            }
            g.stats.stale_evicted += evicted;
            g.outstanding = g.outstanding.saturating_sub(evicted);
            self.cv.notify_all();
            (v, stale_keys)
        };
        // retirement frees budget; burned keys cancel their in-flight
        // redundant members (they could only ever produce surplus)
        self.notify_capacity();
        self.notify_groups(&burned);
        v
    }

    pub fn version(&self) -> u64 {
        self.inner.lock().unwrap().version
    }

    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().outstanding
    }

    pub fn ready_groups(&self) -> usize {
        self.inner.lock().unwrap().ready.len()
    }

    pub fn stats(&self) -> BufferStats {
        self.inner.lock().unwrap().stats
    }

    /// Wake all waiters with a shutdown signal.
    pub fn shutdown(&self) {
        {
            let mut g = self.inner.lock().unwrap();
            g.shutdown = true;
            self.cv.notify_all();
        }
        self.notify_capacity();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn traj(group: u64, iv: u64) -> Trajectory {
        Trajectory::single_turn(vec![1], vec![2, 2], vec![-0.1, -0.1], 1.0, group, iv)
    }

    #[test]
    fn admission_respects_capacity() {
        let b = SampleBuffer::new(4, 2, 0.0); // capacity 4
        for _ in 0..4 {
            assert!(b.begin_sample().is_some());
        }
        assert_eq!(b.outstanding(), 4);
        // 5th would block: use a thread + shutdown to verify blocking
        let b = Arc::new(b);
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.begin_sample());
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!h.is_finished(), "5th ticket must block at capacity");
        b.shutdown();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn capacity_scales_with_alpha() {
        assert_eq!(SampleBuffer::new(8, 2, 0.0).capacity(), 8);
        assert_eq!(SampleBuffer::new(8, 2, 2.0).capacity(), 24);
        assert_eq!(SampleBuffer::new(8, 2, 0.5).capacity(), 12);
    }

    #[test]
    fn async_ratio_retunes_capacity_at_runtime() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = Arc::new(SampleBuffer::new(8, 2, 0.0)); // capacity 8
        let caps = Arc::new(AtomicUsize::new(0));
        let c = caps.clone();
        b.set_capacity_hook(Box::new(move || {
            c.fetch_add(1, Ordering::SeqCst);
        }));
        for _ in 0..8 {
            assert!(b.begin_sample().is_some());
        }
        assert_eq!(b.try_begin_sample(), Admission::Full);
        // governor relaxes: the window widens mid-run and both the
        // event-driven hook and blocked waiters see the new room
        b.set_async_ratio(2.0);
        assert_eq!(b.capacity(), 24);
        assert_eq!(b.alpha(), 2.0);
        assert!(caps.load(Ordering::SeqCst) >= 1, "loosening must fire the capacity hook");
        assert!(matches!(b.try_begin_sample(), Admission::Granted(0)));
        // governor tightens below what is outstanding: no ticket is
        // revoked, admission just stays shut until work drains
        b.set_async_ratio(0.0);
        assert_eq!(b.capacity(), 8);
        assert_eq!(b.outstanding(), 9);
        assert_eq!(b.try_begin_sample(), Admission::Full);
        // unchanged alpha is a no-op (no spurious hook storm)
        let before = caps.load(Ordering::SeqCst);
        b.set_async_ratio(0.0);
        assert_eq!(caps.load(Ordering::SeqCst), before);
        // the tightened freshness floor bites at the next bump: alpha 0
        // at version 1 evicts everything initiated at version 0
        for _ in 0..2 {
            b.push(traj(0, 0));
        }
        assert_eq!(b.ready_groups(), 1);
        b.bump_version();
        assert_eq!(b.ready_groups(), 0, "floor = version - 0 evicts the stale group");
    }

    #[test]
    fn groups_complete_then_consume() {
        let b = SampleBuffer::new(4, 2, 1.0);
        for _ in 0..4 {
            b.begin_sample();
        }
        b.push(traj(0, 0));
        assert_eq!(b.ready_groups(), 0); // partial
        b.push(traj(0, 0));
        assert_eq!(b.ready_groups(), 1);
        b.push(traj(1, 0));
        b.push(traj(1, 0));
        let batch = b.get_batch(2).unwrap();
        assert_eq!(batch.len(), 4);
        // tickets stay outstanding until the trained batch retires
        assert_eq!(b.outstanding(), 4);
        b.bump_version();
        assert_eq!(b.outstanding(), 0);
        assert_eq!(b.stats().consumed, 4);
    }

    #[test]
    fn version_gap_tracked() {
        let b = SampleBuffer::new(2, 2, 2.0);
        b.begin_sample();
        b.begin_sample();
        b.push(traj(0, 0));
        b.push(traj(0, 0));
        b.bump_version();
        b.bump_version(); // version 2, samples from version 0 => gap 2
        let _ = b.get_batch(1).unwrap();
        let s = b.stats();
        assert_eq!(s.max_version_gap, 2);
        assert!((s.mean_version_gap() - 2.0).abs() < 1e-9);
        assert_eq!(s.stale_evicted, 0); // gap == alpha: admissible
    }

    #[test]
    fn cross_version_samples_counted_at_consumption() {
        let b = SampleBuffer::new(2, 2, 1.0);
        b.begin_sample();
        b.begin_sample();
        b.push(Trajectory { cross_version: true, ..traj(0, 0) });
        b.push(traj(0, 0));
        assert_eq!(b.stats().cross_version_samples, 0, "counted when consumed, not pushed");
        let _ = b.get_batch(1).unwrap();
        assert_eq!(b.stats().cross_version_samples, 1);
    }

    #[test]
    fn stale_eviction_beyond_alpha() {
        let b = SampleBuffer::new(2, 2, 1.0);
        b.begin_sample();
        b.begin_sample();
        b.push(traj(0, 0));
        b.push(traj(0, 0));
        b.bump_version();
        b.bump_version(); // floor = 2 - 1 = 1 > init 0 => evict
        assert_eq!(b.stats().stale_evicted, 2);
        assert_eq!(b.ready_groups(), 0);
        assert_eq!(b.outstanding(), 0);
    }

    #[test]
    fn producer_consumer_threads() {
        let b = Arc::new(SampleBuffer::new(8, 4, 1.0));
        let p = b.clone();
        // continuous producer (env managers regenerate forever)
        let producer = std::thread::spawn(move || {
            let mut n = 0u64;
            while let Some(iv) = p.begin_sample() {
                p.push(traj(n / 4, iv));
                n += 1;
            }
        });
        let mut got = 0;
        for _ in 0..4 {
            let batch = b.get_batch(2).unwrap();
            got += batch.len();
            b.bump_version();
        }
        b.shutdown();
        producer.join().unwrap();
        assert_eq!(got, 32);
        // per-sample freshness: consumed gap bounded by alpha exactly
        assert!(b.stats().max_version_gap <= 1, "gap {}", b.stats().max_version_gap);
    }

    #[test]
    fn try_begin_sample_reports_full_and_shutdown() {
        let b = SampleBuffer::new(2, 2, 0.0); // capacity 2
        assert!(matches!(b.try_begin_sample(), Admission::Granted(0)));
        assert!(matches!(b.try_begin_sample(), Admission::Granted(0)));
        assert_eq!(b.try_begin_sample(), Admission::Full);
        b.cancel();
        assert!(matches!(b.try_begin_sample(), Admission::Granted(0)));
        b.shutdown();
        assert_eq!(b.try_begin_sample(), Admission::Shutdown);
    }

    #[test]
    fn hooks_fire_on_capacity_and_group_completion() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let b = Arc::new(SampleBuffer::new(4, 2, 1.0));
        let caps = Arc::new(AtomicUsize::new(0));
        let groups = Arc::new(Mutex::new(Vec::<u64>::new()));
        let c = caps.clone();
        b.set_capacity_hook(Box::new(move || {
            c.fetch_add(1, Ordering::SeqCst);
        }));
        let gk = groups.clone();
        b.set_group_hook(Box::new(move |k| gk.lock().unwrap().push(k)));

        for _ in 0..4 {
            b.begin_sample();
        }
        b.push(traj(7, 0));
        b.push(traj(7, 0)); // group 7 completes here
        assert_eq!(groups.lock().unwrap().as_slice(), &[7]);
        assert!(b.group_completed(7));
        assert!(!b.group_completed(8));
        // surplus for a completed group reclaims a ticket => capacity
        b.push(traj(7, 0));
        assert!(caps.load(Ordering::SeqCst) >= 1, "surplus must fire capacity hook");
        // cancel fires capacity too
        let before = caps.load(Ordering::SeqCst);
        b.cancel();
        assert!(caps.load(Ordering::SeqCst) > before);
        // shutdown fires capacity so waiters re-check
        let before = caps.load(Ordering::SeqCst);
        b.shutdown();
        assert!(caps.load(Ordering::SeqCst) > before);
    }

    #[test]
    fn burned_keys_fire_group_hook_on_bump() {
        let b = Arc::new(SampleBuffer::new(2, 2, 0.0));
        let groups = Arc::new(Mutex::new(Vec::<u64>::new()));
        let gk = groups.clone();
        b.set_group_hook(Box::new(move |k| gk.lock().unwrap().push(k)));
        b.begin_sample();
        b.push(traj(3, 0)); // partial group 3 at version 0
        b.bump_version(); // floor 1 > 0: group 3 burned
        assert_eq!(groups.lock().unwrap().as_slice(), &[3]);
        assert!(b.group_completed(3), "burned keys count as completed");
    }

    #[test]
    fn get_batch_unblocks_on_shutdown() {
        let b = Arc::new(SampleBuffer::new(4, 2, 0.0));
        let b2 = b.clone();
        let h = std::thread::spawn(move || b2.get_batch(1));
        std::thread::sleep(std::time::Duration::from_millis(20));
        b.shutdown();
        assert_eq!(h.join().unwrap().map(|v| v.len()), None);
    }
}
