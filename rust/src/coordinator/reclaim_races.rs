//! Race-hunting suite for the asynchronous RECLAIM machinery
//! (`fleet.rs` PendingSalvage): deterministic drain-race regressions,
//! caller-latency bounds, and seeded interleaving properties over the
//! elastic lifecycle.
//!
//! Everything here runs against stub replicas (no artifacts): live
//! event loops that hold requests without decoding and fabricate
//! RECLAIM behavior on demand — prompt salvage, finish-inside-the-
//! window (the drain race), delayed answers (fail-slow), or silence
//! (wedged). The properties honor `PROPTEST_CASES` so CI can sweep
//! far more interleavings than a local run (`make test-races`).

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use crate::coordinator::fleet::testing::{
    cfg, custom_pool, delayed_pool, elastic_finishing_pool, elastic_pool, mute_pool,
    pool_with_progress,
};
use crate::coordinator::fleet::LlmProxyPool;
use crate::coordinator::llm_proxy::{GenerationTask, LlmProxy, ProxyEvent};
use crate::coordinator::routing::RoutePolicy;
use crate::util::rng::Rng;

const SETTLE: Duration = Duration::from_secs(10);

/// Seeded-case harness matching rust/tests/proptests.rs: `PROPTEST_CASES`
/// overrides the default case count (the dedicated CI race job raises
/// it), and a failure reports the first failing seed for reproduction.
fn for_all_seeds(default_cases: u64, f: impl Fn(&mut Rng)) {
    let n = std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_cases);
    for seed in 0..n {
        let mut rng = Rng::new(0xACE ^ seed.wrapping_mul(0x9e3779b97f4a7c15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("reclaim race property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

fn submit(p: &LlmProxyPool, tx: &std::sync::mpsc::Sender<ProxyEvent>) -> Option<u64> {
    p.try_submit(GenerationTask::fresh(vec![1, 2, 3], 64, tx.clone()))
}

// ---------------------------------------------------------------------------
// Drain-race regressions: a generation finishing inside the reclaim
// window is delivered exactly once, counted completed, never re-decoded.
// ---------------------------------------------------------------------------

#[test]
fn drain_race_retire_delivers_finished_result_once() {
    let p = elastic_finishing_pool(2, 5, &cfg(2, RoutePolicy::RoundRobin, 8));
    let (tx_a, rx_a) = channel();
    let a = p.try_submit(GenerationTask::fresh(vec![1, 2], 32, tx_a)).unwrap(); // RR -> 0
    let (tx_b, rx_b) = channel();
    let _b = p.try_submit(GenerationTask::fresh(vec![3], 32, tx_b)).unwrap(); // RR -> 1
    assert!(p.retire_replica(0));
    p.settle(SETTLE);
    // the stub finished the generation the moment the drain's RECLAIM
    // arrived: the result must reach the caller — once, with the pool id
    let res = rx_a.recv_timeout(Duration::from_secs(5)).expect("completion delivered").done();
    assert_eq!(res.id, a, "result must carry the pool id");
    assert_eq!(res.tokens.len(), 5);
    assert!(
        rx_a.recv_timeout(Duration::from_millis(50)).is_err(),
        "the drain-raced completion must be delivered exactly once"
    );
    let stats = p.token_stats();
    assert_eq!(stats.wasted_tokens, 0, "a finished result is completed, not wasted: {stats:?}");
    assert_eq!(stats.salvaged_tokens, 0, "nothing to salvage: it finished: {stats:?}");
    assert_eq!(p.resumed_dispatches(), 0, "zero re-decode: the task is never re-dispatched");
    assert_eq!(p.outstanding_per_replica(), vec![0, 1], "b is untouched, a is done");
    assert!(rx_b.try_recv().is_err(), "the survivor's request is still running");
    p.check_invariants();
    let report = p.shutdown().unwrap();
    assert_eq!(report.retired.len(), 1, "the drained occupant is archived");
    assert_eq!(report.migrated, 0, "nothing moved: the race resolved as a completion");
}

#[test]
fn drain_race_kill_and_migrate_deliver_once_without_rewaste() {
    // kill arm
    let p = elastic_finishing_pool(2, 3, &cfg(2, RoutePolicy::RoundRobin, 8));
    let (tx_a, rx_a) = channel();
    let a = p.try_submit(GenerationTask::fresh(vec![1], 32, tx_a)).unwrap(); // RR -> 0
    p.kill_replica(0);
    p.settle(SETTLE);
    let res = rx_a.recv_timeout(Duration::from_secs(5)).expect("kill-raced completion").done();
    assert_eq!(res.id, a);
    assert!(rx_a.recv_timeout(Duration::from_millis(50)).is_err(), "double delivery");
    assert_eq!(p.token_stats().wasted_tokens, 0);
    assert_eq!(p.resumed_dispatches(), 0);
    p.check_invariants();
    drop(p);

    // migrate arm
    let p = elastic_finishing_pool(2, 4, &cfg(2, RoutePolicy::LeastOutstanding, 8));
    let (tx_c, rx_c) = channel();
    let c = p.try_submit(GenerationTask::fresh(vec![9], 32, tx_c)).unwrap(); // LO -> 0
    assert!(p.migrate(c));
    p.settle(SETTLE);
    let res = rx_c.recv_timeout(Duration::from_secs(5)).expect("migrate-raced completion").done();
    assert_eq!(res.id, c);
    assert!(rx_c.recv_timeout(Duration::from_millis(50)).is_err(), "double delivery");
    assert_eq!(p.token_stats().wasted_tokens, 0, "no first-result-counted-wasted");
    assert_eq!(p.outstanding_per_replica(), vec![0, 0], "nothing re-decodes anywhere");
    p.check_invariants();
}

// ---------------------------------------------------------------------------
// Caller-latency bounds: no control-plane call waits on a salvage.
// ---------------------------------------------------------------------------

/// `migrate` / `retire_replica` / `kill_replica` must return promptly
/// even when every RECLAIM answer is hundreds of ms away (the old
/// code blocked up to SALVAGE_WAIT per hung generation on the
/// caller's thread — the RolloutEngine's event loop). Budgets are
/// generous multiples of the O(µs) lock work to stay CI-safe while
/// remaining far below the stub's answer delay.
#[test]
fn control_plane_calls_return_without_blocking_on_salvage() {
    let delay = Duration::from_millis(250);
    let budget = Duration::from_millis(100);
    let mut c = cfg(3, RoutePolicy::LeastOutstanding, 8);
    c.salvage_timeout = 30.0; // answers must resolve, never expire
    let p = delayed_pool(3, 2, delay, &c);
    let (sink, _keep) = channel();
    let a = submit(&p, &sink).unwrap(); // LO -> 0
    let _b = submit(&p, &sink).unwrap(); // LO -> 1
    let _c = submit(&p, &sink).unwrap(); // LO -> 2
    assert_eq!(p.outstanding_per_replica(), vec![1, 1, 1]);

    let t = Instant::now();
    assert!(p.migrate(a));
    assert!(t.elapsed() < budget, "migrate blocked on the salvage: {:?}", t.elapsed());
    let t = Instant::now();
    assert!(p.retire_replica(1));
    assert!(t.elapsed() < budget, "retire_replica blocked on the salvage: {:?}", t.elapsed());
    let t = Instant::now();
    p.kill_replica(2);
    assert!(t.elapsed() < budget, "kill_replica blocked on the salvage: {:?}", t.elapsed());

    // the collectors absorb all three delayed answers off-thread
    p.settle(Duration::from_secs(30));
    let stats = p.token_stats();
    assert_eq!(stats.salvaged_tokens, 6, "every reclaim salvaged its 2 tokens: {stats:?}");
    assert_eq!(stats.wasted_tokens, 0, "{stats:?}");
    assert_eq!(
        p.outstanding_per_replica()[0],
        3,
        "all three tasks resumed on the lone survivor"
    );
    p.check_invariants();
}

/// A wedged replica (never answers RECLAIM) must not leak the parked
/// entry: the collector-side `salvage_timeout` expires it and the task
/// re-dispatches from its last salvaged prefix.
#[test]
fn wedged_replica_salvage_expires_and_redispatches() {
    let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
    c.salvage_timeout = 0.05;
    let p = mute_pool(2, &c);
    let (sink, _keep) = channel();
    let a = submit(&p, &sink).unwrap(); // LO -> 0
    let t = Instant::now();
    assert!(p.migrate(a));
    assert!(t.elapsed() < Duration::from_millis(100), "caller must not wait out the wedge");
    p.settle(SETTLE);
    assert_eq!(
        p.outstanding_per_replica(),
        vec![0, 1],
        "the expired entry re-dispatches to the survivor"
    );
    let stats = p.token_stats();
    assert_eq!(stats.salvaged_tokens, 0, "the wedge yielded nothing: {stats:?}");
    assert_eq!(stats.wasted_tokens, 0, "an empty prefix wastes nothing: {stats:?}");
    p.check_invariants();
}

/// Aborting a mid-reclaim (parked) request must bill its salvaged
/// prefix immediately — a wedged replica that never answers the
/// in-flight RECLAIM would otherwise leak the tokens from the ledger —
/// and a late answer must bill only the *new* progress (the tombstone
/// prevents double-charging the prefix).
#[test]
fn abort_of_parked_entry_bills_prefix_exactly_once() {
    // arm 1: the reclaim is never answered (wedged target)
    let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
    c.salvage_timeout = 30.0; // expiry must not race the assertions
    let p = custom_pool(
        vec![LlmProxy::spawn_stub_with_progress(4), LlmProxy::spawn_stub_mute()],
        &c,
    );
    let (sink, _keep) = channel();
    let a = submit(&p, &sink).unwrap(); // LO -> 0 (the healthy stub)
    assert!(p.migrate(a));
    p.settle(SETTLE); // salvage 4 -> resumed on the mute replica 1
    assert_eq!(p.token_stats().salvaged_tokens, 4);
    assert_eq!(p.prefix_tokens_outstanding(), 4);
    assert!(p.migrate(a), "park on the wedged replica");
    p.abort(a); // abort while the reclaim hangs, forever unanswered
    assert_eq!(p.pending_reclaims(), 0, "abort must unpark");
    let stats = p.token_stats();
    assert_eq!(
        stats.wasted_tokens, 4,
        "the salvaged prefix must be billed at the abort, not deferred \
         to an answer that never comes: {stats:?}"
    );
    assert_eq!(p.prefix_tokens_outstanding(), 0);
    // conservation holds even against a wedged replica
    assert_eq!(stats.salvaged_tokens, stats.wasted_tokens);
    p.check_invariants();

    // arm 2: the answer does arrive (late) — only the NEW progress is
    // billed on top; the prefix is never double-charged
    let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
    c.salvage_timeout = 30.0;
    // wide window: the abort below must land before this answer even
    // under heavy CI scheduling noise
    let delay = Duration::from_millis(500);
    let p = custom_pool(
        vec![
            LlmProxy::spawn_stub_with_reclaim_delay(3, delay),
            LlmProxy::spawn_stub_with_reclaim_delay(3, delay),
        ],
        &c,
    );
    let (sink, _keep) = channel();
    let b = submit(&p, &sink).unwrap(); // LO -> 0
    assert!(p.migrate(b));
    p.settle(SETTLE); // salvage 3 -> resumed on replica 1 with prefix 3
    assert_eq!(p.token_stats().salvaged_tokens, 3);
    assert!(p.migrate(b), "park again; the answer is half a second away");
    p.abort(b); // lands well inside the delay window
    // prefix billed at the abort...
    assert_eq!(p.token_stats().wasted_tokens, 3);
    // ...and the late answer (prefix 3 + progress 3 = 6 tokens) adds
    // exactly the 3 new tokens — 6 total, not 9
    let deadline = Instant::now() + Duration::from_secs(10);
    while p.token_stats().wasted_tokens < 6 {
        assert!(Instant::now() < deadline, "late salvage never accounted");
        std::thread::sleep(Duration::from_millis(5));
    }
    let stats = p.token_stats();
    assert_eq!(stats.wasted_tokens, 6, "prefix double-charged: {stats:?}");
    assert_eq!(stats.salvaged_tokens, 3, "{stats:?}");
    p.check_invariants();

    // arm 3: expiry instead of abort — the entry times out (Lost), the
    // task re-dispatches carrying its prefix, and the LATE answer is
    // billed for exactly the new progress. The tombstone threads the
    // resumed-prefix length through the expiry, so the 6-token answer
    // (prefix 3 + progress 3) wastes exactly 3 — not 6.
    let mut c = cfg(2, RoutePolicy::LeastOutstanding, 8);
    c.salvage_timeout = 0.15; // expires long before the stub answers
    let delay = Duration::from_millis(600);
    let p = custom_pool(
        vec![
            LlmProxy::spawn_stub_with_progress(3),
            LlmProxy::spawn_stub_with_reclaim_delay(3, delay),
        ],
        &c,
    );
    let (sink, _keep) = channel();
    let d = submit(&p, &sink).unwrap(); // LO -> 0 (the prompt stub)
    assert!(p.migrate(d));
    p.settle(SETTLE); // salvage 3 -> resumed on the slow replica 1
    assert_eq!(p.token_stats().salvaged_tokens, 3);
    assert_eq!(p.prefix_tokens_outstanding(), 3);
    assert!(p.migrate(d), "park on the slow replica; its answer is 600ms away");
    // the deadline wakeup expires the entry at ~150ms and re-dispatches
    // the task (prefix intact) to the survivor
    let deadline = Instant::now() + Duration::from_secs(10);
    while p.pending_reclaims() > 0 {
        assert!(Instant::now() < deadline, "expiry never fired");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(p.token_stats().wasted_tokens, 0, "the prefix lives on: nothing billed yet");
    assert_eq!(p.outstanding_per_replica(), vec![1, 0], "re-dispatched to the survivor");
    assert_eq!(p.prefix_tokens_outstanding(), 3, "the re-dispatched task carries the prefix");
    // the late answer lands ~450ms later: 6 tokens, of which 3 are the
    // prefix already re-dispatched — exactly 3 new tokens are wasted
    let deadline = Instant::now() + Duration::from_secs(10);
    while p.token_stats().wasted_tokens < 3 {
        assert!(Instant::now() < deadline, "late salvage never accounted");
        std::thread::sleep(Duration::from_millis(5));
    }
    p.settle(SETTLE);
    let stats = p.token_stats();
    assert_eq!(stats.wasted_tokens, 3, "late answer billed beyond its new progress: {stats:?}");
    assert_eq!(stats.salvaged_tokens, 3, "{stats:?}");
    assert_eq!(p.prefix_tokens_outstanding(), 3);
    p.check_invariants();
}

// ---------------------------------------------------------------------------
// ReclaimInPlace and salvage-cost-aware retire.
// ---------------------------------------------------------------------------

/// With every peer's decode window full, the watchdog's migrate
/// degrades to ReclaimInPlace: the hung generation is salvaged and
/// re-enters pool admission (behind the backlog) instead of being
/// piled onto a saturated survivor.
#[test]
fn saturated_pool_reclaims_in_place_instead_of_stacking() {
    let p = pool_with_progress(2, 4, &cfg(2, RoutePolicy::QueueSched, 1));
    let (sink, _keep) = channel();
    let a = submit(&p, &sink).unwrap(); // slot on 0
    let _b = submit(&p, &sink).unwrap(); // slot on 1
    let _c = submit(&p, &sink).unwrap(); // pool-queued (both windows full)
    assert_eq!(p.pool_queue_len(), 1);
    assert!(p.migrate(a), "a saturated migrate must still reclaim");
    p.settle(SETTLE);
    assert_eq!(p.reclaims_in_place(), 1);
    let stats = p.token_stats();
    assert_eq!(stats.salvaged_tokens, 4, "the pause keeps the decoded prefix: {stats:?}");
    assert_eq!(stats.wasted_tokens, 0, "{stats:?}");
    // the freed window admitted the backlog; the paused task waits
    // with its prefix intact
    assert_eq!(p.outstanding_per_replica(), vec![1, 1]);
    assert_eq!(p.pool_queue_len(), 1);
    assert_eq!(p.prefix_tokens_outstanding(), 4, "the queued task carries the salvage");
    p.check_invariants();

    // knob off: the saturated migrate is refused outright and parks
    // nothing
    let mut c2 = cfg(2, RoutePolicy::QueueSched, 1);
    c2.reclaim_in_place = false;
    let p2 = pool_with_progress(2, 0, &c2);
    let (sink2, _keep2) = channel();
    let a2 = submit(&p2, &sink2).unwrap();
    let _b2 = submit(&p2, &sink2).unwrap();
    assert!(!p2.migrate(a2), "reclaim_in_place=false refuses a saturated migrate");
    assert_eq!(p2.pending_reclaims(), 0);
    assert_eq!(p2.reclaims_in_place(), 0);
}

/// `retire_idlest` tie-break: among equally idle replicas, drain the
/// one whose in-flight work carries the fewest already-salvaged
/// prefix tokens (the cheapest KV replay).
#[test]
fn retire_idlest_breaks_ties_toward_cheapest_salvage() {
    let p = elastic_pool(2, 4, &cfg(2, RoutePolicy::LeastOutstanding, 8));
    let (sink, _keep) = channel();
    let a = submit(&p, &sink).unwrap(); // LO -> 0
    assert!(p.migrate(a)); // resumes on 1 with a 4-token salvaged prefix
    p.settle(SETTLE);
    assert_eq!(p.outstanding_per_replica(), vec![0, 1]);
    let _b = submit(&p, &sink).unwrap(); // LO -> 0 (prefix-free)
    assert_eq!(p.outstanding_per_replica(), vec![1, 1], "counts must tie");
    assert!(p.retire_idlest());
    p.settle(SETTLE);
    let report = p.shutdown().unwrap();
    assert_eq!(report.retired.len(), 1);
    assert_eq!(
        report.retired[0].slot, 0,
        "equally idle: the prefix-free replica is the cheaper drain"
    );
}

// ---------------------------------------------------------------------------
// Interleaving properties.
// ---------------------------------------------------------------------------

/// Token conservation under arbitrary interleavings of
/// kill/retire/migrate/add/submit on a stub pool whose replicas
/// fabricate `progress` decoded tokens per RECLAIM: every fabricated
/// token is either attached to live work or accounted wasted —
/// `salvaged == live_prefix + wasted` — and no PendingSalvage entry
/// leaks or resolves twice (the structural invariants would break).
/// Ops deliberately do NOT quiesce between steps: kill/retire/add land
/// while earlier reclaims are still parked mid-resolution, which is
/// exactly the overlapped state the table has to survive
/// (`check_invariants` holds under the state lock at any instant; only
/// the final ledger balance needs the quiescent read).
#[test]
fn prop_reclaim_interleavings_conserve_tokens() {
    for_all_seeds(24, |rng| {
        let progress = 1 + rng.below(4);
        let policy = RoutePolicy::ALL[rng.below(RoutePolicy::ALL.len())];
        let mut c = cfg(2, policy, 1 + rng.below(4));
        c.salvage_timeout = 10.0;
        let p = elastic_pool(2, progress, &c);
        let (sink, _keep) = channel();
        let mut ids: Vec<u64> = Vec::new();
        for _ in 0..30 {
            match rng.below(8) {
                0 | 1 | 2 => {
                    if let Some(id) = submit(&p, &sink) {
                        ids.push(id);
                    }
                }
                3 | 4 => {
                    if let Some(&id) = ids.get(rng.below(ids.len().max(1))) {
                        p.migrate(id);
                    }
                }
                5 => {
                    p.kill_replica(rng.below(p.num_replicas()));
                }
                6 => {
                    p.retire_replica(rng.below(p.num_replicas()));
                }
                _ => {
                    let _ = p.add_replica();
                }
            }
            // occasionally let the dust settle so both the quiescent
            // and the mid-resolution shapes are exercised
            if rng.chance(0.2) {
                p.settle(SETTLE);
            }
            p.check_invariants();
        }
        p.settle(SETTLE);
        assert_eq!(p.pending_reclaims(), 0, "PendingSalvage leak");
        let stats = p.token_stats();
        let live = p.prefix_tokens_outstanding() as u64;
        assert_eq!(
            stats.salvaged_tokens,
            live + stats.wasted_tokens,
            "ledger imbalance: salvaged {} != live prefix {} + wasted {}",
            stats.salvaged_tokens,
            live,
            stats.wasted_tokens
        );
        p.check_invariants();
    });
}

/// Exactly-once delivery under arbitrary interleavings when every
/// RECLAIM races a completion (finishing stubs): each submitted
/// request observes at most one `Done`, and nothing is ever counted
/// wasted or re-decoded. Like the conservation property, ops overlap
/// in-flight resolutions on purpose — the drain races pile up across
/// kill/retire/migrate without a quiescent point between them.
#[test]
fn prop_drain_race_interleavings_deliver_exactly_once() {
    for_all_seeds(24, |rng| {
        let policy = RoutePolicy::ALL[rng.below(RoutePolicy::ALL.len())];
        let p = elastic_finishing_pool(2, 3, &cfg(2, policy, 1 + rng.below(4)));
        let (sink, rx) = channel();
        let mut ids: Vec<u64> = Vec::new();
        for _ in 0..30 {
            match rng.below(8) {
                0 | 1 | 2 => {
                    if let Some(id) = submit(&p, &sink) {
                        ids.push(id);
                    }
                }
                3 | 4 => {
                    if let Some(&id) = ids.get(rng.below(ids.len().max(1))) {
                        p.migrate(id);
                    }
                }
                5 => {
                    p.kill_replica(rng.below(p.num_replicas()));
                }
                6 => {
                    p.retire_replica(rng.below(p.num_replicas()));
                }
                _ => {
                    let _ = p.add_replica();
                }
            }
            if rng.chance(0.2) {
                p.settle(SETTLE);
            }
            p.check_invariants();
        }
        p.settle(SETTLE);
        let mut delivered: std::collections::HashMap<u64, usize> = Default::default();
        while let Ok(ev) = rx.try_recv() {
            if let ProxyEvent::Done(res) = ev {
                *delivered.entry(res.id).or_insert(0) += 1;
            }
        }
        for (id, count) in &delivered {
            assert_eq!(*count, 1, "request {id} delivered {count} times");
            assert!(ids.contains(id), "delivery for an unknown id {id}");
        }
        let stats = p.token_stats();
        assert_eq!(stats.wasted_tokens, 0, "drain races must never waste: {stats:?}");
        assert_eq!(p.resumed_dispatches(), 0, "drain races must never re-decode");
        p.check_invariants();
    });
}
