//! Replica routing for the inference fleet (paper Section 4.2 at
//! scale): where does the next `GenerationTask` go?
//!
//! The pool fronts N `LlmProxy` replicas; a `Router` picks the target
//! replica for each request from a load snapshot. Five policies:
//!
//!   * `RoundRobin` — cycle over replicas regardless of load (the
//!     baseline most serving fabrics start from). Under the paper's
//!     long-tail response lengths this stacks short requests behind
//!     30k-token stragglers.
//!   * `LeastOutstanding` — route to the replica with the fewest
//!     in-flight requests. Outstanding count is a cheap proxy for
//!     remaining work that adapts to stragglers over time.
//!   * `QueueSched` — the queue-scheduling placement of Section 5.1.1,
//!     reusing the least-loaded heuristic of `sim/queue.rs::pick_gpu`:
//!     only replicas with a free decode slot are eligible; when every
//!     replica is saturated the request is held in the *pool* queue and
//!     dispatched on the next completion, instead of over-committing a
//!     replica's continuous-batching window.
//!   * `Ewma` — latency-aware placement: the router keeps a per-replica
//!     EWMA of the observed per-request token rate (fed by
//!     [`Router::on_completion`] from both the real pool's collectors
//!     and the virtual-time `sim/fleet.rs` mirror) and routes to the
//!     replica with the smallest expected drain time,
//!     `(outstanding + 1) / rate`. Unlike `LeastOutstanding` this
//!     penalizes fail-slow or heterogeneous replicas even when their
//!     queues look short; with no measurements yet it degrades to
//!     least-outstanding, so cold replicas still get probed.
//!   * `TailAware` — length-prediction-aware packing (RollPacker,
//!     arxiv 2509.21009): the last quarter of the eligible replicas is
//!     a dedicated *long pool*; rollouts the `LengthPredictor`
//!     classifies long are packed there so stragglers share decode
//!     batches with each other instead of pinning short work, and the
//!     load score is `ReplicaLoad::predicted_remaining` *tokens* (not
//!     request count), so one 30k-token straggler outweighs ten short
//!     requests. Like `QueueSched` it only places into free decode
//!     slots — saturation holds work in the pool queue. Starvation
//!     safety is two-layered: routing is work-conserving (a class
//!     spills to the other sub-pool rather than wait for its own), and
//!     the proxy's admission order carries an explicit aging bound
//!     (`llm_proxy::AGING_LIMIT`), so neither class can be starved by
//!     the other.
//!
//! Replicas that are suspended (mid weight-sync during a rolling
//! update) are skipped by every policy, which is what lets the
//! staggered broadcast keep N-1 replicas serving.

use anyhow::{Context, Result};

/// EWMA smoothing weight for per-replica token-rate observations.
const EWMA_BETA: f64 = 0.2;

/// One replica's load, as seen by the router.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaLoad {
    /// requests routed to the replica and not yet finished
    /// (decoding + replica-side queue)
    pub outstanding: usize,
    /// decode slots (continuous-batching admission cap)
    pub slots: usize,
    /// replica is mid weight-sync (rolling update) — do not route here
    pub suspended: bool,
    /// predicted tokens still to be generated across everything in
    /// flight on the replica (predictor estimate minus gossiped decode
    /// progress; 0.0 when the predictor is cold). `TailAware`'s load
    /// score — request *cost*, where `outstanding` is request count.
    pub predicted_remaining: f64,
}

/// Per-request routing hint derived from the `LengthPredictor` and the
/// pool's KV-prefix index: how long this rollout is expected to run,
/// which admission class it falls in, and how much of its
/// `prompt ++ prefix` each replica already holds in KV. `None`/default
/// (cold predictor, disabled index) degrades `TailAware` to
/// shortest-predicted-remaining over all replicas and leaves every
/// policy's placement byte-identical to the unhinted router.
#[derive(Clone, Debug, Default)]
pub struct RouteHint {
    /// predicted tokens still to generate for this request
    pub predicted_len: f64,
    /// predictor classified this rollout into the long class
    pub long: bool,
    /// per-replica cached-prefix match length in tokens, indexed by
    /// replica slot (`KvPrefixIndex::lookup` of the task's
    /// `prompt ++ prefix`). Empty (the default) = no cache preference:
    /// the cache-aware override is skipped entirely and every policy
    /// routes exactly as before the index existed.
    pub cached: Vec<usize>,
}

/// Request-placement policy (`route_policy` in YAML / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstanding,
    QueueSched,
    Ewma,
    TailAware,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 5] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstanding,
        RoutePolicy::QueueSched,
        RoutePolicy::Ewma,
        RoutePolicy::TailAware,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::QueueSched => "queue",
            RoutePolicy::Ewma => "ewma",
            RoutePolicy::TailAware => "tail_aware",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Self::ALL.into_iter().find(|p| p.as_str() == s).with_context(|| {
            format!(
                "unknown route_policy {s:?} \
                 (round_robin|least_outstanding|queue|ewma|tail_aware)"
            )
        })
    }
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::LeastOutstanding
    }
}

/// Stateful router (round-robin cursor + per-replica EWMA token rates).
/// Shared by the real `LlmProxyPool` and the virtual-time `sim::fleet`
/// mirror so both exercise identical placement decisions.
#[derive(Clone, Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    rr_next: usize,
    /// EWMA of observed per-request token rate, tokens per (virtual or
    /// wall) second; 0.0 = no observation yet
    rates: Vec<f64>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, rr_next: 0, rates: Vec::new() }
    }

    /// Feed a completion observation: `tokens` generated in `secs` on
    /// `replica`. Both the real pool's collectors and the sim mirror
    /// call this; the unit of time only has to be self-consistent.
    pub fn on_completion(&mut self, replica: usize, tokens: f64, secs: f64) {
        if self.rates.len() <= replica {
            self.rates.resize(replica + 1, 0.0);
        }
        let inst = tokens.max(0.0) / secs.max(1e-9);
        let r = &mut self.rates[replica];
        *r = if *r == 0.0 { inst } else { EWMA_BETA * inst + (1.0 - EWMA_BETA) * *r };
    }

    /// Current rate estimate for a replica (tokens/sec; 0 = unmeasured).
    pub fn rate(&self, replica: usize) -> f64 {
        self.rates.get(replica).copied().unwrap_or(0.0)
    }

    /// Forget everything measured about a replica slot. Called when an
    /// elastic pool reuses a retired slot for a fresh replica: the new
    /// occupant must be probed from scratch, not inherit the previous
    /// occupant's EWMA token rate.
    pub fn reset_replica(&mut self, replica: usize) {
        if let Some(r) = self.rates.get_mut(replica) {
            *r = 0.0;
        }
    }

    /// Expected drain time of `replica` if one more request lands on it.
    /// Unmeasured replicas score 0 so they are probed first; ties fall
    /// back to least-outstanding, then lowest index (deterministic).
    fn ewma_score(&self, load: &ReplicaLoad, replica: usize) -> f64 {
        let rate = self.rate(replica);
        if rate <= 0.0 {
            0.0
        } else {
            (load.outstanding + 1) as f64 / rate
        }
    }

    /// Pick a replica for the next request. `None` means "hold the
    /// request in the pool queue": every replica is suspended, or (for
    /// `QueueSched`/`TailAware`) every replica's decode window is full.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> Option<usize> {
        self.route_excluding_hinted(loads, None, None)
    }

    /// [`route`](Self::route) with a per-request hint. `TailAware`
    /// reads the length class; every policy honors a non-empty
    /// `cached` vector as a placement override (longest matching
    /// cached prefix wins, work-conserving); otherwise the hint is
    /// ignored, so callers can pass whatever they know unconditionally.
    pub fn route_hinted(&mut self, loads: &[ReplicaLoad], hint: Option<RouteHint>) -> Option<usize> {
        self.route_excluding_hinted(loads, None, hint)
    }

    /// Non-mutating saturation probe: does any replica other than
    /// `exclude` have a *free decode slot* to absorb a migrated
    /// request right now? Unlike [`route_excluding`](Self::route_excluding)
    /// this never advances policy state (round-robin cursor), so the
    /// fleet can use it to choose between moving a hung request and
    /// RECLAIMing it in place — piling a migration onto a replica
    /// whose continuous-batching window is already full only trades
    /// one queue for another.
    pub fn has_free_candidate(&self, loads: &[ReplicaLoad], exclude: Option<usize>) -> bool {
        loads.iter().enumerate().any(|(i, l)| {
            !l.suspended && Some(i) != exclude && l.outstanding < l.slots
        })
    }

    /// Like [`route`](Self::route) but never returns `exclude` — used
    /// by abort-and-resubmit migration away from a hung replica.
    pub fn route_excluding(&mut self, loads: &[ReplicaLoad], exclude: Option<usize>) -> Option<usize> {
        self.route_excluding_hinted(loads, exclude, None)
    }

    /// The full placement entry point: exclusion for migration plus the
    /// request's length hint for `TailAware` class packing.
    pub fn route_excluding_hinted(
        &mut self,
        loads: &[ReplicaLoad],
        exclude: Option<usize>,
        hint: Option<RouteHint>,
    ) -> Option<usize> {
        let n = loads.len();
        if n == 0 {
            return None;
        }
        let eligible = |i: usize| !loads[i].suspended && Some(i) != exclude;
        // Cache-aware override (the KV-prefix index): if the hint names
        // replicas already holding part of this request's prefix, the
        // longest match wins — provided it is eligible AND has a free
        // decode slot (work-conserving: a hot replica's full window
        // never wedges the request; it falls through to the base
        // policy). Ties break on fewer outstanding, then lowest index.
        // An empty `cached` vector (disabled index, non-engine caller)
        // skips this entirely, keeping legacy placement byte-identical.
        if let Some(h) = hint.as_ref() {
            if !h.cached.is_empty() {
                let best = (0..n)
                    .filter(|&i| {
                        eligible(i)
                            && loads[i].outstanding < loads[i].slots
                            && h.cached.get(i).copied().unwrap_or(0) > 0
                    })
                    .max_by(|&a, &b| {
                        h.cached[a]
                            .cmp(&h.cached[b])
                            .then(loads[b].outstanding.cmp(&loads[a].outstanding))
                            .then(b.cmp(&a))
                    });
                if best.is_some() {
                    return best;
                }
            }
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                for k in 0..n {
                    let i = (self.rr_next + k) % n;
                    if eligible(i) {
                        self.rr_next = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            RoutePolicy::LeastOutstanding => (0..n)
                .filter(|&i| eligible(i))
                .min_by_key(|&i| loads[i].outstanding),
            RoutePolicy::QueueSched => (0..n)
                .filter(|&i| eligible(i) && loads[i].outstanding < loads[i].slots)
                .min_by_key(|&i| loads[i].outstanding),
            RoutePolicy::Ewma => (0..n).filter(|&i| eligible(i)).min_by(|&a, &b| {
                let (sa, sb) = (self.ewma_score(&loads[a], a), self.ewma_score(&loads[b], b));
                sa.partial_cmp(&sb)
                    .unwrap()
                    .then(loads[a].outstanding.cmp(&loads[b].outstanding))
                    .then(a.cmp(&b))
            }),
            RoutePolicy::TailAware => {
                let elig: Vec<usize> = (0..n).filter(|&i| eligible(i)).collect();
                if elig.is_empty() {
                    return None;
                }
                // the last quarter (>= 1 replica once the fleet has 2)
                // is the dedicated long pool; with a single eligible
                // replica everything shares it
                let long_n = if elig.len() >= 2 { elig.len().div_ceil(4) } else { 0 };
                let (short_pool, long_pool) = elig.split_at(elig.len() - long_n);
                let pick = |pool: &[usize]| {
                    pool.iter()
                        .copied()
                        // free decode slot required, like QueueSched:
                        // saturation backs up into the pool queue
                        .filter(|&i| loads[i].outstanding < loads[i].slots)
                        .min_by(|&a, &b| {
                            loads[a]
                                .predicted_remaining
                                .partial_cmp(&loads[b].predicted_remaining)
                                .unwrap()
                                .then(loads[a].outstanding.cmp(&loads[b].outstanding))
                                .then(a.cmp(&b))
                        })
                };
                let (preferred, other) = if hint.as_ref().is_some_and(|h| h.long) {
                    (long_pool, short_pool)
                } else {
                    (short_pool, long_pool)
                };
                // work-conserving spill: a class never waits for its
                // own sub-pool while the other has a free slot, so the
                // split can bias placement but never starve a request
                pick(preferred).or_else(|| pick(other))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(outstanding: &[usize], slots: usize) -> Vec<ReplicaLoad> {
        outstanding
            .iter()
            .map(|&o| ReplicaLoad { outstanding: o, slots, ..Default::default() })
            .collect()
    }

    /// Loads with an explicit predicted-remaining-token column.
    fn tail_loads(pred: &[f64], outstanding: &[usize], slots: usize) -> Vec<ReplicaLoad> {
        pred.iter()
            .zip(outstanding)
            .map(|(&p, &o)| ReplicaLoad {
                outstanding: o,
                slots,
                suspended: false,
                predicted_remaining: p,
            })
            .collect()
    }

    fn long_hint() -> Option<RouteHint> {
        Some(RouteHint { predicted_len: 10_000.0, long: true, ..Default::default() })
    }

    fn short_hint() -> Option<RouteHint> {
        Some(RouteHint { predicted_len: 100.0, long: false, ..Default::default() })
    }

    /// Hint carrying only a per-replica cached-prefix column.
    fn cache_hint(cached: &[usize]) -> Option<RouteHint> {
        Some(RouteHint { cached: cached.to_vec(), ..Default::default() })
    }

    #[test]
    fn policy_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_cycles_ignoring_load() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[9, 0, 0], 4);
        assert_eq!(r.route(&l), Some(0)); // load-blind
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(0));
    }

    #[test]
    fn round_robin_skips_suspended() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let mut l = loads(&[0, 0, 0], 4);
        l[0].suspended = true;
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(1));
    }

    #[test]
    fn least_outstanding_picks_min_with_stable_ties() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        assert_eq!(r.route(&loads(&[3, 1, 2], 4)), Some(1));
        // tie: lowest index wins (deterministic)
        assert_eq!(r.route(&loads(&[2, 1, 1], 4)), Some(1));
        // over-committed replicas are still eligible (replica queues)
        assert_eq!(r.route(&loads(&[9, 8, 10], 4)), Some(1));
    }

    #[test]
    fn queue_sched_requires_free_slot() {
        let mut r = Router::new(RoutePolicy::QueueSched);
        // replica 1 has the only free slot
        assert_eq!(r.route(&loads(&[4, 3, 4], 4)), Some(1));
        // pool saturated: hold in the pool queue
        assert_eq!(r.route(&loads(&[4, 4, 4], 4)), None);
    }

    #[test]
    fn ewma_cold_start_degrades_to_least_outstanding() {
        let mut r = Router::new(RoutePolicy::Ewma);
        // no observations: all scores 0, least-outstanding tie-break
        assert_eq!(r.route(&loads(&[3, 1, 2], 4)), Some(1));
        assert_eq!(r.route(&loads(&[2, 1, 1], 4)), Some(1));
    }

    #[test]
    fn ewma_penalizes_slow_replica_despite_short_queue() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 10.0); // 10 tok/s: fail-slow
        r.on_completion(1, 100.0, 1.0); // 100 tok/s
        // replica 0 has the shorter queue but 10x the drain time:
        // (1+1)/10 = 0.2 vs (3+1)/100 = 0.04
        assert_eq!(r.route(&loads(&[1, 3], 8)), Some(1));
        // least-outstanding would have picked the slow one
        let mut lo = Router::new(RoutePolicy::LeastOutstanding);
        assert_eq!(lo.route(&loads(&[1, 3], 8)), Some(0));
    }

    #[test]
    fn ewma_probes_unmeasured_replicas_first() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 1.0);
        // replica 1 unmeasured (score 0) wins even with a longer queue
        assert_eq!(r.route(&loads(&[0, 2], 8)), Some(1));
    }

    #[test]
    fn ewma_smooths_observations() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 1.0); // first sample sets the rate
        assert!((r.rate(0) - 100.0).abs() < 1e-9);
        r.on_completion(0, 200.0, 1.0);
        // 0.2 * 200 + 0.8 * 100 = 120
        assert!((r.rate(0) - 120.0).abs() < 1e-9);
        assert_eq!(r.rate(5), 0.0); // never observed
    }

    #[test]
    fn reset_replica_clears_rate_for_slot_reuse() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 10.0, 10.0); // 1 tok/s: a cripple lived here
        r.on_completion(1, 100.0, 1.0);
        // slot 0 is reused by a fresh replica: without the reset the new
        // occupant would inherit the cripple's rate and be starved
        r.reset_replica(0);
        assert_eq!(r.rate(0), 0.0);
        // unmeasured again: probed first despite the other's history
        assert_eq!(r.route(&loads(&[0, 0], 8)), Some(0));
        // resetting an index never measured is a no-op
        r.reset_replica(17);
        assert_eq!(r.rate(17), 0.0);
    }

    #[test]
    fn all_suspended_holds_request() {
        for p in RoutePolicy::ALL {
            let mut r = Router::new(p);
            let mut l = loads(&[0, 0], 4);
            l[0].suspended = true;
            l[1].suspended = true;
            assert_eq!(r.route(&l), None, "{p:?}");
        }
    }

    #[test]
    fn exclusion_for_migration() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        // replica 0 is least loaded but hung: exclusion forces 1
        assert_eq!(r.route_excluding(&loads(&[0, 5, 7], 4), Some(0)), Some(1));
        // single replica: nowhere to migrate
        assert_eq!(r.route_excluding(&loads(&[0], 4), Some(0)), None);
    }

    #[test]
    fn empty_fleet_routes_nowhere() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.route(&[]), None);
    }

    #[test]
    fn free_candidate_probe_sees_slots_and_exclusion() {
        let r = Router::new(RoutePolicy::LeastOutstanding);
        // replica 1 has the only free window
        assert!(r.has_free_candidate(&loads(&[4, 3], 4), None));
        // ...but not when it is the excluded (hung) replica
        assert!(!r.has_free_candidate(&loads(&[4, 3], 4), Some(1)));
        // fully saturated fleet: nowhere to move anything
        assert!(!r.has_free_candidate(&loads(&[4, 4, 4], 4), None));
        // suspension hides a free window
        let mut l = loads(&[0, 4], 4);
        l[0].suspended = true;
        assert!(!r.has_free_candidate(&l, None));
        assert!(!r.has_free_candidate(&[], None));
    }

    #[test]
    fn tail_aware_packs_long_work_onto_the_dedicated_pool() {
        let mut r = Router::new(RoutePolicy::TailAware);
        // 4 replicas: replicas 0..3 short pool, replica 3 long pool
        let l = tail_loads(&[0.0, 0.0, 0.0, 0.0], &[0, 0, 0, 0], 4);
        assert_eq!(r.route_hinted(&l, long_hint()), Some(3), "long work goes to the long pool");
        assert_eq!(r.route_hinted(&l, short_hint()), Some(0), "short work stays together");
        // no hint (cold predictor / non-engine caller) behaves short
        assert_eq!(r.route_hinted(&l, None), Some(0));
    }

    #[test]
    fn tail_aware_scores_by_predicted_tokens_not_request_count() {
        let mut r = Router::new(RoutePolicy::TailAware);
        // replica 0 holds ONE 30k-token straggler, replica 1 holds
        // three short rollouts: request-count routing (least
        // outstanding) would stack onto the straggler; token-aware
        // routing must not
        let l = tail_loads(&[30_000.0, 600.0, 0.0], &[1, 3, 4], 4);
        assert_eq!(r.route_hinted(&l, short_hint()), Some(1));
        // ties on predicted tokens fall back to outstanding, then index
        let l = tail_loads(&[500.0, 500.0, 0.0], &[2, 1, 4], 4);
        assert_eq!(r.route_hinted(&l, short_hint()), Some(1));
    }

    #[test]
    fn tail_aware_spills_rather_than_starving_a_class() {
        let mut r = Router::new(RoutePolicy::TailAware);
        // long pool (replica 3) is saturated: long work spills into the
        // short pool instead of waiting behind its own class
        let l = tail_loads(&[0.0, 0.0, 0.0, 9e9], &[0, 0, 0, 4], 4);
        assert_eq!(r.route_hinted(&l, long_hint()), Some(0));
        // short pool saturated: short work spills into the long pool
        let l = tail_loads(&[9e9, 9e9, 9e9, 0.0], &[4, 4, 4, 0], 4);
        assert_eq!(r.route_hinted(&l, short_hint()), Some(3));
        // everything saturated: hold in the pool queue (QueueSched
        // semantics — never over-commit a decode window)
        let l = tail_loads(&[1.0, 1.0, 1.0, 1.0], &[4, 4, 4, 4], 4);
        assert_eq!(r.route_hinted(&l, long_hint()), None);
        assert_eq!(r.route_hinted(&l, short_hint()), None);
    }

    #[test]
    fn tail_aware_single_replica_serves_both_classes() {
        let mut r = Router::new(RoutePolicy::TailAware);
        let l = tail_loads(&[0.0], &[0], 4);
        assert_eq!(r.route_hinted(&l, long_hint()), Some(0));
        assert_eq!(r.route_hinted(&l, short_hint()), Some(0));
    }

    #[test]
    fn tail_aware_respects_suspension_and_exclusion() {
        let mut r = Router::new(RoutePolicy::TailAware);
        let mut l = tail_loads(&[0.0, 0.0, 0.0, 0.0], &[0, 0, 0, 0], 4);
        // the long replica is suspended mid-sync: the split recomputes
        // over the remaining eligible set (last of {0,1,2} = 2)
        l[3].suspended = true;
        assert_eq!(r.route_hinted(&l, long_hint()), Some(2));
        // exclusion (migration away from a hung replica) is honored
        let l = tail_loads(&[0.0, 5.0, 0.0], &[0, 1, 0], 4);
        assert_eq!(r.route_excluding_hinted(&l, Some(0), short_hint()), Some(1));
    }

    #[test]
    fn hint_is_ignored_by_every_other_policy() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::LeastOutstanding,
            RoutePolicy::QueueSched,
            RoutePolicy::Ewma,
        ] {
            let mut hinted = Router::new(p);
            let mut plain = Router::new(p);
            let l = loads(&[2, 0, 1], 4);
            assert_eq!(hinted.route_hinted(&l, long_hint()), plain.route(&l), "{p:?}");
        }
    }

    #[test]
    fn empty_cached_hint_is_byte_identical_for_every_policy() {
        // the legacy guarantee: a hint without cache information (the
        // only kind that exists when `kv_cache` is disabled) must not
        // perturb any policy's decision sequence, cursor included
        for p in RoutePolicy::ALL {
            let mut hinted = Router::new(p);
            let mut plain = Router::new(p);
            for l in [loads(&[2, 0, 1], 4), loads(&[0, 0, 0], 4), loads(&[4, 4, 1], 4)] {
                for _ in 0..5 {
                    assert_eq!(
                        hinted.route_hinted(&l, cache_hint(&[])),
                        plain.route(&l),
                        "{p:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn cache_override_prefers_longest_matching_prefix() {
        // replica 2 holds the longest cached prefix: every policy sends
        // the request there, whatever its own score says
        for p in RoutePolicy::ALL {
            let mut r = Router::new(p);
            let l = loads(&[0, 1, 2], 4);
            assert_eq!(r.route_hinted(&l, cache_hint(&[64, 128, 512])), Some(2), "{p:?}");
        }
    }

    #[test]
    fn cache_override_is_work_conserving() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        // best-cached replica 2 has a full decode window: the override
        // falls to the next cached replica with a free slot
        let l = loads(&[0, 1, 4], 4);
        assert_eq!(r.route_hinted(&l, cache_hint(&[0, 128, 512])), Some(1));
        // every cached replica is full: fall through to the base policy
        // rather than wedging behind the hot replica
        let l = loads(&[0, 4, 4], 4);
        assert_eq!(r.route_hinted(&l, cache_hint(&[0, 128, 512])), Some(0));
        // saturated QueueSched fleet: cached-but-full holds in queue
        let mut q = Router::new(RoutePolicy::QueueSched);
        let l = loads(&[4, 4, 4], 4);
        assert_eq!(q.route_hinted(&l, cache_hint(&[0, 0, 512])), None);
    }

    #[test]
    fn cache_override_ties_break_on_outstanding_then_index() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        // equal match length: fewer outstanding wins
        let l = loads(&[3, 1, 2], 4);
        assert_eq!(r.route_hinted(&l, cache_hint(&[256, 256, 0])), Some(1));
        // full tie: lowest index (deterministic)
        let l = loads(&[1, 1, 1], 4);
        assert_eq!(r.route_hinted(&l, cache_hint(&[256, 256, 256])), Some(0));
    }

    #[test]
    fn cache_override_honors_exclusion_and_suspension() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        // the cached replica is the one being migrated away from:
        // exclusion is hard, cache preference never resurrects it
        let l = loads(&[0, 5], 4);
        assert_eq!(r.route_excluding_hinted(&l, Some(0), cache_hint(&[512, 0])), Some(1));
        // suspended mid weight-sync: same
        let mut l = loads(&[0, 5], 4);
        l[0].suspended = true;
        assert_eq!(r.route_hinted(&l, cache_hint(&[512, 0])), Some(1));
        // a short cached column never panics on a larger fleet
        let l = loads(&[5, 5, 0], 4);
        assert_eq!(r.route_hinted(&l, cache_hint(&[0, 9])), Some(1));
    }

    #[test]
    fn free_candidate_probe_never_mutates_policy_state() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[0, 0, 0], 4);
        assert!(r.has_free_candidate(&l, Some(0)));
        // the probe must not have advanced the round-robin cursor
        assert_eq!(r.route(&l), Some(0));
    }
}
