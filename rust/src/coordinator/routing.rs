//! Replica routing for the inference fleet (paper Section 4.2 at
//! scale): where does the next `GenerationTask` go?
//!
//! The pool fronts N `LlmProxy` replicas; a `Router` picks the target
//! replica for each request from a load snapshot. Four policies:
//!
//!   * `RoundRobin` — cycle over replicas regardless of load (the
//!     baseline most serving fabrics start from). Under the paper's
//!     long-tail response lengths this stacks short requests behind
//!     30k-token stragglers.
//!   * `LeastOutstanding` — route to the replica with the fewest
//!     in-flight requests. Outstanding count is a cheap proxy for
//!     remaining work that adapts to stragglers over time.
//!   * `QueueSched` — the queue-scheduling placement of Section 5.1.1,
//!     reusing the least-loaded heuristic of `sim/queue.rs::pick_gpu`:
//!     only replicas with a free decode slot are eligible; when every
//!     replica is saturated the request is held in the *pool* queue and
//!     dispatched on the next completion, instead of over-committing a
//!     replica's continuous-batching window.
//!   * `Ewma` — latency-aware placement: the router keeps a per-replica
//!     EWMA of the observed per-request token rate (fed by
//!     [`Router::on_completion`] from both the real pool's collectors
//!     and the virtual-time `sim/fleet.rs` mirror) and routes to the
//!     replica with the smallest expected drain time,
//!     `(outstanding + 1) / rate`. Unlike `LeastOutstanding` this
//!     penalizes fail-slow or heterogeneous replicas even when their
//!     queues look short; with no measurements yet it degrades to
//!     least-outstanding, so cold replicas still get probed.
//!
//! Replicas that are suspended (mid weight-sync during a rolling
//! update) are skipped by every policy, which is what lets the
//! staggered broadcast keep N-1 replicas serving.

use anyhow::{Context, Result};

/// EWMA smoothing weight for per-replica token-rate observations.
const EWMA_BETA: f64 = 0.2;

/// One replica's load, as seen by the router.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaLoad {
    /// requests routed to the replica and not yet finished
    /// (decoding + replica-side queue)
    pub outstanding: usize,
    /// decode slots (continuous-batching admission cap)
    pub slots: usize,
    /// replica is mid weight-sync (rolling update) — do not route here
    pub suspended: bool,
}

/// Request-placement policy (`route_policy` in YAML / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstanding,
    QueueSched,
    Ewma,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 4] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstanding,
        RoutePolicy::QueueSched,
        RoutePolicy::Ewma,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::QueueSched => "queue",
            RoutePolicy::Ewma => "ewma",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Self::ALL.into_iter().find(|p| p.as_str() == s).with_context(|| {
            format!("unknown route_policy {s:?} (round_robin|least_outstanding|queue|ewma)")
        })
    }
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::LeastOutstanding
    }
}

/// Stateful router (round-robin cursor + per-replica EWMA token rates).
/// Shared by the real `LlmProxyPool` and the virtual-time `sim::fleet`
/// mirror so both exercise identical placement decisions.
#[derive(Clone, Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    rr_next: usize,
    /// EWMA of observed per-request token rate, tokens per (virtual or
    /// wall) second; 0.0 = no observation yet
    rates: Vec<f64>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, rr_next: 0, rates: Vec::new() }
    }

    /// Feed a completion observation: `tokens` generated in `secs` on
    /// `replica`. Both the real pool's collectors and the sim mirror
    /// call this; the unit of time only has to be self-consistent.
    pub fn on_completion(&mut self, replica: usize, tokens: f64, secs: f64) {
        if self.rates.len() <= replica {
            self.rates.resize(replica + 1, 0.0);
        }
        let inst = tokens.max(0.0) / secs.max(1e-9);
        let r = &mut self.rates[replica];
        *r = if *r == 0.0 { inst } else { EWMA_BETA * inst + (1.0 - EWMA_BETA) * *r };
    }

    /// Current rate estimate for a replica (tokens/sec; 0 = unmeasured).
    pub fn rate(&self, replica: usize) -> f64 {
        self.rates.get(replica).copied().unwrap_or(0.0)
    }

    /// Forget everything measured about a replica slot. Called when an
    /// elastic pool reuses a retired slot for a fresh replica: the new
    /// occupant must be probed from scratch, not inherit the previous
    /// occupant's EWMA token rate.
    pub fn reset_replica(&mut self, replica: usize) {
        if let Some(r) = self.rates.get_mut(replica) {
            *r = 0.0;
        }
    }

    /// Expected drain time of `replica` if one more request lands on it.
    /// Unmeasured replicas score 0 so they are probed first; ties fall
    /// back to least-outstanding, then lowest index (deterministic).
    fn ewma_score(&self, load: &ReplicaLoad, replica: usize) -> f64 {
        let rate = self.rate(replica);
        if rate <= 0.0 {
            0.0
        } else {
            (load.outstanding + 1) as f64 / rate
        }
    }

    /// Pick a replica for the next request. `None` means "hold the
    /// request in the pool queue": every replica is suspended, or (for
    /// `QueueSched`) every replica's decode window is full.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> Option<usize> {
        self.route_excluding(loads, None)
    }

    /// Non-mutating saturation probe: does any replica other than
    /// `exclude` have a *free decode slot* to absorb a migrated
    /// request right now? Unlike [`route_excluding`](Self::route_excluding)
    /// this never advances policy state (round-robin cursor), so the
    /// fleet can use it to choose between moving a hung request and
    /// RECLAIMing it in place — piling a migration onto a replica
    /// whose continuous-batching window is already full only trades
    /// one queue for another.
    pub fn has_free_candidate(&self, loads: &[ReplicaLoad], exclude: Option<usize>) -> bool {
        loads.iter().enumerate().any(|(i, l)| {
            !l.suspended && Some(i) != exclude && l.outstanding < l.slots
        })
    }

    /// Like [`route`](Self::route) but never returns `exclude` — used
    /// by abort-and-resubmit migration away from a hung replica.
    pub fn route_excluding(&mut self, loads: &[ReplicaLoad], exclude: Option<usize>) -> Option<usize> {
        let n = loads.len();
        if n == 0 {
            return None;
        }
        let eligible = |i: usize| !loads[i].suspended && Some(i) != exclude;
        match self.policy {
            RoutePolicy::RoundRobin => {
                for k in 0..n {
                    let i = (self.rr_next + k) % n;
                    if eligible(i) {
                        self.rr_next = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            RoutePolicy::LeastOutstanding => (0..n)
                .filter(|&i| eligible(i))
                .min_by_key(|&i| loads[i].outstanding),
            RoutePolicy::QueueSched => (0..n)
                .filter(|&i| eligible(i) && loads[i].outstanding < loads[i].slots)
                .min_by_key(|&i| loads[i].outstanding),
            RoutePolicy::Ewma => (0..n).filter(|&i| eligible(i)).min_by(|&a, &b| {
                let (sa, sb) = (self.ewma_score(&loads[a], a), self.ewma_score(&loads[b], b));
                sa.partial_cmp(&sb)
                    .unwrap()
                    .then(loads[a].outstanding.cmp(&loads[b].outstanding))
                    .then(a.cmp(&b))
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(outstanding: &[usize], slots: usize) -> Vec<ReplicaLoad> {
        outstanding
            .iter()
            .map(|&o| ReplicaLoad { outstanding: o, slots, suspended: false })
            .collect()
    }

    #[test]
    fn policy_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_cycles_ignoring_load() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[9, 0, 0], 4);
        assert_eq!(r.route(&l), Some(0)); // load-blind
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(0));
    }

    #[test]
    fn round_robin_skips_suspended() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let mut l = loads(&[0, 0, 0], 4);
        l[0].suspended = true;
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(1));
    }

    #[test]
    fn least_outstanding_picks_min_with_stable_ties() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        assert_eq!(r.route(&loads(&[3, 1, 2], 4)), Some(1));
        // tie: lowest index wins (deterministic)
        assert_eq!(r.route(&loads(&[2, 1, 1], 4)), Some(1));
        // over-committed replicas are still eligible (replica queues)
        assert_eq!(r.route(&loads(&[9, 8, 10], 4)), Some(1));
    }

    #[test]
    fn queue_sched_requires_free_slot() {
        let mut r = Router::new(RoutePolicy::QueueSched);
        // replica 1 has the only free slot
        assert_eq!(r.route(&loads(&[4, 3, 4], 4)), Some(1));
        // pool saturated: hold in the pool queue
        assert_eq!(r.route(&loads(&[4, 4, 4], 4)), None);
    }

    #[test]
    fn ewma_cold_start_degrades_to_least_outstanding() {
        let mut r = Router::new(RoutePolicy::Ewma);
        // no observations: all scores 0, least-outstanding tie-break
        assert_eq!(r.route(&loads(&[3, 1, 2], 4)), Some(1));
        assert_eq!(r.route(&loads(&[2, 1, 1], 4)), Some(1));
    }

    #[test]
    fn ewma_penalizes_slow_replica_despite_short_queue() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 10.0); // 10 tok/s: fail-slow
        r.on_completion(1, 100.0, 1.0); // 100 tok/s
        // replica 0 has the shorter queue but 10x the drain time:
        // (1+1)/10 = 0.2 vs (3+1)/100 = 0.04
        assert_eq!(r.route(&loads(&[1, 3], 8)), Some(1));
        // least-outstanding would have picked the slow one
        let mut lo = Router::new(RoutePolicy::LeastOutstanding);
        assert_eq!(lo.route(&loads(&[1, 3], 8)), Some(0));
    }

    #[test]
    fn ewma_probes_unmeasured_replicas_first() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 1.0);
        // replica 1 unmeasured (score 0) wins even with a longer queue
        assert_eq!(r.route(&loads(&[0, 2], 8)), Some(1));
    }

    #[test]
    fn ewma_smooths_observations() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 100.0, 1.0); // first sample sets the rate
        assert!((r.rate(0) - 100.0).abs() < 1e-9);
        r.on_completion(0, 200.0, 1.0);
        // 0.2 * 200 + 0.8 * 100 = 120
        assert!((r.rate(0) - 120.0).abs() < 1e-9);
        assert_eq!(r.rate(5), 0.0); // never observed
    }

    #[test]
    fn reset_replica_clears_rate_for_slot_reuse() {
        let mut r = Router::new(RoutePolicy::Ewma);
        r.on_completion(0, 10.0, 10.0); // 1 tok/s: a cripple lived here
        r.on_completion(1, 100.0, 1.0);
        // slot 0 is reused by a fresh replica: without the reset the new
        // occupant would inherit the cripple's rate and be starved
        r.reset_replica(0);
        assert_eq!(r.rate(0), 0.0);
        // unmeasured again: probed first despite the other's history
        assert_eq!(r.route(&loads(&[0, 0], 8)), Some(0));
        // resetting an index never measured is a no-op
        r.reset_replica(17);
        assert_eq!(r.rate(17), 0.0);
    }

    #[test]
    fn all_suspended_holds_request() {
        for p in RoutePolicy::ALL {
            let mut r = Router::new(p);
            let mut l = loads(&[0, 0], 4);
            l[0].suspended = true;
            l[1].suspended = true;
            assert_eq!(r.route(&l), None, "{p:?}");
        }
    }

    #[test]
    fn exclusion_for_migration() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        // replica 0 is least loaded but hung: exclusion forces 1
        assert_eq!(r.route_excluding(&loads(&[0, 5, 7], 4), Some(0)), Some(1));
        // single replica: nowhere to migrate
        assert_eq!(r.route_excluding(&loads(&[0], 4), Some(0)), None);
    }

    #[test]
    fn empty_fleet_routes_nowhere() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.route(&[]), None);
    }

    #[test]
    fn free_candidate_probe_sees_slots_and_exclusion() {
        let r = Router::new(RoutePolicy::LeastOutstanding);
        // replica 1 has the only free window
        assert!(r.has_free_candidate(&loads(&[4, 3], 4), None));
        // ...but not when it is the excluded (hung) replica
        assert!(!r.has_free_candidate(&loads(&[4, 3], 4), Some(1)));
        // fully saturated fleet: nowhere to move anything
        assert!(!r.has_free_candidate(&loads(&[4, 4, 4], 4), None));
        // suspension hides a free window
        let mut l = loads(&[0, 4], 4);
        l[0].suspended = true;
        assert!(!r.has_free_candidate(&l, None));
        assert!(!r.has_free_candidate(&[], None));
    }

    #[test]
    fn free_candidate_probe_never_mutates_policy_state() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[0, 0, 0], 4);
        assert!(r.has_free_candidate(&l, Some(0)));
        // the probe must not have advanced the round-robin cursor
        assert_eq!(r.route(&l), Some(0));
    }
}
