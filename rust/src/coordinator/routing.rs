//! Replica routing for the inference fleet (paper Section 4.2 at
//! scale): where does the next `GenRequest` go?
//!
//! The pool fronts N `LlmProxy` replicas; a `Router` picks the target
//! replica for each request from a load snapshot. Three policies:
//!
//!   * `RoundRobin` — cycle over replicas regardless of load (the
//!     baseline most serving fabrics start from). Under the paper's
//!     long-tail response lengths this stacks short requests behind
//!     30k-token stragglers.
//!   * `LeastOutstanding` — route to the replica with the fewest
//!     in-flight requests. Outstanding count is a cheap proxy for
//!     remaining work that adapts to stragglers over time.
//!   * `QueueSched` — the queue-scheduling placement of Section 5.1.1,
//!     reusing the least-loaded heuristic of `sim/queue.rs::pick_gpu`:
//!     only replicas with a free decode slot are eligible; when every
//!     replica is saturated the request is held in the *pool* queue and
//!     dispatched on the next completion, instead of over-committing a
//!     replica's continuous-batching window.
//!
//! Replicas that are suspended (mid weight-sync during a rolling
//! update) are skipped by every policy, which is what lets the
//! staggered broadcast keep N-1 replicas serving.

use anyhow::{Context, Result};

/// One replica's load, as seen by the router.
#[derive(Clone, Copy, Debug, Default)]
pub struct ReplicaLoad {
    /// requests routed to the replica and not yet finished
    /// (decoding + replica-side queue)
    pub outstanding: usize,
    /// decode slots (continuous-batching admission cap)
    pub slots: usize,
    /// replica is mid weight-sync (rolling update) — do not route here
    pub suspended: bool,
}

/// Request-placement policy (`route_policy` in YAML / CLI).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum RoutePolicy {
    RoundRobin,
    LeastOutstanding,
    QueueSched,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastOutstanding, RoutePolicy::QueueSched];

    pub fn as_str(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round_robin",
            RoutePolicy::LeastOutstanding => "least_outstanding",
            RoutePolicy::QueueSched => "queue",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Self::ALL
            .into_iter()
            .find(|p| p.as_str() == s)
            .with_context(|| format!("unknown route_policy {s:?} (round_robin|least_outstanding|queue)"))
    }
}

impl Default for RoutePolicy {
    fn default() -> Self {
        RoutePolicy::LeastOutstanding
    }
}

/// Stateful router (the round-robin cursor is the only state). Shared
/// by the real `LlmProxyPool` and the virtual-time `sim::fleet` mirror
/// so both exercise identical placement decisions.
#[derive(Clone, Debug)]
pub struct Router {
    pub policy: RoutePolicy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Self {
        Router { policy, rr_next: 0 }
    }

    /// Pick a replica for the next request. `None` means "hold the
    /// request in the pool queue": every replica is suspended, or (for
    /// `QueueSched`) every replica's decode window is full.
    pub fn route(&mut self, loads: &[ReplicaLoad]) -> Option<usize> {
        self.route_excluding(loads, None)
    }

    /// Like [`route`](Self::route) but never returns `exclude` — used
    /// by abort-and-resubmit migration away from a hung replica.
    pub fn route_excluding(&mut self, loads: &[ReplicaLoad], exclude: Option<usize>) -> Option<usize> {
        let n = loads.len();
        if n == 0 {
            return None;
        }
        let eligible = |i: usize| !loads[i].suspended && Some(i) != exclude;
        match self.policy {
            RoutePolicy::RoundRobin => {
                for k in 0..n {
                    let i = (self.rr_next + k) % n;
                    if eligible(i) {
                        self.rr_next = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
            RoutePolicy::LeastOutstanding => (0..n)
                .filter(|&i| eligible(i))
                .min_by_key(|&i| loads[i].outstanding),
            RoutePolicy::QueueSched => (0..n)
                .filter(|&i| eligible(i) && loads[i].outstanding < loads[i].slots)
                .min_by_key(|&i| loads[i].outstanding),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(outstanding: &[usize], slots: usize) -> Vec<ReplicaLoad> {
        outstanding
            .iter()
            .map(|&o| ReplicaLoad { outstanding: o, slots, suspended: false })
            .collect()
    }

    #[test]
    fn policy_roundtrip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.as_str()).unwrap(), p);
        }
        assert!(RoutePolicy::parse("bogus").is_err());
    }

    #[test]
    fn round_robin_cycles_ignoring_load() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let l = loads(&[9, 0, 0], 4);
        assert_eq!(r.route(&l), Some(0)); // load-blind
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(0));
    }

    #[test]
    fn round_robin_skips_suspended() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let mut l = loads(&[0, 0, 0], 4);
        l[0].suspended = true;
        assert_eq!(r.route(&l), Some(1));
        assert_eq!(r.route(&l), Some(2));
        assert_eq!(r.route(&l), Some(1));
    }

    #[test]
    fn least_outstanding_picks_min_with_stable_ties() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        assert_eq!(r.route(&loads(&[3, 1, 2], 4)), Some(1));
        // tie: lowest index wins (deterministic)
        assert_eq!(r.route(&loads(&[2, 1, 1], 4)), Some(1));
        // over-committed replicas are still eligible (replica queues)
        assert_eq!(r.route(&loads(&[9, 8, 10], 4)), Some(1));
    }

    #[test]
    fn queue_sched_requires_free_slot() {
        let mut r = Router::new(RoutePolicy::QueueSched);
        // replica 1 has the only free slot
        assert_eq!(r.route(&loads(&[4, 3, 4], 4)), Some(1));
        // pool saturated: hold in the pool queue
        assert_eq!(r.route(&loads(&[4, 4, 4], 4)), None);
    }

    #[test]
    fn all_suspended_holds_request() {
        for p in RoutePolicy::ALL {
            let mut r = Router::new(p);
            let mut l = loads(&[0, 0], 4);
            l[0].suspended = true;
            l[1].suspended = true;
            assert_eq!(r.route(&l), None, "{p:?}");
        }
    }

    #[test]
    fn exclusion_for_migration() {
        let mut r = Router::new(RoutePolicy::LeastOutstanding);
        // replica 0 is least loaded but hung: exclusion forces 1
        assert_eq!(r.route_excluding(&loads(&[0, 5, 7], 4), Some(0)), Some(1));
        // single replica: nowhere to migrate
        assert_eq!(r.route_excluding(&loads(&[0], 4), Some(0)), None);
    }

    #[test]
    fn empty_fleet_routes_nowhere() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        assert_eq!(r.route(&[]), None);
    }
}
