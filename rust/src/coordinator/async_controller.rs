//! AsyncController (paper Section 4.2): the training-side orchestrator.
//!
//! Each iteration issues a blocking `get_batch` to the SampleBuffer,
//! runs `train_step` minibatches on the retrieved data, then performs
//! the three-phase weight synchronization: suspend -> model_update
//! (fetch + broadcast latest weights to the inference fleet) -> resume.
//! With `rolling_update` the broadcast staggers across replicas (the
//! pool's sync agent pauses at most one at a time, so the rollout
//! stage never fully stalls). In asynchronous mode the rollout stage
//! keeps collecting in parallel; switching to synchronous mode is
//! exactly the paper's recipe — "invoking suspend immediately after
//! get_batch".

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::config::PgVariant;
use crate::coordinator::async_governor::{AsyncGovernor, AsyncMode, GovernorCfg};
use crate::coordinator::autoscaler::{AutoscaleCfg, Autoscaler};
use crate::coordinator::fleet::LlmProxyPool;
use crate::coordinator::sample_buffer::{BufferStats, SampleBuffer};
use crate::metrics::prometheus;
use crate::metrics::telemetry::{self, TelemetryCfg, TelemetryPlane, TelemetryStatus};
use crate::metrics::trace::{AttrSnapshot, EventPhase};
use crate::rl;
use crate::runtime::{ModelRuntime, TrainState};

#[derive(Clone, Debug)]
pub struct ControllerCfg {
    pub variant: PgVariant,
    pub steps: usize,
    pub lr: f32,
    /// prompts consumed per training step (rollout_batch_size)
    pub n_groups: usize,
    pub group_size: usize,
    /// synchronous mode: suspend rollout during training
    pub sync_mode: bool,
    /// elastic fleet: tick the queue-driven autoscaler between steps.
    /// Only meaningful in async mode — a synchronous step leaves no
    /// rollout running to scale against — and ignored when absent or
    /// disabled.
    pub autoscale: Option<AutoscaleCfg>,
    /// live telemetry plane: ticked between steps with pool + buffer
    /// signals; produces windowed bottleneck verdicts, watchdog
    /// alerts, and (at end of run) Prometheus / verdict-JSONL exports.
    /// Absent or disabled = zero cost, legacy behavior byte-identical.
    pub telemetry: Option<TelemetryCfg>,
    /// adaptive asynchrony governor: dial sync/barrier/one-step-off/
    /// fully-async at runtime off the telemetry plane's measured
    /// version-gap windows. Requires `telemetry` — the governor only
    /// acts on closed windows. Absent or disabled = the static
    /// `sync_mode` branch runs untouched.
    pub governor: Option<GovernorCfg>,
}

/// Per-step training log (the Fig 4-style curve data).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub mean_ratio: f32,
    pub max_ratio: f32,
    pub clip_frac: f32,
    pub entropy: f32,
    pub reward_mean: f32,
    pub pass_rate: f32,
    pub mean_version_gap: f64,
    /// largest consumed-sample version gap observed so far (cumulative
    /// BufferStats::max_version_gap at the end of this step)
    pub max_version_gap: u64,
    /// rolling-sync lag across inference replicas right after this
    /// step's model_update (max - min acknowledged weight version)
    pub replica_version_skew: u64,
    /// samples consumed THIS step whose behavior policy was piecewise
    /// across a weight update (a salvaged prefix resumed under newer
    /// weights — partial migration). Zero whenever salvage is off or
    /// no migration straddled a model_update.
    pub cross_version_samples: usize,
    /// decoded tokens salvaged by migration/resubmission during this
    /// step (fleet-wide delta of the pool's TokenLedger)
    pub salvaged_tokens: u64,
    /// decoded tokens discarded without salvage during this step
    /// (aborts + from-scratch migration; the fail-slow/fail-stop bill)
    pub wasted_tokens: u64,
    /// prompt/prefix tokens served from a replica's KV cache instead
    /// of being re-prefilled this step (fleet-wide ledger delta; zero
    /// while `kv_cache` is disabled)
    pub prefix_hit_tokens: u64,
    /// routable inference replicas at the end of this step — moves
    /// between autoscale bounds when the elastic fleet is on, constant
    /// otherwise
    pub serving_replicas: usize,
    pub wall_secs: f64,
    /// where the fleet's replica-seconds went during this step — the
    /// per-step delta of the pool's time attribution (decode-busy,
    /// prefill, salvage replay, weight-sync pause, draining, idle
    /// bubble). `attr.serving_total()` tracks
    /// `serving_replicas × wall_secs` for a static fleet.
    pub attr: AttrSnapshot,
    /// p50 episode-completion latency (dispatch → Done, seconds) among
    /// episodes that finished during this step; 0 when none did
    pub lat_p50: f64,
    /// p99 episode-completion latency for the same window — the
    /// long-tail scoreboard the length-aware scheduling drives down
    pub lat_p99: f64,
    /// latest telemetry-window summary (verdict + active watchdogs);
    /// `None` until the first window closes, or always while the
    /// `telemetry:` block is absent — in which case `format_log`'s
    /// line is byte-identical to the legacy output
    pub telemetry: Option<TelemetryStatus>,
    /// asynchrony mode this step ran under — `None` while the
    /// governor is off (legacy lines stay byte-identical)
    pub mode: Option<AsyncMode>,
}

/// Run the training loop. `rt`/`st` belong to the calling thread (the
/// trainer owns its own PJRT runtime — weights cross threads only as
/// flat vectors, the paper's model_update broadcast).
pub fn run_training(
    rt: &ModelRuntime,
    st: &mut TrainState,
    proxy: &Arc<LlmProxyPool>,
    buffer: &Arc<SampleBuffer>,
    cfg: &ControllerCfg,
) -> Result<Vec<StepLog>> {
    let b = rt.manifest.train_batch;
    let s = rt.manifest.max_seq;
    let per_step = cfg.n_groups * cfg.group_size;
    anyhow::ensure!(
        per_step % b == 0,
        "sequences per step ({per_step}) must be a multiple of train_batch ({b})"
    );
    let mut logs = Vec::with_capacity(cfg.steps);
    // elastic fleet: the control loop lives on the training thread and
    // runs between steps, where the pool's signals reflect a full
    // collection interval. Sync mode suspends rollout during training,
    // so there is nothing to scale against — the scaler stays off.
    let mut autoscaler = cfg
        .autoscale
        .filter(|a| a.enabled && !cfg.sync_mode)
        .map(Autoscaler::new);
    // live telemetry plane: caller-clocked off the pool recorder's
    // epoch; the first tick below seeds the t=0 baseline so windows
    // tile the run from its start. None = every check is one branch
    // and the legacy step loop is untouched.
    let mut plane = cfg
        .telemetry
        .as_ref()
        .filter(|t| t.enabled)
        .map(|t| TelemetryPlane::new(t.clone()));
    // adaptive asynchrony governor: acts only on closed telemetry
    // windows, so it requires the plane. The step quota (the N its
    // outstanding cap scales from) is resolved from the batch shape
    // when the config left it open.
    let mut governor = cfg
        .governor
        .filter(|g| g.enabled)
        .map(|mut g| {
            if g.step_quota == 0 {
                g.step_quota = per_step;
            }
            AsyncGovernor::new(g)
        });
    if let Some(g) = governor.as_ref() {
        anyhow::ensure!(
            plane.is_some(),
            "async_governor requires the telemetry plane (enable the telemetry: block)"
        );
        // the governor owns the admission window from here on: align
        // the buffer with the starting mode and seed the mode gauge
        buffer.set_async_ratio(g.cfg.admission_alpha(g.mode()));
        proxy.metrics().gauge("governor.mode").set(g.mode().rank() as f64);
    }
    // cumulative seconds the trainer spent blocked in get_batch — the
    // plane's RolloutBound / QueueStarved discriminator
    let mut train_wait_secs = 0.0f64;
    // the last step's measured mean consumed gap, carried across
    // zero-consumption windows so the plane (and governor) never see
    // a phantom value
    let mut last_mean_gap = 0.0f64;
    if let Some(p) = plane.as_mut() {
        let mut sig = proxy.telemetry_signals();
        sig.buffer_ready = buffer_ready(buffer);
        p.tick(&sig);
    }

    for step in 0..cfg.steps {
        let t0 = Instant::now();
        // the asynchrony recipe this step runs under. Computed ONCE
        // per step (mode transitions land between steps, at the
        // governor decision below), so a step that suspends always
        // resumes in the same iteration — a transition can never
        // strand replicas suspended or double-resume them (suspend/
        // resume are additionally idempotent at the pool).
        let mode = governor.as_ref().map(|g| g.mode());
        let sync_step = match mode {
            Some(m) => m.sync_step(step),
            None => cfg.sync_mode,
        };
        // snapshot BEFORE get_batch: consumption stats (version gaps,
        // cross-version counts) are recorded inside get_batch itself,
        // so reading afterwards would always difference to zero
        let gap_before = buffer.stats();
        let tokens_before = proxy.token_stats();
        let attr_before = proxy.attribution();
        let wait_t0 = Instant::now();
        let Some(samples) = buffer.get_batch(cfg.n_groups) else {
            anyhow::bail!("sample buffer shut down mid-training");
        };
        train_wait_secs += wait_t0.elapsed().as_secs_f64();
        if sync_step {
            proxy.suspend();
        }

        let advantages = rl::grpo_advantages(&samples);
        let signs = rl::topr_signs(&samples, &advantages);

        // minibatch sweep (gradient_accumulation analogue: sequential
        // Adam updates over chunks, as ppo_epochs=1 single pass)
        let mut agg = crate::runtime::TrainStats::default();
        let chunks = per_step / b;
        for c in 0..chunks {
            let lo = c * b;
            let rows = &samples[lo..lo + b];
            let adv = &advantages[lo..lo + b];
            let sgn = &signs[lo..lo + b];
            let mut batch = rl::assemble_batch(rows, adv, sgn, b, s);
            if cfg.variant.needs_prox() {
                // proximal policy = current weights before this update
                let prox = rt.seq_logprobs(&st.params, &batch.tokens)?;
                rl::fill_prox(&mut batch, &prox);
            }
            let stats = rt.train_step(cfg.variant.as_str(), st, cfg.lr, &batch)?;
            agg.loss += stats.loss / chunks as f32;
            agg.grad_norm += stats.grad_norm / chunks as f32;
            agg.mean_ratio += stats.mean_ratio / chunks as f32;
            agg.max_ratio = agg.max_ratio.max(stats.max_ratio);
            agg.clip_frac += stats.clip_frac / chunks as f32;
            agg.entropy += stats.entropy / chunks as f32;
        }

        // three-phase weight sync: suspend -> model_update -> resume.
        // (UpdateWeights is atomic w.r.t. decode steps in each replica
        // loop; with rolling_update the pool staggers the broadcast so
        // at most one replica pauses at a time.)
        let version = buffer.bump_version();
        proxy.update_weights(rt.snapshot(st)?, version);
        if sync_step {
            proxy.resume();
        }
        if let Some(a) = autoscaler.as_mut() {
            a.tick(proxy);
        }

        let gap_after = buffer.stats();
        let tokens_after = proxy.token_stats();
        let (lat_p50, lat_p99) = proxy.latency_percentiles();
        let mean_version_gap = match window_mean_gap(&gap_before, &gap_after) {
            Some(g) => {
                last_mean_gap = g;
                g
            }
            // zero samples consumed this step: carry the previous
            // measurement instead of dividing a stale gap sum by a
            // phantom sample — the governor and the VersionGap
            // watchdog act on this value
            None => last_mean_gap,
        };
        // telemetry tick: gather cumulative pool signals, fill in the
        // trainer-side half, and let the plane decide whether a window
        // closes. A closed window is published into the pool's trace +
        // registry (verdict/alert events, live gauges) along with the
        // recorder's own health gauges.
        if let Some(p) = plane.as_mut() {
            if p.due(proxy.recorder().now()) {
                let recorder = proxy.recorder();
                p.observe_trace(&recorder);
                let mut sig = proxy.telemetry_signals();
                sig.buffer_ready = buffer_ready(buffer);
                sig.train_wait_secs = train_wait_secs;
                sig.version_gap = mean_version_gap;
                sig.lat_p50 = lat_p50;
                sig.lat_p99 = lat_p99;
                if let Some(w) = p.tick(&sig) {
                    telemetry::publish(&w, &recorder, &proxy.metrics());
                    proxy.publish_trace_gauges();
                    // feedback loop: the governor reads the closed
                    // window's measured gap + watchdog state and may
                    // move the asynchrony mode for the NEXT step
                    if let Some(g) = governor.as_mut() {
                        if let Some(m) = g.decide_at(w.t1, &w) {
                            buffer.set_async_ratio(g.cfg.admission_alpha(m));
                            let reg = proxy.metrics();
                            reg.gauge("governor.mode").set(m.rank() as f64);
                            reg.counter("governor.transitions").inc();
                            recorder.emit_at(
                                "governor_mode",
                                EventPhase::Instant,
                                0,
                                None,
                                0,
                                0,
                                w.t1,
                                format!("mode={} gap={:.2}", m.label(), w.version_gap),
                            );
                        }
                    }
                }
            }
        }
        logs.push(StepLog {
            step,
            loss: agg.loss,
            grad_norm: agg.grad_norm,
            mean_ratio: agg.mean_ratio,
            max_ratio: agg.max_ratio,
            clip_frac: agg.clip_frac,
            entropy: agg.entropy,
            reward_mean: samples.iter().map(|t| t.reward).sum::<f32>() / samples.len() as f32,
            pass_rate: rl::pass_rate(&samples) as f32,
            mean_version_gap,
            max_version_gap: gap_after.max_version_gap,
            replica_version_skew: proxy.version_skew(),
            cross_version_samples: gap_after
                .cross_version_samples
                .saturating_sub(gap_before.cross_version_samples),
            salvaged_tokens: tokens_after
                .salvaged_tokens
                .saturating_sub(tokens_before.salvaged_tokens),
            wasted_tokens: tokens_after.wasted_tokens.saturating_sub(tokens_before.wasted_tokens),
            prefix_hit_tokens: tokens_after
                .prefix_hit_tokens
                .saturating_sub(tokens_before.prefix_hit_tokens),
            serving_replicas: proxy.serving_replicas(),
            wall_secs: t0.elapsed().as_secs_f64(),
            attr: proxy.attribution().delta(&attr_before),
            lat_p50,
            lat_p99,
            telemetry: plane.as_ref().and_then(|p| p.step_status()),
            mode,
        });
    }
    // close the trailing partial window so short runs (and the tail
    // of every run) still land in the timeline
    if let Some(p) = plane.as_mut() {
        let recorder = proxy.recorder();
        p.observe_trace(&recorder);
        let mut sig = proxy.telemetry_signals();
        sig.buffer_ready = buffer_ready(buffer);
        sig.train_wait_secs = train_wait_secs;
        // the trailing window carries the real staleness signal too —
        // a defaulted 0.0 here would spuriously clear the gap watchdog
        // (and lie to anyone reading the final verdicts.jsonl line)
        sig.version_gap = last_mean_gap;
        if let Some(w) = p.flush(&sig) {
            telemetry::publish(&w, &recorder, &proxy.metrics());
        }
    }
    // end-of-run exports: verdict timeline JSONL next to the trace
    // exports, Prometheus text exposition of the pool registry
    if let Some(p) = plane.as_ref() {
        proxy.publish_trace_gauges();
        if let Some(path) = &p.cfg().verdict_path {
            if let Some(dir) = path.parent() {
                if !dir.as_os_str().is_empty() {
                    let _ = std::fs::create_dir_all(dir);
                }
            }
            if let Err(e) = std::fs::write(path, p.timeline_jsonl()) {
                eprintln!("telemetry: failed to write verdict timeline {path:?}: {e}");
            }
        }
        if let Some(path) = &p.cfg().prometheus_path {
            if let Err(e) = prometheus::write_to_file(&proxy.metrics(), path) {
                eprintln!("telemetry: failed to write prometheus exposition {path:?}: {e}");
            }
        }
    }
    Ok(logs)
}

/// Finished samples sitting in the buffer right now (produced minus
/// every consumed/cancelled/evicted outcome) — the plane's
/// TrainBound discriminator.
fn buffer_ready(buffer: &Arc<SampleBuffer>) -> f64 {
    let s = buffer.stats();
    s.produced.saturating_sub(s.consumed + s.cancelled + s.stale_evicted) as f64
}

/// Mean consumed version gap across a step window of cumulative
/// [`BufferStats`] readings. `None` when the step consumed nothing —
/// the caller carries the previous measurement (or reports 0.0)
/// instead of dividing the stale gap sum by a phantom sample, which
/// is what the governor's staleness signal must never see.
pub fn window_mean_gap(before: &BufferStats, after: &BufferStats) -> Option<f64> {
    let d = after.consumed.saturating_sub(before.consumed);
    if d == 0 {
        return None;
    }
    Some(after.sum_version_gap.saturating_sub(before.sum_version_gap) as f64 / d as f64)
}

/// Format a step log line (shared by examples and benches). `gap` is
/// mean/max consumed staleness; `skew` is the rolling-sync replica
/// weight-version spread; `xver` counts piecewise-policy samples
/// consumed this step (salvaged prefixes spanning an update); `salv`/
/// `waste` are the step's decoded-token salvage and loss; `kvhit` is
/// the step's prefix tokens served from replica KV caches instead of
/// re-prefill (the pool-level prefix index at work); `repl` is
/// the serving replica count (elastic under autoscaling); `attr` is
/// the step's replica-time split as busy/sync/idle percent of serving
/// time (`-` until the recorder has attributed anything); `lat` is the
/// step's p50/p99 episode-completion latency in seconds (0/0 when no
/// episode finished inside the step).
pub fn format_log(l: &StepLog) -> String {
    let mut line = format!(
        "step {:>4}  loss {:>8.4}  reward {:.3}  pass {:.3}  ratio {:.3}/{:.3}  clip {:.3}  ent {:.3}  gap {:.2}/{}  skew {}  xver {}  salv {}  waste {}  kvhit {}  repl {}  attr {}  lat {:.2}/{:.2}  {:.2}s",
        l.step, l.loss, l.reward_mean, l.pass_rate, l.mean_ratio, l.max_ratio, l.clip_frac,
        l.entropy, l.mean_version_gap, l.max_version_gap, l.replica_version_skew,
        l.cross_version_samples, l.salvaged_tokens, l.wasted_tokens, l.prefix_hit_tokens,
        l.serving_replicas, l.attr.format_compact(), l.lat_p50, l.lat_p99, l.wall_secs
    );
    // live telemetry column — only present when the plane is on, so
    // legacy (telemetry-absent) lines stay byte-identical
    if let Some(t) = &l.telemetry {
        line.push_str(&format!("  tele {}", t.verdict.as_str()));
        if t.alerts_active > 0 {
            line.push_str(&format!("!{}", t.alerts_active));
        }
    }
    // governor column — only present when the governor is on, same
    // byte-identical-legacy rule as the telemetry column
    if let Some(m) = &l.mode {
        line.push_str(&format!("  mode {}", m.label()));
    }
    line
}

/// Machine-readable `StepLog` line: one flat JSON object per step,
/// emitted *alongside* `format_log` (the human line is unchanged).
/// Callers collect these into a `steps.jsonl` next to the trace and
/// verdict-timeline exports.
pub fn steplog_jsonl(l: &StepLog) -> String {
    let mode = match &l.mode {
        Some(m) => format!("\"{}\"", m.as_str()),
        None => "null".to_string(),
    };
    let tele = match &l.telemetry {
        Some(t) => format!(
            "{{\"verdict\":\"{}\",\"alerts_active\":{},\"throughput\":{:.6},\"waste_rate\":{:.6}}}",
            t.verdict.as_str(),
            t.alerts_active,
            t.throughput,
            t.waste_rate
        ),
        None => "null".to_string(),
    };
    format!(
        "{{\"step\":{},\"loss\":{:.6},\"grad_norm\":{:.6},\"mean_ratio\":{:.6},\
         \"max_ratio\":{:.6},\"clip_frac\":{:.6},\"entropy\":{:.6},\"reward_mean\":{:.6},\
         \"pass_rate\":{:.6},\"mean_version_gap\":{:.6},\"max_version_gap\":{},\
         \"replica_version_skew\":{},\"cross_version_samples\":{},\"salvaged_tokens\":{},\
         \"wasted_tokens\":{},\"prefix_hit_tokens\":{},\"serving_replicas\":{},\
         \"wall_secs\":{:.6},\"attr\":{{\"decode_busy\":{:.6},\"prefill\":{:.6},\
         \"prefill_replay\":{:.6},\"weight_sync\":{:.6},\"draining\":{:.6},\
         \"idle_bubble\":{:.6}}},\"lat_p50\":{:.6},\"lat_p99\":{:.6},\"telemetry\":{},\
         \"mode\":{}}}",
        l.step,
        l.loss,
        l.grad_norm,
        l.mean_ratio,
        l.max_ratio,
        l.clip_frac,
        l.entropy,
        l.reward_mean,
        l.pass_rate,
        l.mean_version_gap,
        l.max_version_gap,
        l.replica_version_skew,
        l.cross_version_samples,
        l.salvaged_tokens,
        l.wasted_tokens,
        l.prefix_hit_tokens,
        l.serving_replicas,
        l.wall_secs,
        l.attr.decode_busy,
        l.attr.prefill,
        l.attr.prefill_replay,
        l.attr.weight_sync,
        l.attr.draining,
        l.attr.idle_bubble,
        l.lat_p50,
        l.lat_p99,
        tele,
        mode
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_consumed_step_reports_no_phantom_gap() {
        // regression: the old code divided the stale cumulative gap
        // sum by `.max(1)` — a step that consumed nothing reported
        // sum_version_gap/1 as if one sample carried it all
        let before = BufferStats { consumed: 64, sum_version_gap: 96, ..Default::default() };
        let after = before; // nothing consumed this step
        assert_eq!(window_mean_gap(&before, &after), None, "no samples -> no measurement");
        // a real window still measures
        let after =
            BufferStats { consumed: 80, sum_version_gap: 128, ..Default::default() };
        assert_eq!(window_mean_gap(&before, &after), Some(2.0), "(128-96)/(80-64)");
        // fresh run from zero
        assert_eq!(
            window_mean_gap(&BufferStats::default(), &BufferStats::default()),
            None
        );
    }

    #[test]
    fn steplog_jsonl_and_format_log_carry_mode_only_when_governed() {
        let legacy = StepLog { step: 3, ..Default::default() };
        assert!(legacy.mode.is_none());
        assert!(steplog_jsonl(&legacy).contains("\"mode\":null"));
        assert!(!format_log(&legacy).contains("mode"), "legacy line byte-identical");
        let governed = StepLog {
            mode: Some(AsyncMode::PeriodicBarrier { every_k: 4 }),
            ..legacy
        };
        assert!(steplog_jsonl(&governed).contains("\"mode\":\"barrier\""));
        assert!(format_log(&governed).ends_with("mode barrier(4)"));
    }
}
