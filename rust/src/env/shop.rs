//! ShopSimulator-like single-turn environment: echo the requested
//! "product id" (paper Appendix A uses ShopSimulator-SingleTurn). A
//! single-turn task with a longer target than MathEnv, exercising the
//! same pipeline with a different reward profile.

use super::{vocab, BaseEnv, StepResult};
use crate::util::rng::Rng;
use crate::workload::EnvLatency;

pub const PROMPT_LEN: usize = 8;

pub struct ShopEnv {
    target: u64,
    latency: EnvLatency,
    rng: Rng,
}

impl ShopEnv {
    pub fn new(latency: EnvLatency) -> Self {
        ShopEnv { target: 0, latency, rng: Rng::new(0) }
    }

    pub fn target(&self) -> u64 {
        self.target
    }
}

impl BaseEnv for ShopEnv {
    fn reset(&mut self, task_seed: u64) -> Vec<i32> {
        self.rng = Rng::new(task_seed ^ 0x5409);
        self.target = self.rng.below(100) as u64;
        let mut p = vec![vocab::BOS];
        let digits = vocab::encode_number(self.target);
        p.extend(&digits);
        p.push(vocab::EQ);
        p.resize(PROMPT_LEN, vocab::PAD);
        p
    }

    fn step(&mut self, action: &[i32]) -> StepResult {
        let reward = match vocab::decode_number(action) {
            Some(n) if n == self.target => 1.0,
            _ => 0.0,
        };
        StepResult {
            obs: vec![],
            done: true,
            reward: Some(reward),
            latency: self.latency.sample(&mut self.rng),
        }
    }

    fn max_steps(&self) -> usize {
        1
    }

    fn max_new_tokens(&self) -> usize {
        4
    }

    fn prompt_len(&self) -> usize {
        PROMPT_LEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_task_verifies() {
        let mut e = ShopEnv::new(EnvLatency::gaussian(0.0, 0.0));
        e.reset(3);
        let mut ok = vocab::encode_number(e.target());
        ok.push(vocab::EOS);
        assert_eq!(e.step(&ok).reward, Some(1.0));
        e.reset(3);
        assert_eq!(e.step(&[vocab::EOS]).reward, Some(0.0));
    }

    #[test]
    fn prompt_contains_target() {
        let mut e = ShopEnv::new(EnvLatency::gaussian(0.0, 0.0));
        let p = e.reset(11);
        assert_eq!(p.len(), PROMPT_LEN);
        // target digits appear right after BOS
        let shown = vocab::decode_number(&p[1..]).unwrap();
        assert_eq!(shown, e.target());
    }
}
