//! Environment substrates. The paper trains against DAPO-Math (RLVR
//! verifier rewards) and three agentic suites (SWE, ALFWorld,
//! ShopSimulator); none are available offline, so each is replaced by
//! a simulator that preserves the properties the experiments depend on
//! (verifiable rewards, multi-turn interaction, latency long tails,
//! fail-slow/fail-stop) — DESIGN.md §7.

pub mod alfworld;
pub mod math;
pub mod shop;
pub mod swe;

/// Shared token vocabulary for all environments (fits every model
/// config's vocab = 64).
pub mod vocab {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 1;
    pub const EOS: i32 = 2;
    pub const PLUS: i32 = 3;
    pub const EQ: i32 = 4;
    /// digits 0..=9 map to 5..=14
    pub const DIGIT0: i32 = 5;

    pub fn digit(d: u32) -> i32 {
        DIGIT0 + d as i32
    }

    pub fn as_digit(tok: i32) -> Option<u32> {
        if (DIGIT0..DIGIT0 + 10).contains(&tok) {
            Some((tok - DIGIT0) as u32)
        } else {
            None
        }
    }

    /// Encode a non-negative integer as digit tokens.
    pub fn encode_number(n: u64) -> Vec<i32> {
        n.to_string().chars().map(|c| digit(c.to_digit(10).unwrap())).collect()
    }

    /// Decode a digit-token prefix (stops at the first non-digit).
    pub fn decode_number(toks: &[i32]) -> Option<u64> {
        let digits: Vec<u32> = toks.iter().map_while(|&t| as_digit(t)).collect();
        if digits.is_empty() {
            return None;
        }
        let mut n = 0u64;
        for d in digits {
            n = n.checked_mul(10)?.checked_add(d as u64)?;
        }
        Some(n)
    }
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct StepResult {
    /// observation tokens appended to the context (empty on terminal)
    pub obs: Vec<i32>,
    pub done: bool,
    /// verifier reward, present when done
    pub reward: Option<f32>,
    /// simulated wall latency of this env step (seconds) — consumed by
    /// the EnvManager for latency accounting / optional real sleeps
    pub latency: f64,
}

/// Outcome of [`BaseEnv::poll_step`]: the step result plus the latency
/// deadline before the observation may be acted upon. Event-driven
/// engines schedule `ready_in` on a timer wheel instead of sleeping.
#[derive(Clone, Debug)]
pub struct PendingStep {
    pub result: StepResult,
    /// simulated seconds until `result` becomes observable (0 = now);
    /// scaled into real time by the engine's `latency_scale`
    pub ready_in: f64,
}

/// The environment interface the rollout layer drives (paper Section
/// 4.2: `reset` then a step loop against the shared LLMProxy).
pub trait BaseEnv: Send {
    /// Start an episode; returns the fixed-length prompt tokens.
    fn reset(&mut self, task_seed: u64) -> Vec<i32>;

    /// Apply an action (generated tokens) and observe.
    fn step(&mut self, action: &[i32]) -> StepResult;

    /// Non-blocking step surface for the event-driven RolloutEngine:
    /// apply the action immediately and report the latency *deadline*
    /// instead of expecting the caller to sleep through it. The default
    /// delegates to [`step`](Self::step) and exposes its `latency` as
    /// the deadline, so existing envs are engine-ready as-is; envs with
    /// genuinely asynchronous backends can override.
    fn poll_step(&mut self, action: &[i32]) -> PendingStep {
        let result = self.step(action);
        let ready_in = if result.latency.is_finite() { result.latency.max(0.0) } else { 0.0 };
        PendingStep { result, ready_in }
    }

    /// Maximum interaction turns per trajectory.
    fn max_steps(&self) -> usize;

    /// Tokens the policy may generate per turn.
    fn max_new_tokens(&self) -> usize;

    /// Fixed prompt length this env emits (model prompt region).
    fn prompt_len(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::vocab;
    use super::BaseEnv;

    #[test]
    fn poll_step_default_exposes_latency_deadline() {
        let mut e = crate::env::alfworld::AlfworldEnv::new(
            5,
            crate::workload::EnvLatency::gaussian(2.0, 0.0),
        );
        e.reset(3);
        let p = e.poll_step(&[vocab::digit(1)]);
        assert!(p.ready_in > 0.0, "latency must surface as a deadline");
        assert!((p.ready_in - p.result.latency).abs() < 1e-12);
        // zero-latency envs are ready immediately
        let mut m = crate::env::math::MathEnv::new();
        m.reset(1);
        let p = m.poll_step(&[vocab::EOS]);
        assert_eq!(p.ready_in, 0.0);
        assert!(p.result.done);
    }

    #[test]
    fn number_roundtrip() {
        for n in [0u64, 7, 10, 42, 199] {
            let toks = vocab::encode_number(n);
            assert_eq!(vocab::decode_number(&toks), Some(n));
        }
    }

    #[test]
    fn decode_stops_at_non_digit() {
        let mut toks = vocab::encode_number(12);
        toks.push(vocab::EOS);
        toks.extend(vocab::encode_number(9));
        assert_eq!(vocab::decode_number(&toks), Some(12));
        assert_eq!(vocab::decode_number(&[vocab::EOS]), None);
    }
}
