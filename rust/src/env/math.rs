//! Single-turn RLVR environment with an exact verifier: single-digit
//! addition. Substitutes DAPO-Math-18K (DESIGN.md §7): same reward
//! structure — binary verifiable reward, group sampling per prompt,
//! degenerate (zero-variance) groups possible — at a difficulty a
//! tiny/small policy can actually learn within a few hundred steps.

use super::{vocab, BaseEnv, StepResult};
use crate::util::rng::Rng;

/// Prompt layout (8 tokens, fixed): BOS a + b = PAD PAD PAD
pub const PROMPT_LEN: usize = 8;

pub struct MathEnv {
    a: u32,
    b: u32,
    max_new_tokens: usize,
}

impl MathEnv {
    pub fn new() -> Self {
        MathEnv { a: 0, b: 0, max_new_tokens: 4 }
    }

    /// The ground-truth answer for the current episode.
    pub fn answer(&self) -> u64 {
        (self.a + self.b) as u64
    }

    /// Build the prompt for operands (a, b) — exposed for tests.
    pub fn prompt_for(a: u32, b: u32) -> Vec<i32> {
        let mut p = vec![vocab::BOS, vocab::digit(a), vocab::PLUS, vocab::digit(b), vocab::EQ];
        p.resize(PROMPT_LEN, vocab::PAD);
        p
    }

    /// Graded verifier. Exact answers score 1.0; partial credit for
    /// well-formed output gives GRPO a learnable gradient from a cold
    /// start (a group of all-garbage responses has zero intra-group
    /// variance and therefore zero advantage — the same degenerate-
    /// group phenomenon DAPO filters, Section 5.1.1).
    pub fn verify(&self, action: &[i32]) -> f32 {
        match vocab::decode_number(action) {
            Some(n) if n == self.answer() => 1.0,
            Some(n) => {
                let want = vocab::encode_number(self.answer());
                let got = vocab::encode_number(n);
                if want[0] == got[0] {
                    0.4 // correct leading digit
                } else {
                    0.15 // well-formed number, wrong value
                }
            }
            None => 0.0,
        }
    }
}

impl Default for MathEnv {
    fn default() -> Self {
        Self::new()
    }
}

impl BaseEnv for MathEnv {
    fn reset(&mut self, task_seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(task_seed);
        self.a = rng.below(10) as u32;
        self.b = rng.below(10) as u32;
        Self::prompt_for(self.a, self.b)
    }

    fn step(&mut self, action: &[i32]) -> StepResult {
        StepResult { obs: vec![], done: true, reward: Some(self.verify(action)), latency: 0.0 }
    }

    fn max_steps(&self) -> usize {
        1
    }

    fn max_new_tokens(&self) -> usize {
        self.max_new_tokens
    }

    fn prompt_len(&self) -> usize {
        PROMPT_LEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifier_accepts_correct_answer() {
        let mut env = MathEnv::new();
        env.reset(3);
        let answer = env.answer();
        let mut action = vocab::encode_number(answer);
        action.push(vocab::EOS);
        let r = env.step(&action);
        assert!(r.done);
        assert_eq!(r.reward, Some(1.0));
    }

    #[test]
    fn verifier_grades_wrong_answers_below_pass() {
        let mut env = MathEnv::new();
        env.reset(3);
        let wrong = env.answer() + 100; // wrong leading digit for sure
        let action = vocab::encode_number(wrong);
        let r = env.step(&action).reward.unwrap();
        assert!(r < 0.5, "wrong answer must not pass: {r}");
        assert!(r > 0.0, "well-formed number earns partial credit");
    }

    #[test]
    fn prompts_are_fixed_length_and_deterministic() {
        let mut env = MathEnv::new();
        let p1 = env.reset(7);
        let p2 = env.reset(7);
        assert_eq!(p1, p2);
        assert_eq!(p1.len(), PROMPT_LEN);
        assert_eq!(p1[0], vocab::BOS);
    }

    #[test]
    fn garbage_actions_score_zero() {
        let mut env = MathEnv::new();
        env.reset(1);
        assert_eq!(env.step(&[vocab::EOS]).reward, Some(0.0));
        assert_eq!(env.step(&[]).reward, Some(0.0));
        assert_eq!(env.step(&[vocab::PLUS, vocab::EQ]).reward, Some(0.0));
    }

    #[test]
    fn reward_ordering_exact_gt_partial_gt_garbage() {
        // pick a seed with a two-digit answer so leading digit matters
        let mut env = MathEnv::new();
        for seed in 0..64 {
            env.reset(seed);
            if env.answer() >= 10 {
                let exact = env.verify(&vocab::encode_number(env.answer()));
                let lead = env.verify(&vocab::encode_number(env.answer() + 1).as_slice());
                let garbage = env.verify(&[vocab::EOS]);
                assert_eq!(exact, 1.0);
                assert!(lead < exact && lead > garbage);
                return;
            }
        }
        panic!("no two-digit answer found");
    }
}
