//! ALFWorld-like multi-turn environment: navigate a ring world to a
//! hidden goal. Preserves what the paper's ALFWorld experiments need —
//! multi-turn LLM/env interaction with per-step latency and a terminal
//! verifiable reward (success = goal reached within max_steps).

use super::{vocab, BaseEnv, StepResult};
use crate::util::rng::Rng;
use crate::workload::EnvLatency;

pub const PROMPT_LEN: usize = 8;
const RING: u32 = 10;

pub struct AlfworldEnv {
    pos: u32,
    goal: u32,
    turn: usize,
    max_steps: usize,
    latency: EnvLatency,
    rng: Rng,
}

impl AlfworldEnv {
    pub fn new(max_steps: usize, latency: EnvLatency) -> Self {
        AlfworldEnv { pos: 0, goal: 0, turn: 0, max_steps, latency, rng: Rng::new(0) }
    }

    /// Observation prompt: BOS pos goal EQ PAD... (the policy can learn
    /// "move toward goal" from the visible pos/goal digits).
    fn obs_tokens(&self) -> Vec<i32> {
        let mut p = vec![
            vocab::BOS,
            vocab::digit(self.pos),
            vocab::digit(self.goal),
            vocab::EQ,
        ];
        p.resize(PROMPT_LEN, vocab::PAD);
        p
    }

    /// Action decoding: first digit token mod 3 => {stay, +1, -1}.
    fn apply(&mut self, action: &[i32]) {
        let mv = action.iter().find_map(|&t| vocab::as_digit(t)).unwrap_or(0) % 3;
        self.pos = match mv {
            1 => (self.pos + 1) % RING,
            2 => (self.pos + RING - 1) % RING,
            _ => self.pos,
        };
    }
}

impl BaseEnv for AlfworldEnv {
    fn reset(&mut self, task_seed: u64) -> Vec<i32> {
        self.rng = Rng::new(task_seed ^ 0xA1F);
        self.pos = self.rng.below(RING as usize) as u32;
        self.goal = self.rng.below(RING as usize) as u32;
        self.turn = 0;
        self.obs_tokens()
    }

    fn step(&mut self, action: &[i32]) -> StepResult {
        self.apply(action);
        self.turn += 1;
        let lat = self.latency.sample(&mut self.rng);
        if self.pos == self.goal {
            return StepResult { obs: vec![], done: true, reward: Some(1.0), latency: lat };
        }
        if self.turn >= self.max_steps {
            return StepResult { obs: vec![], done: true, reward: Some(0.0), latency: lat };
        }
        StepResult { obs: self.obs_tokens(), done: false, reward: None, latency: lat }
    }

    fn max_steps(&self) -> usize {
        self.max_steps
    }

    fn max_new_tokens(&self) -> usize {
        2
    }

    fn prompt_len(&self) -> usize {
        PROMPT_LEN
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> AlfworldEnv {
        AlfworldEnv::new(30, EnvLatency::gaussian(0.0, 0.0))
    }

    #[test]
    fn optimal_play_reaches_goal() {
        let mut e = env();
        e.reset(5);
        for _ in 0..30 {
            // oracle: move +1 toward goal on the ring
            let dist_up = (e.goal + RING - e.pos) % RING;
            let mv = if dist_up == 0 {
                0
            } else if dist_up <= RING / 2 {
                1
            } else {
                2
            };
            let r = e.step(&[vocab::digit(mv)]);
            if r.done {
                assert_eq!(r.reward, Some(1.0));
                return;
            }
        }
        panic!("oracle failed to reach goal");
    }

    #[test]
    fn times_out_with_zero_reward() {
        let mut e = AlfworldEnv::new(3, EnvLatency::gaussian(0.0, 0.0));
        let p = e.reset(8);
        assert_eq!(p.len(), PROMPT_LEN);
        let mut last = None;
        for _ in 0..3 {
            let r = e.step(&[vocab::digit(0)]); // stay forever
            last = Some(r.clone());
            if r.done {
                break;
            }
        }
        let r = last.unwrap();
        assert!(r.done);
        // reward is 0 unless we happened to start on the goal
        assert!(r.reward == Some(0.0) || r.reward == Some(1.0));
    }

    #[test]
    fn latency_reported() {
        let mut e = AlfworldEnv::new(5, EnvLatency::gaussian(2.0, 0.5));
        e.reset(9);
        let r = e.step(&[vocab::digit(1)]);
        assert!(r.latency > 0.0);
    }
}
