//! SWE-like environment: long multi-turn episodes with heavy-tailed
//! step latency and fail-slow / fail-stop injection (Section 5.2.2's
//! motivation: "execution latency varies widely and failures are
//! common"). Task: a multi-digit "bug id" must be reproduced digit by
//! digit (a stand-in for applying a patch step by step).

use super::{vocab, BaseEnv, StepResult};
use crate::util::rng::Rng;
use crate::workload::{EnvLatency, FailureModel};

pub const PROMPT_LEN: usize = 8;

pub struct SweEnv {
    target: Vec<u32>,
    progress: usize,
    turn: usize,
    max_steps: usize,
    latency: EnvLatency,
    failures: FailureModel,
    /// turn at which this episode fail-stops (usize::MAX = healthy)
    dead_at: usize,
    rng: Rng,
}

impl SweEnv {
    pub fn new(max_steps: usize, latency: EnvLatency, failures: FailureModel) -> Self {
        SweEnv {
            target: vec![],
            progress: 0,
            turn: 0,
            max_steps,
            latency,
            failures,
            dead_at: usize::MAX,
            rng: Rng::new(0),
        }
    }

    /// Episode died (fail-stop): the EnvManager's timeout/redundancy
    /// machinery must recover — mirrors a crashed SWE container.
    pub fn is_dead(&self) -> bool {
        self.turn >= self.dead_at
    }

    fn obs_tokens(&self) -> Vec<i32> {
        // show the next digit to reproduce (teacher forcing makes the
        // task learnable; reward still requires the full sequence)
        let next = self.target.get(self.progress).copied().unwrap_or(0);
        let mut p = vec![vocab::BOS, vocab::digit(next), vocab::EQ];
        p.resize(PROMPT_LEN, vocab::PAD);
        p
    }
}

impl BaseEnv for SweEnv {
    fn reset(&mut self, task_seed: u64) -> Vec<i32> {
        self.rng = Rng::new(task_seed ^ 0x5E);
        let len = 3 + self.rng.below(3);
        self.target = (0..len).map(|_| self.rng.below(10) as u32).collect();
        self.progress = 0;
        self.turn = 0;
        self.dead_at = if self.rng.chance(self.failures.fail_stop_prob) {
            self.rng.below(self.max_steps.max(1))
        } else {
            usize::MAX
        };
        self.obs_tokens()
    }

    fn step(&mut self, action: &[i32]) -> StepResult {
        self.turn += 1;
        let mut lat = self.latency.sample(&mut self.rng);
        if self.rng.chance(self.failures.fail_slow_prob) {
            lat *= self.failures.fail_slow_factor;
        }
        if self.is_dead() {
            // env hangs: report the hang latency; the manager times out
            return StepResult { obs: vec![], done: false, reward: None, latency: f64::INFINITY }
                .with_latency(lat);
        }
        let want = self.target.get(self.progress).copied();
        let got = action.iter().find_map(|&t| vocab::as_digit(t));
        if want.is_some() && got == want {
            self.progress += 1;
        }
        if self.progress == self.target.len() {
            return StepResult { obs: vec![], done: true, reward: Some(1.0), latency: lat };
        }
        if self.turn >= self.max_steps {
            let partial = self.progress as f32 / self.target.len() as f32;
            // binary verifier with partial credit threshold (R2E-style)
            let reward = if partial >= 1.0 { 1.0 } else { 0.0 };
            return StepResult { obs: vec![], done: true, reward: Some(reward), latency: lat };
        }
        StepResult { obs: self.obs_tokens(), done: false, reward: None, latency: lat }
    }

    fn max_steps(&self) -> usize {
        self.max_steps
    }

    fn max_new_tokens(&self) -> usize {
        2
    }

    fn prompt_len(&self) -> usize {
        PROMPT_LEN
    }
}

impl StepResult {
    fn with_latency(mut self, lat: f64) -> Self {
        if self.latency.is_infinite() {
            self.latency = lat.max(1e9); // effectively hung
        }
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> SweEnv {
        SweEnv::new(50, EnvLatency::gaussian(0.0, 0.0), FailureModel::none())
    }

    #[test]
    fn oracle_solves() {
        let mut e = env();
        let obs = e.reset(2);
        let mut next = vocab::as_digit(obs[1]).unwrap();
        for _ in 0..50 {
            let r = e.step(&[vocab::digit(next), vocab::EOS]);
            if r.done {
                assert_eq!(r.reward, Some(1.0));
                return;
            }
            next = vocab::as_digit(r.obs[1]).unwrap();
        }
        panic!("oracle failed");
    }

    #[test]
    fn wrong_digits_fail() {
        let mut e = SweEnv::new(4, EnvLatency::gaussian(0.0, 0.0), FailureModel::none());
        e.reset(2);
        let mut last_reward = None;
        for _ in 0..4 {
            let r = e.step(&[vocab::EOS]); // never answers
            if r.done {
                last_reward = r.reward;
                break;
            }
        }
        assert_eq!(last_reward, Some(0.0));
    }

    #[test]
    fn fail_stop_hangs() {
        let failures = FailureModel { fail_slow_prob: 0.0, fail_slow_factor: 1.0, fail_stop_prob: 1.0 };
        let mut e = SweEnv::new(50, EnvLatency::gaussian(0.1, 0.0), failures);
        e.reset(4);
        let mut hung = false;
        for _ in 0..50 {
            let r = e.step(&[vocab::digit(0)]);
            if r.latency >= 1e9 {
                hung = true;
                break;
            }
            if r.done {
                break;
            }
        }
        assert!(hung, "fail_stop_prob=1 must hang the episode");
    }

    #[test]
    fn fail_slow_inflates_latency() {
        let failures = FailureModel { fail_slow_prob: 1.0, fail_slow_factor: 10.0, fail_stop_prob: 0.0 };
        let mut e = SweEnv::new(50, EnvLatency::gaussian(1.0, 0.0), failures);
        e.reset(5);
        let r = e.step(&[vocab::digit(0)]);
        assert!(r.latency > 5.0, "{}", r.latency);
    }
}
