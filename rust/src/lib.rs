//! roll-flash: reproduction of "ROLL Flash — Accelerating RLVR and
//! Agentic Training with Asynchrony" (see DESIGN.md).
//!
//! Three-layer architecture: this Rust crate is Layer 3 (coordinator +
//! runtime + simulator); `python/compile/` holds Layer 2 (JAX model)
//! and Layer 1 (Pallas kernels), AOT-lowered to `artifacts/` which the
//! runtime executes via PJRT. Python never runs on the request path.
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod env;
pub mod metrics;
pub mod rl;
pub mod runtime;
pub mod sim;
pub mod theory;
pub mod util;
pub mod workload;
