//! Closed-form bounds from the paper's Section 3.1, used by
//! `benches/prop_bounds.rs` to overlay theory on measured results.

/// Proposition 1 inputs: Q samples over K queue-scheduled workers.
#[derive(Clone, Copy, Debug)]
pub struct Prop1 {
    pub k_workers: usize,
    pub mu_gen: f64,
    pub l_gen: f64,
}

impl Prop1 {
    /// Eq. 4: T_completion <= (Q/K) mu + L.
    pub fn completion_bound(&self, q: usize) -> f64 {
        q as f64 / self.k_workers as f64 * self.mu_gen + self.l_gen
    }

    /// Eq. 5: per-sample bound mu/K + L/Q.
    pub fn per_sample_bound(&self, q: usize) -> f64 {
        self.mu_gen / self.k_workers as f64 + self.l_gen / q as f64
    }

    /// Eq. 6: sync per-sample bound (Q = N).
    pub fn sync_bound(&self, n: usize) -> f64 {
        self.per_sample_bound(n)
    }

    /// Eq. 7: async per-sample bound (Q = (alpha+1) N).
    pub fn async_bound(&self, n: usize, alpha: f64) -> f64 {
        self.mu_gen / self.k_workers as f64 + self.l_gen / ((alpha + 1.0) * n as f64)
    }

    /// Limit speedup of Async over Sync as alpha -> inf, K = N:
    /// (L + mu) / mu.
    pub fn max_speedup(&self) -> f64 {
        (self.l_gen + self.mu_gen) / self.mu_gen
    }
}

/// Proposition 2 inputs: end-to-end with resource partitioning.
#[derive(Clone, Copy, Debug)]
pub struct Prop2 {
    pub k_workers: usize,
    pub n_samples: usize,
    pub mu_gen: f64,
    pub l_gen: f64,
    pub mu_train: f64,
    /// sample reuse count E
    pub epochs: f64,
}

impl Prop2 {
    /// Eq. 8: T_sync <= (N/K)(mu_g + E mu_t) + L.
    pub fn sync_bound(&self) -> f64 {
        let (n, k) = (self.n_samples as f64, self.k_workers as f64);
        n / k * (self.mu_gen + self.epochs * self.mu_train) + self.l_gen
    }

    /// Eq. 9: T_async <= max(gen side, train side) at split beta.
    pub fn async_bound(&self, beta: f64, alpha: f64) -> f64 {
        assert!(beta > 0.0 && beta < 1.0);
        let (n, k) = (self.n_samples as f64, self.k_workers as f64);
        let gen = n / ((1.0 - beta) * k) * self.mu_gen
            + self.l_gen / ((alpha + 1.0) * (1.0 - beta));
        let train = self.epochs * n * self.mu_train / (beta * k);
        gen.max(train)
    }

    /// Eq. 10: optimal worker split beta*.
    pub fn beta_star(&self, alpha: f64) -> f64 {
        let (n, k) = (self.n_samples as f64, self.k_workers as f64);
        let en_mt = self.epochs * n * self.mu_train;
        en_mt / (n * self.mu_gen + k * self.l_gen / (alpha + 1.0) + en_mt)
    }

    /// Eq. 11: bound at beta*: (N/K)(mu_g + E mu_t) + L/(alpha+1).
    pub fn async_bound_at_beta_star(&self, alpha: f64) -> f64 {
        let (n, k) = (self.n_samples as f64, self.k_workers as f64);
        n / k * (self.mu_gen + self.epochs * self.mu_train) + self.l_gen / (alpha + 1.0)
    }

    /// Limit speedup as alpha -> inf: 1 + K L / (N (mu_g + E mu_t)).
    pub fn max_speedup(&self) -> f64 {
        let (n, k) = (self.n_samples as f64, self.k_workers as f64);
        1.0 + k * self.l_gen / (n * (self.mu_gen + self.epochs * self.mu_train))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop1_async_tightens_with_alpha() {
        let p = Prop1 { k_workers: 16, mu_gen: 10.0, l_gen: 100.0 };
        let sync = p.sync_bound(256);
        let a1 = p.async_bound(256, 1.0);
        let a8 = p.async_bound(256, 8.0);
        assert!(a1 < sync && a8 < a1);
        // converges to mu/K
        assert!((p.async_bound(256, 1e9) - 10.0 / 16.0).abs() < 1e-3);
    }

    #[test]
    fn prop1_max_speedup() {
        let p = Prop1 { k_workers: 256, mu_gen: 10.0, l_gen: 100.0 };
        assert!((p.max_speedup() - 11.0).abs() < 1e-12);
    }

    #[test]
    fn prop2_beta_star_balances_sides() {
        let p = Prop2 {
            k_workers: 40,
            n_samples: 4096,
            mu_gen: 30.0,
            l_gen: 400.0,
            mu_train: 10.0,
            epochs: 1.0,
        };
        let alpha = 2.0;
        let b = p.beta_star(alpha);
        assert!(b > 0.0 && b < 1.0);
        // at beta*, the two sides of the max are equal
        let (n, k) = (p.n_samples as f64, p.k_workers as f64);
        let gen = n / ((1.0 - b) * k) * p.mu_gen + p.l_gen / ((alpha + 1.0) * (1.0 - b));
        let train = p.epochs * n * p.mu_train / (b * k);
        assert!((gen - train).abs() / train < 1e-9, "gen {gen} train {train}");
        // Eq. 11 matches Eq. 9 evaluated at beta*
        assert!((p.async_bound(b, alpha) - p.async_bound_at_beta_star(alpha)).abs() < 1e-6);
    }

    #[test]
    fn prop2_async_strictly_better_when_alpha_positive() {
        let p = Prop2 {
            k_workers: 40,
            n_samples: 4096,
            mu_gen: 30.0,
            l_gen: 400.0,
            mu_train: 10.0,
            epochs: 1.0,
        };
        assert!(p.async_bound_at_beta_star(2.0) < p.sync_bound());
        // alpha = 0 bound equals the sync bound
        assert!((p.async_bound_at_beta_star(0.0) - p.sync_bound()).abs() < 1e-9);
    }

    #[test]
    fn prop2_beta_star_minimizes_bound() {
        let p = Prop2 {
            k_workers: 64,
            n_samples: 2048,
            mu_gen: 20.0,
            l_gen: 300.0,
            mu_train: 15.0,
            epochs: 2.0,
        };
        let alpha = 1.0;
        let best = p.async_bound(p.beta_star(alpha), alpha);
        for i in 1..20 {
            let beta = i as f64 / 20.0;
            assert!(p.async_bound(beta, alpha) >= best - 1e-9, "beta {beta}");
        }
    }
}
