//! Central metrics registry: named counters, gauges, and histograms
//! with snapshot-and-reset semantics, replacing the ad-hoc `Histogram`
//! fields that accreted across the fleet layers. Components register
//! handles once (registration is idempotent by name) and bump them
//! lock-free on the hot path; reporters take a [`MetricsSnapshot`] for
//! text/CSV export. Names are emitted in registration order.

use std::collections::HashMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::Histogram;

/// Monotonic event counter. `Clone` shares the underlying cell.
#[derive(Clone, Debug)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (stored as f64 bits).
#[derive(Clone, Debug)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Shared handle onto a registry-owned [`Histogram`].
#[derive(Clone, Debug)]
pub struct HistogramHandle(Arc<Mutex<Histogram>>);

impl HistogramHandle {
    pub fn record(&self, v: f64) {
        self.0.lock().unwrap().record(v);
    }

    /// Read the current distribution (clone; the live one keeps
    /// accumulating).
    pub fn read(&self) -> Histogram {
        self.0.lock().unwrap().clone()
    }
}

#[derive(Default)]
struct Inner {
    counters: Vec<(String, Counter)>,
    gauges: Vec<(String, Gauge)>,
    hists: Vec<(String, HistogramHandle)>,
    index: HashMap<String, ()>,
}

/// The registry itself. Handle lookups take the registry lock;
/// recording through a handle touches only that handle's cell, so hot
/// paths register once up front and never contend here again.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        f.debug_struct("MetricsRegistry")
            .field("counters", &inner.counters.len())
            .field("gauges", &inner.gauges.len())
            .field("histograms", &inner.hists.len())
            .finish()
    }
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch-or-create the counter called `name`.
    pub fn counter(&self, name: &str) -> Counter {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, c)) = inner.counters.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        assert!(
            !inner.index.contains_key(name),
            "metric name {name:?} already registered with a different kind"
        );
        let c = Counter(Arc::new(AtomicU64::new(0)));
        inner.counters.push((name.to_string(), c.clone()));
        inner.index.insert(name.to_string(), ());
        c
    }

    /// Fetch-or-create the gauge called `name`.
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, g)) = inner.gauges.iter().find(|(n, _)| n == name) {
            return g.clone();
        }
        assert!(
            !inner.index.contains_key(name),
            "metric name {name:?} already registered with a different kind"
        );
        let g = Gauge(Arc::new(AtomicU64::new(0f64.to_bits())));
        inner.gauges.push((name.to_string(), g.clone()));
        inner.index.insert(name.to_string(), ());
        g
    }

    /// Fetch-or-create the histogram called `name`. The bucket layout
    /// (`min`, `growth`) only applies on first registration.
    pub fn histogram(&self, name: &str, min: f64, growth: f64) -> HistogramHandle {
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, h)) = inner.hists.iter().find(|(n, _)| n == name) {
            return h.clone();
        }
        assert!(
            !inner.index.contains_key(name),
            "metric name {name:?} already registered with a different kind"
        );
        let h = HistogramHandle(Arc::new(Mutex::new(Histogram::new(min, growth))));
        inner.hists.push((name.to_string(), h.clone()));
        inner.index.insert(name.to_string(), ());
        h
    }

    /// Read every metric without disturbing it.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.collect(false)
    }

    /// Read every metric and reset it to zero/empty — the windowed
    /// read reporters use between steps. Gauges are instantaneous and
    /// keep their value.
    pub fn snapshot_and_reset(&self) -> MetricsSnapshot {
        self.collect(true)
    }

    fn collect(&self, reset: bool) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let counters = inner
            .counters
            .iter()
            .map(|(n, c)| {
                let v = if reset { c.0.swap(0, Ordering::Relaxed) } else { c.get() };
                (n.clone(), v)
            })
            .collect();
        let gauges = inner.gauges.iter().map(|(n, g)| (n.clone(), g.get())).collect();
        let hists = inner
            .hists
            .iter()
            .map(|(n, h)| {
                let mut guard = h.0.lock().unwrap();
                let snap = guard.clone();
                if reset {
                    guard.reset();
                }
                (n.clone(), snap)
            })
            .collect();
        MetricsSnapshot { counters, gauges, hists }
    }
}

/// Point-in-time reading of a [`MetricsRegistry`], in registration
/// order.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, Histogram)>,
}

impl MetricsSnapshot {
    /// Human-readable dump, one metric per line.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (n, v) in &self.counters {
            let _ = writeln!(out, "counter {n} {v}");
        }
        for (n, v) in &self.gauges {
            let _ = writeln!(out, "gauge {n} {v:.6}");
        }
        for (n, h) in &self.hists {
            let _ = writeln!(
                out,
                "histogram {n} count={} mean={:.6} p50={:.6} p99={:.6} max={:.6}",
                h.count(),
                h.mean(),
                h.percentile(50.0),
                h.percentile(99.0),
                h.max()
            );
        }
        out
    }

    /// Two-line CSV (header + row); histograms expand to
    /// `name.count/mean/p50/p99/max` columns.
    pub fn to_csv(&self) -> String {
        let mut header: Vec<String> = Vec::new();
        let mut row: Vec<String> = Vec::new();
        for (n, v) in &self.counters {
            header.push(n.clone());
            row.push(v.to_string());
        }
        for (n, v) in &self.gauges {
            header.push(n.clone());
            row.push(format!("{v:.6}"));
        }
        for (n, h) in &self.hists {
            for (suffix, v) in [
                ("count", h.count() as f64),
                ("mean", h.mean()),
                ("p50", h.percentile(50.0)),
                ("p99", h.percentile(99.0)),
                ("max", h.max()),
            ] {
                header.push(format!("{n}.{suffix}"));
                row.push(format!("{v:.6}"));
            }
        }
        format!("{}\n{}\n", header.join(","), row.join(","))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registration_is_idempotent_and_shared() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("requests");
        let b = reg.counter("requests");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3, "same name -> same cell");
        let snap = reg.snapshot();
        assert_eq!(snap.counters, vec![("requests".to_string(), 3)]);
    }

    #[test]
    #[should_panic(expected = "different kind")]
    fn name_collision_across_kinds_panics() {
        let reg = MetricsRegistry::new();
        reg.counter("x");
        reg.gauge("x");
    }

    #[test]
    fn snapshot_and_reset_windows_counters_and_hists() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("done");
        let g = reg.gauge("depth");
        let h = reg.histogram("latency", 1e-6, 1.1);
        c.add(5);
        g.set(7.5);
        h.record(0.25);
        let s1 = reg.snapshot_and_reset();
        assert_eq!(s1.counters[0].1, 5);
        assert_eq!(s1.gauges[0].1, 7.5);
        assert_eq!(s1.hists[0].1.count(), 1);
        // counters and histograms reset; gauges persist
        let s2 = reg.snapshot();
        assert_eq!(s2.counters[0].1, 0);
        assert_eq!(s2.gauges[0].1, 7.5);
        assert_eq!(s2.hists[0].1.count(), 0);
        // the live handles still work after the reset
        c.inc();
        assert_eq!(reg.snapshot().counters[0].1, 1);
    }

    #[test]
    fn exports_emit_registration_order() {
        let reg = MetricsRegistry::new();
        reg.counter("zulu");
        reg.counter("alpha");
        reg.gauge("mike");
        reg.histogram("lat", 1e-6, 1.1).record(1.0);
        let snap = reg.snapshot();
        let text = snap.to_text();
        let z = text.find("zulu").unwrap();
        let a = text.find("alpha").unwrap();
        assert!(z < a, "registration order, not alphabetical:\n{text}");
        let csv = snap.to_csv();
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        let row = lines.next().unwrap();
        assert!(header.starts_with("zulu,alpha,mike,lat.count"), "{header}");
        assert_eq!(header.split(',').count(), row.split(',').count());
    }
}
