//! Live telemetry plane: windowed bottleneck verdicts, anomaly
//! watchdogs, and episode critical-path analysis.
//!
//! PR 6's observability is post-hoc — the `FlightRecorder` and the
//! attribution tables are exported at shutdown. This module makes the
//! same surfaces *live*: a caller-clocked [`TelemetryPlane`] is ticked
//! periodically (the real `AsyncController` between training steps,
//! the virtual-time sim inside its event loop — one impl for both,
//! like `autoscaler::decide`), pulls cumulative counters from the
//! existing surfaces ([`AttrSnapshot`] attribution, `TokenLedger`
//! deltas, queue depth, buffer staleness, completion latency,
//! recorder open spans) and folds each window into
//!
//! 1. a **bottleneck verdict** ([`BottleneckVerdict`]) from a pure,
//!    unit-testable decision rule ([`verdict`]),
//! 2. **anomaly watchdogs** with fire/clear hysteresis, each
//!    transition emitting a structured [`TelemetryAlert`] into the
//!    trace and the metrics registry ([`publish`]),
//! 3. an **episode critical-path decomposition**
//!    ([`CriticalPath`] / [`fold_episode`]) of finished episodes'
//!    `TraceEvent` lifecycles into per-stage delays with windowed
//!    p50/p99.
//!
//! The plane is pure state + arithmetic: no threads, no clocks of its
//! own, no I/O. Callers export its JSONL timeline
//! ([`TelemetryPlane::timeline_jsonl`]) next to the existing trace
//! exports and render the registry via `metrics/prometheus.rs`. A
//! disabled plane (`cfg.enabled == false`) returns `None` from every
//! tick before touching any state, so legacy configs stay
//! byte-identical.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::metrics::registry::MetricsRegistry;
use crate::metrics::trace::{AttrSnapshot, EventPhase, FlightRecorder, TraceEvent};
use crate::metrics::Histogram;

/// `telemetry:` block (YAML/CLI). Absent block == `disabled()` ==
/// every tick is a single branch and legacy behavior is untouched.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryCfg {
    /// master switch
    pub enabled: bool,
    /// minimum seconds (wall or virtual) between verdict windows
    pub window_secs: f64,
    /// write Prometheus text exposition here at end of run
    pub prometheus_path: Option<PathBuf>,
    /// write the verdict-timeline JSONL here at end of run
    pub verdict_path: Option<PathBuf>,
    /// (weight_sync + draining) fraction of replica time at or above
    /// which the window is `SyncStall`
    pub sync_stall_frac: f64,
    /// `TailBound` when window p99 completion latency exceeds
    /// `tail_ratio × p50`
    pub tail_ratio: f64,
    /// trainer-blocked-in-`get_batch` fraction of the window at or
    /// above which the trainer is starved (`RolloutBound` /
    /// `QueueStarved`)
    pub rollout_wait_frac: f64,
    /// idle-bubble fraction of serving time at or above which the
    /// fleet is underfed (`QueueStarved` / `TrainBound`)
    pub idle_frac: f64,
    /// throughput-regression watchdog: fire when the window's episode
    /// rate sits this many EWMA standard deviations below the mean
    pub throughput_sigma: f64,
    /// stalled-episode watchdog: fire when the oldest open decode
    /// span is older than this
    pub stall_timeout_secs: f64,
    /// waste watchdog: fire when wasted tokens exceed this fraction
    /// of the window's token flow
    pub waste_budget: f64,
    /// staleness watchdog: fire when the window's version gap meets
    /// this budget
    pub gap_budget: f64,
}

impl TelemetryCfg {
    /// The absent-block state: one branch per tick, nothing recorded.
    pub fn disabled() -> Self {
        TelemetryCfg { enabled: false, ..Self::on() }
    }

    /// Enabled with default thresholds (the values the YAML block
    /// starts from before per-key overrides).
    pub fn on() -> Self {
        TelemetryCfg {
            enabled: true,
            window_secs: 5.0,
            prometheus_path: None,
            verdict_path: None,
            sync_stall_frac: 0.15,
            tail_ratio: 6.0,
            rollout_wait_frac: 0.4,
            idle_frac: 0.5,
            throughput_sigma: 3.0,
            stall_timeout_secs: 30.0,
            waste_budget: 0.2,
            gap_budget: 8.0,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        if !self.enabled {
            return Ok(());
        }
        let frac = |name: &str, v: f64| {
            if v > 0.0 && v <= 1.0 {
                Ok(())
            } else {
                Err(format!("telemetry.{name} must be in (0, 1], got {v}"))
            }
        };
        if !(self.window_secs > 0.0) {
            return Err(format!("telemetry.window_secs must be > 0, got {}", self.window_secs));
        }
        frac("sync_stall_frac", self.sync_stall_frac)?;
        frac("rollout_wait_frac", self.rollout_wait_frac)?;
        frac("idle_frac", self.idle_frac)?;
        frac("waste_budget", self.waste_budget)?;
        if !(self.tail_ratio > 1.0) {
            return Err(format!("telemetry.tail_ratio must be > 1, got {}", self.tail_ratio));
        }
        if !(self.throughput_sigma > 0.0) {
            return Err(format!(
                "telemetry.throughput_sigma must be > 0, got {}",
                self.throughput_sigma
            ));
        }
        if !(self.stall_timeout_secs > 0.0) {
            return Err(format!(
                "telemetry.stall_timeout_secs must be > 0, got {}",
                self.stall_timeout_secs
            ));
        }
        if !(self.gap_budget >= 1.0) {
            return Err(format!("telemetry.gap_budget must be >= 1, got {}", self.gap_budget));
        }
        Ok(())
    }
}

impl Default for TelemetryCfg {
    fn default() -> Self {
        Self::disabled()
    }
}

/// Where the window's time went — the live answer to "what is the
/// system waiting on right now".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BottleneckVerdict {
    /// the trainer starves waiting for samples while the fleet is busy
    RolloutBound,
    /// the fleet idles with finished samples queued — training is slow
    TrainBound,
    /// weight-sync pauses / drains dominate replica time
    SyncStall,
    /// nothing anywhere: replicas idle, pool queue empty, trainer
    /// waiting — the prompt feed upstream is the bottleneck
    QueueStarved,
    /// a long-tail straggler stretches p99 far past p50
    TailBound,
    #[default]
    Healthy,
}

impl BottleneckVerdict {
    pub fn as_str(&self) -> &'static str {
        match self {
            BottleneckVerdict::RolloutBound => "RolloutBound",
            BottleneckVerdict::TrainBound => "TrainBound",
            BottleneckVerdict::SyncStall => "SyncStall",
            BottleneckVerdict::QueueStarved => "QueueStarved",
            BottleneckVerdict::TailBound => "TailBound",
            BottleneckVerdict::Healthy => "Healthy",
        }
    }

    /// lowercase key for metric names (`telemetry.verdict.<key>`)
    pub fn metric_key(&self) -> &'static str {
        match self {
            BottleneckVerdict::RolloutBound => "rollout_bound",
            BottleneckVerdict::TrainBound => "train_bound",
            BottleneckVerdict::SyncStall => "sync_stall",
            BottleneckVerdict::QueueStarved => "queue_starved",
            BottleneckVerdict::TailBound => "tail_bound",
            BottleneckVerdict::Healthy => "healthy",
        }
    }
}

/// Inputs to the pure verdict rule — all *window-local* quantities
/// (attribution delta, window percentiles, window fractions).
#[derive(Clone, Debug, Default)]
pub struct VerdictInputs {
    /// replica-time attribution over the window
    pub attr: AttrSnapshot,
    /// pool queue depth at window end
    pub queue_depth: f64,
    /// finished samples sitting in the buffer at window end
    pub buffer_ready: f64,
    /// fraction of the window the trainer spent blocked in get_batch
    pub train_wait_frac: f64,
    /// window p50/p99 episode-completion latency (0 when none)
    pub lat_p50: f64,
    pub lat_p99: f64,
}

/// The decision rule, first match wins:
///
/// 1. `SyncStall` — weight-sync + draining dominate replica time
/// 2. `TailBound` — p99 ≥ `tail_ratio` × p50 among window completions
/// 3. trainer starved (`train_wait_frac` high):
///    `QueueStarved` when the fleet is *also* idle with an empty pool
///    queue (no work exists anywhere), else `RolloutBound`
/// 4. `TrainBound` — fleet idle while finished samples wait
/// 5. `Healthy`
///
/// Pure function of its inputs; every arm is unit-tested below.
pub fn verdict(i: &VerdictInputs, cfg: &TelemetryCfg) -> BottleneckVerdict {
    let total = i.attr.total();
    let sync_frac = if total > 0.0 { (i.attr.weight_sync + i.attr.draining) / total } else { 0.0 };
    let idle = i.attr.bubble_frac();
    if sync_frac >= cfg.sync_stall_frac {
        return BottleneckVerdict::SyncStall;
    }
    if i.lat_p50 > 0.0 && i.lat_p99 >= cfg.tail_ratio * i.lat_p50 {
        return BottleneckVerdict::TailBound;
    }
    if i.train_wait_frac >= cfg.rollout_wait_frac {
        if idle >= cfg.idle_frac && i.queue_depth < 1.0 {
            return BottleneckVerdict::QueueStarved;
        }
        return BottleneckVerdict::RolloutBound;
    }
    if idle >= cfg.idle_frac && i.buffer_ready >= 1.0 {
        return BottleneckVerdict::TrainBound;
    }
    BottleneckVerdict::Healthy
}

/// Which watchdog spoke.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlertKind {
    ThroughputRegression,
    StalledEpisode,
    WasteBudget,
    VersionGapBudget,
}

impl AlertKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AlertKind::ThroughputRegression => "throughput_regression",
            AlertKind::StalledEpisode => "stalled_episode",
            AlertKind::WasteBudget => "waste_budget",
            AlertKind::VersionGapBudget => "version_gap_budget",
        }
    }
}

/// A watchdog transition. `firing == true` is the alarm raising,
/// `false` is the all-clear; steady state (still firing / still
/// quiet) emits nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryAlert {
    /// window-end timestamp the transition was observed at
    pub t: f64,
    pub kind: AlertKind,
    pub firing: bool,
    /// the observed value that crossed (or re-crossed) the line
    pub value: f64,
    pub threshold: f64,
}

impl TelemetryAlert {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"kind\":\"{}\",\"firing\":{},\"value\":{:.6},\"threshold\":{:.6}}}",
            self.kind.as_str(),
            self.firing,
            self.value,
            self.threshold
        )
    }
}

/// Fire at `value >= threshold`, clear only once `value <=
/// threshold/2` — the half-threshold gap is the hysteresis band that
/// stops a value oscillating around the line from spamming
/// fire/clear pairs every window.
#[derive(Clone, Copy, Debug, Default)]
struct Hysteresis {
    firing: bool,
}

impl Hysteresis {
    fn update(
        &mut self,
        t: f64,
        kind: AlertKind,
        value: f64,
        threshold: f64,
    ) -> Option<TelemetryAlert> {
        if !self.firing && value >= threshold {
            self.firing = true;
            return Some(TelemetryAlert { t, kind, firing: true, value, threshold });
        }
        if self.firing && value <= threshold / 2.0 {
            self.firing = false;
            return Some(TelemetryAlert { t, kind, firing: false, value, threshold });
        }
        None
    }
}

/// EWMA mean/variance of window throughput; the regression watchdog
/// fires on the z-score of a *drop* (a faster-than-usual window never
/// alarms). Needs three windows of warmup before it can fire.
#[derive(Clone, Copy, Debug, Default)]
struct ThroughputWatch {
    mean: f64,
    var: f64,
    n: u64,
}

const EWMA_ALPHA: f64 = 0.3;

impl ThroughputWatch {
    /// z-score of `x` *below* the mean (positive == regression)
    fn z(&self, x: f64) -> f64 {
        if self.n < 3 {
            return 0.0;
        }
        (self.mean - x) / self.var.sqrt().max(1e-9)
    }

    fn update(&mut self, x: f64) {
        if self.n == 0 {
            self.mean = x;
        } else {
            let d = x - self.mean;
            self.mean += EWMA_ALPHA * d;
            self.var = (1.0 - EWMA_ALPHA) * (self.var + EWMA_ALPHA * d * d);
        }
        self.n += 1;
    }
}

/// One cumulative reading of every surface the plane watches. The
/// caller owns the clock (`now` is wall seconds for the real
/// controller, virtual seconds for the sim) and passes *cumulative*
/// counters — the plane differences consecutive readings itself, so
/// it never resets or double-consumes a shared window (the pool's
/// reset-on-read latency percentiles are the one exception: they are
/// already window-scoped, so they pass through as-is).
#[derive(Clone, Debug, Default)]
pub struct TelemetrySignals {
    pub now: f64,
    /// cumulative completed episodes
    pub completed: u64,
    /// pool queue depth right now
    pub queue_depth: f64,
    /// routable replicas right now
    pub serving: usize,
    /// cumulative replica-time attribution
    pub attr: AttrSnapshot,
    /// cumulative token ledger
    pub wasted_tokens: u64,
    pub salvaged_tokens: u64,
    pub prefix_hit_tokens: u64,
    /// cumulative useful decoded tokens, when the caller tracks them
    /// (the sim does); 0 keeps the waste-rate denominator honest
    pub produced_tokens: u64,
    /// window version-gap signal (mean or max consumed gap — caller's
    /// choice, compared against `gap_budget`)
    pub version_gap: f64,
    /// finished samples sitting in the buffer right now
    pub buffer_ready: f64,
    /// cumulative seconds the trainer spent blocked in get_batch
    pub train_wait_secs: f64,
    /// window p50/p99 episode-completion latency (already windowed)
    pub lat_p50: f64,
    pub lat_p99: f64,
    /// age of the oldest still-open decode span (0 when none)
    pub oldest_open_decode_secs: f64,
}

/// Per-stage window percentile row of the critical-path decomposition.
#[derive(Clone, Debug)]
pub struct StageStat {
    pub stage: &'static str,
    pub p50: f64,
    pub p99: f64,
    pub n: u64,
}

/// One closed telemetry window: `[t0, t1)`, its verdict, the window
/// rates the verdict was derived from, any watchdog transitions, and
/// the critical-path percentiles of episodes that finished inside it.
#[derive(Clone, Debug)]
pub struct TelemetryWindow {
    pub t0: f64,
    pub t1: f64,
    pub verdict: BottleneckVerdict,
    /// episodes per second over the window
    pub throughput: f64,
    /// wasted / (wasted + salvaged + prefix-hit + produced) tokens
    pub waste_rate: f64,
    pub queue_depth: f64,
    pub serving: usize,
    /// attribution delta over the window
    pub attr: AttrSnapshot,
    pub lat_p50: f64,
    pub lat_p99: f64,
    /// the window's version-gap signal exactly as the caller fed it
    /// (`TelemetrySignals::version_gap`) — the staleness measurement
    /// the async governor dials modes against, preserved here so
    /// consumers read the *measured* gap, never a re-derived one
    pub version_gap: f64,
    /// `VersionGapBudget` watchdog state *after* this window (true
    /// while the staleness alarm is raised — the governor's
    /// emergency-sync trigger)
    pub gap_firing: bool,
    pub alerts: Vec<TelemetryAlert>,
    pub stages: Vec<StageStat>,
}

impl TelemetryWindow {
    /// One JSONL timeline line.
    pub fn to_json(&self) -> String {
        let alerts: Vec<String> = self.alerts.iter().map(|a| a.to_json()).collect();
        let stages: Vec<String> = self
            .stages
            .iter()
            .map(|s| {
                format!(
                    "{{\"stage\":\"{}\",\"p50\":{:.6},\"p99\":{:.6},\"n\":{}}}",
                    s.stage, s.p50, s.p99, s.n
                )
            })
            .collect();
        format!(
            "{{\"t0\":{:.6},\"t1\":{:.6},\"verdict\":\"{}\",\"throughput\":{:.6},\
             \"waste_rate\":{:.6},\"queue_depth\":{:.3},\"serving\":{},\
             \"lat_p50\":{:.6},\"lat_p99\":{:.6},\
             \"version_gap\":{:.6},\"gap_firing\":{},\
             \"attr\":{{\"decode_busy\":{:.6},\"prefill\":{:.6},\"prefill_replay\":{:.6},\
             \"weight_sync\":{:.6},\"draining\":{:.6},\"idle_bubble\":{:.6}}},\
             \"alerts\":[{}],\"stages\":[{}]}}",
            self.t0,
            self.t1,
            self.verdict.as_str(),
            self.throughput,
            self.waste_rate,
            self.queue_depth,
            self.serving,
            self.lat_p50,
            self.lat_p99,
            self.version_gap,
            self.gap_firing,
            self.attr.decode_busy,
            self.attr.prefill,
            self.attr.prefill_replay,
            self.attr.weight_sync,
            self.attr.draining,
            self.attr.idle_bubble,
            alerts.join(","),
            stages.join(",")
        )
    }

    /// Synthetic window carrying only the staleness signal — the
    /// governor's unit tests (and offline what-if sweeps) drive
    /// `async_governor::decide` with these instead of standing up a
    /// whole plane.
    pub fn probe(t1: f64, version_gap: f64, gap_firing: bool) -> Self {
        TelemetryWindow {
            t0: t1 - 1.0,
            t1,
            verdict: BottleneckVerdict::Healthy,
            throughput: 0.0,
            waste_rate: 0.0,
            queue_depth: 0.0,
            serving: 0,
            attr: AttrSnapshot::default(),
            lat_p50: 0.0,
            lat_p99: 0.0,
            version_gap,
            gap_firing,
            alerts: Vec::new(),
            stages: Vec::new(),
        }
    }

    /// The live one-line status (`StepLog` / example output).
    pub fn status(&self) -> String {
        let firing: Vec<&str> =
            self.alerts.iter().filter(|a| a.firing).map(|a| a.kind.as_str()).collect();
        let alarm = if firing.is_empty() { String::new() } else { format!(" !{}", firing.join(",")) };
        format!(
            "[tele {:.1}s] {} thr={:.2}/s waste={:.0}% q={:.1} attr={}{}",
            self.t1,
            self.verdict.as_str(),
            self.throughput,
            self.waste_rate * 100.0,
            self.queue_depth,
            self.attr.format_compact(),
            alarm
        )
    }
}

/// Compact, `Copy` summary of the latest window for embedding in
/// `StepLog` (which stays `Copy`-friendly via `Option`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TelemetryStatus {
    pub verdict: BottleneckVerdict,
    /// watchdogs currently in the firing state
    pub alerts_active: u32,
    pub throughput: f64,
    pub waste_rate: f64,
}

/// Episode lifecycle stages the critical-path analyzer decomposes a
/// finished episode into. Span stages (`queue`, `prefill`, `decode`,
/// `env`, `score`, `buffer`) sum matched Begin/End pairs; `route` is
/// the dispatch gap — queue exit (or episode start) to first decode
/// Begin. Stages absent from a trace contribute zero.
pub const STAGES: [&str; 7] = ["queue", "route", "prefill", "decode", "env", "score", "buffer"];

/// Fold one episode's events (any order; sorted internally by
/// timestamp then seq) into per-stage seconds, indexed like
/// [`STAGES`]. Pure function — the unit tests drive it with
/// synthetic lifecycles.
pub fn fold_episode(events: &[TraceEvent]) -> [f64; 7] {
    let mut evs: Vec<&TraceEvent> = events.iter().collect();
    evs.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal).then(a.seq.cmp(&b.seq)));
    let mut out = [0.0f64; 7];
    // open Begin per span-stage name -> begin time
    let mut open: HashMap<&str, f64> = HashMap::new();
    let first_t = evs.first().map(|e| e.t).unwrap_or(0.0);
    let mut queue_exit: Option<f64> = None;
    let mut first_decode: Option<f64> = None;
    for e in &evs {
        let Some(idx) = STAGES.iter().position(|s| *s == e.name) else { continue };
        match e.phase {
            EventPhase::Begin => {
                open.entry(e.name).or_insert(e.t);
                if e.name == "decode" && first_decode.is_none() {
                    first_decode = Some(e.t);
                }
            }
            EventPhase::End => {
                if let Some(b) = open.remove(e.name) {
                    out[idx] += (e.t - b).max(0.0);
                }
                if e.name == "queue" {
                    queue_exit = Some(e.t);
                }
            }
            EventPhase::Instant => {}
        }
    }
    if let Some(d) = first_decode {
        let from = queue_exit.filter(|&q| q <= d).unwrap_or(first_t);
        out[1] = (d - from).max(0.0); // route
    }
    out
}

/// Windowed per-stage delay histograms fed by [`fold_episode`].
#[derive(Debug)]
pub struct CriticalPath {
    hists: Vec<Histogram>,
    episodes: u64,
}

impl Default for CriticalPath {
    fn default() -> Self {
        Self::new()
    }
}

impl CriticalPath {
    pub fn new() -> Self {
        CriticalPath {
            hists: (0..STAGES.len()).map(|_| Histogram::new(1e-5, 1.3)).collect(),
            episodes: 0,
        }
    }

    pub fn observe_episode(&mut self, events: &[TraceEvent]) {
        let stages = fold_episode(events);
        for (i, &v) in stages.iter().enumerate() {
            if v > 0.0 {
                self.hists[i].record(v);
            }
        }
        self.episodes += 1;
    }

    pub fn episodes(&self) -> u64 {
        self.episodes
    }

    /// Per-stage p50/p99 rows for stages that saw any samples.
    pub fn stage_stats(&self) -> Vec<StageStat> {
        STAGES
            .iter()
            .enumerate()
            .filter(|(i, _)| self.hists[*i].count() > 0)
            .map(|(i, s)| StageStat {
                stage: s,
                p50: self.hists[i].percentile(50.0),
                p99: self.hists[i].percentile(99.0),
                n: self.hists[i].count(),
            })
            .collect()
    }

    fn reset(&mut self) {
        for h in &mut self.hists {
            h.reset();
        }
        self.episodes = 0;
    }
}

/// The plane. Caller-clocked: `tick` with a fresh cumulative
/// [`TelemetrySignals`] reading whenever convenient; a window closes
/// (and a verdict is produced) once at least `window_secs` have
/// elapsed since the previous close. The first tick only seeds the
/// baseline.
#[derive(Debug)]
pub struct TelemetryPlane {
    cfg: TelemetryCfg,
    prev: Option<TelemetrySignals>,
    windows: Vec<TelemetryWindow>,
    thr: ThroughputWatch,
    dog_thr: Hysteresis,
    dog_stall: Hysteresis,
    dog_waste: Hysteresis,
    dog_gap: Hysteresis,
    /// trace watermark: events at or below this seq are folded
    seen_seq: u64,
    /// open episodes: req -> lifecycle events so far
    pending: HashMap<u64, Vec<TraceEvent>>,
    window_path: CriticalPath,
    last_status: Option<TelemetryStatus>,
}

/// Episodes kept open at most this long before eviction (ring
/// overwrite can eat an End event; don't leak the map).
const MAX_PENDING_EPISODES: usize = 16_384;

impl TelemetryPlane {
    pub fn new(cfg: TelemetryCfg) -> Self {
        TelemetryPlane {
            cfg,
            prev: None,
            windows: Vec::new(),
            thr: ThroughputWatch::default(),
            dog_thr: Hysteresis::default(),
            dog_stall: Hysteresis::default(),
            dog_waste: Hysteresis::default(),
            dog_gap: Hysteresis::default(),
            seen_seq: 0,
            pending: HashMap::new(),
            window_path: CriticalPath::new(),
            last_status: None,
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.cfg.enabled
    }

    pub fn cfg(&self) -> &TelemetryCfg {
        &self.cfg
    }

    /// True once `now` is at least a window past the last close — the
    /// cheap guard callers use to skip gathering signals.
    pub fn due(&self, now: f64) -> bool {
        if !self.cfg.enabled {
            return false;
        }
        match &self.prev {
            None => true,
            Some(p) => now - p.now >= self.cfg.window_secs,
        }
    }

    /// Fold new recorder events (since the last call) into the
    /// critical-path analyzer. An episode closes on its `decode` End
    /// (terminal in both the pool's and the sim's schema) or a `lost`
    /// instant.
    pub fn observe_trace(&mut self, recorder: &FlightRecorder) {
        if !self.cfg.enabled {
            return;
        }
        for e in recorder.events() {
            if e.seq < self.seen_seq {
                continue;
            }
            self.seen_seq = e.seq + 1;
            self.observe_event(e);
        }
    }

    /// Same, from an event slice (pure-testing / pre-collected).
    pub fn observe_events(&mut self, events: &[TraceEvent]) {
        if !self.cfg.enabled {
            return;
        }
        for e in events {
            if e.seq < self.seen_seq {
                continue;
            }
            self.seen_seq = e.seq + 1;
            self.observe_event(e.clone());
        }
    }

    fn observe_event(&mut self, e: TraceEvent) {
        let terminal = (e.name == "decode" && e.phase == EventPhase::End) || e.name == "lost";
        let req = e.req;
        self.pending.entry(req).or_default().push(e);
        if terminal {
            if let Some(evs) = self.pending.remove(&req) {
                self.window_path.observe_episode(&evs);
            }
        } else if self.pending.len() > MAX_PENDING_EPISODES {
            // evict an arbitrary stale episode to bound memory
            let victim = self.pending.keys().next().copied();
            if let Some(k) = victim {
                self.pending.remove(&k);
            }
        }
    }

    /// Advance the plane. Returns the closed window (if one closed on
    /// this tick) — `None` while disabled, on the baseline-seeding
    /// first call, or when less than `window_secs` has elapsed.
    pub fn tick(&mut self, sig: &TelemetrySignals) -> Option<TelemetryWindow> {
        if !self.cfg.enabled {
            return None;
        }
        let Some(prev) = self.prev.clone() else {
            self.prev = Some(sig.clone());
            return None;
        };
        let dt = sig.now - prev.now;
        if dt < self.cfg.window_secs {
            return None;
        }
        Some(self.close_window(&prev, sig, dt))
    }

    /// Force-close the current partial window at `sig.now` — the
    /// end-of-run flush, so the window timeline tiles the whole run
    /// (`[0, makespan]` with no truncated remainder). No-op while
    /// disabled, before the baseline seeds, or when no time has
    /// passed since the last close.
    pub fn flush(&mut self, sig: &TelemetrySignals) -> Option<TelemetryWindow> {
        if !self.cfg.enabled {
            return None;
        }
        let prev = self.prev.clone()?;
        let dt = sig.now - prev.now;
        if dt <= 0.0 {
            return None;
        }
        Some(self.close_window(&prev, sig, dt))
    }

    fn close_window(
        &mut self,
        prev: &TelemetrySignals,
        sig: &TelemetrySignals,
        dt: f64,
    ) -> TelemetryWindow {
        let d_completed = sig.completed.saturating_sub(prev.completed);
        let throughput = d_completed as f64 / dt;
        let d_wasted = sig.wasted_tokens.saturating_sub(prev.wasted_tokens);
        let d_useful = sig.salvaged_tokens.saturating_sub(prev.salvaged_tokens)
            + sig.prefix_hit_tokens.saturating_sub(prev.prefix_hit_tokens)
            + sig.produced_tokens.saturating_sub(prev.produced_tokens);
        let flow = d_wasted + d_useful;
        let waste_rate = if flow == 0 { 0.0 } else { d_wasted as f64 / flow as f64 };
        let attr_delta = sig.attr.delta(&prev.attr);
        let train_wait_frac =
            ((sig.train_wait_secs - prev.train_wait_secs) / dt).clamp(0.0, 1.0);

        let v = verdict(
            &VerdictInputs {
                attr: attr_delta,
                queue_depth: sig.queue_depth,
                buffer_ready: sig.buffer_ready,
                train_wait_frac,
                lat_p50: sig.lat_p50,
                lat_p99: sig.lat_p99,
            },
            &self.cfg,
        );

        let t1 = sig.now;
        let mut alerts = Vec::new();
        // throughput regression: z-score against EWMA history, then
        // absorb the window into the history
        let z = self.thr.z(throughput);
        if let Some(a) = self.dog_thr.update(
            t1,
            AlertKind::ThroughputRegression,
            z,
            self.cfg.throughput_sigma,
        ) {
            alerts.push(a);
        }
        self.thr.update(throughput);
        if let Some(a) = self.dog_stall.update(
            t1,
            AlertKind::StalledEpisode,
            sig.oldest_open_decode_secs,
            self.cfg.stall_timeout_secs,
        ) {
            alerts.push(a);
        }
        if let Some(a) =
            self.dog_waste.update(t1, AlertKind::WasteBudget, waste_rate, self.cfg.waste_budget)
        {
            alerts.push(a);
        }
        if let Some(a) =
            self.dog_gap.update(t1, AlertKind::VersionGapBudget, sig.version_gap, self.cfg.gap_budget)
        {
            alerts.push(a);
        }

        let w = TelemetryWindow {
            t0: prev.now,
            t1,
            verdict: v,
            throughput,
            waste_rate,
            queue_depth: sig.queue_depth,
            serving: sig.serving,
            attr: attr_delta,
            lat_p50: sig.lat_p50,
            lat_p99: sig.lat_p99,
            version_gap: sig.version_gap,
            // dog_gap.update ran above, so this is the post-window
            // alarm state the governor keys its emergency path off
            gap_firing: self.dog_gap.firing,
            alerts,
            stages: self.window_path.stage_stats(),
        };
        self.window_path.reset();
        self.prev = Some(sig.clone());
        self.last_status = Some(TelemetryStatus {
            verdict: v,
            alerts_active: self.alerts_active(),
            throughput,
            waste_rate,
        });
        self.windows.push(w.clone());
        w
    }

    /// Watchdogs currently in the firing state.
    pub fn alerts_active(&self) -> u32 {
        [self.dog_thr, self.dog_stall, self.dog_waste, self.dog_gap]
            .iter()
            .filter(|d| d.firing)
            .count() as u32
    }

    /// Latest-window summary for `StepLog`; `None` until the first
    /// window closes (or forever while disabled).
    pub fn step_status(&self) -> Option<TelemetryStatus> {
        self.last_status
    }

    pub fn windows(&self) -> &[TelemetryWindow] {
        &self.windows
    }

    /// The verdict timeline, one JSON object per line — written next
    /// to the existing trace exports.
    pub fn timeline_jsonl(&self) -> String {
        let mut out = String::new();
        for w in &self.windows {
            out.push_str(&w.to_json());
            out.push('\n');
        }
        out
    }

    /// Every alert transition across all closed windows.
    pub fn alerts(&self) -> Vec<TelemetryAlert> {
        self.windows.iter().flat_map(|w| w.alerts.iter().cloned()).collect()
    }
}

/// Push a closed window into the shared trace + registry: a
/// `telemetry_verdict` instant (pool ring) plus one `telemetry_alert`
/// instant per transition, verdict/alert counters, and live gauges.
/// Uses `emit_at(window.t1)` so virtual-time callers timestamp
/// correctly.
pub fn publish(w: &TelemetryWindow, recorder: &FlightRecorder, registry: &MetricsRegistry) {
    registry.counter("telemetry.windows").inc();
    registry.counter(&format!("telemetry.verdict.{}", w.verdict.metric_key())).inc();
    registry.gauge("telemetry.throughput").set(w.throughput);
    registry.gauge("telemetry.waste_rate").set(w.waste_rate);
    registry.gauge("telemetry.queue_depth").set(w.queue_depth);
    registry.gauge("telemetry.lat_p99").set(w.lat_p99);
    recorder.emit_at(
        "telemetry_verdict",
        EventPhase::Instant,
        0,
        None,
        0,
        0,
        w.t1,
        format!("verdict={} thr={:.3} waste={:.3}", w.verdict.as_str(), w.throughput, w.waste_rate),
    );
    for a in &w.alerts {
        if a.firing {
            registry.counter(&format!("telemetry.alert.{}", a.kind.as_str())).inc();
        }
        recorder.emit_at(
            "telemetry_alert",
            EventPhase::Instant,
            0,
            None,
            0,
            0,
            w.t1,
            format!(
                "kind={} firing={} value={:.4} threshold={:.4}",
                a.kind.as_str(),
                a.firing,
                a.value,
                a.threshold
            ),
        );
    }
}

/// Satellite: surface the recorder's own health in the registry —
/// overflow drops (silent trace loss) and per-ring occupancy.
pub fn publish_recorder_gauges(recorder: &FlightRecorder, registry: &MetricsRegistry) {
    registry.gauge("trace.dropped").set(recorder.dropped() as f64);
    for (i, occ) in recorder.ring_occupancy().iter().enumerate() {
        registry.gauge(&format!("trace.ring_occupancy.{i}")).set(*occ as f64);
    }
}

/// Adaptive redundancy hint (log-only): with observed per-episode
/// failure probability `p` (fail-slow timeouts + fail-stop lane
/// deaths over episodes attempted), the expected attempts per success
/// is `1/(1-p)` — the redundancy factor that would hide the observed
/// failure rate. Never below the configured base, capped at 3x so a
/// pathological window cannot suggest unbounded duplication.
pub fn redundancy_hint(base: f64, failure_rate: f64) -> f64 {
    let p = failure_rate.clamp(0.0, 0.9);
    (base.max(1.0)).max(1.0 / (1.0 - p)).min(3.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TelemetryCfg {
        TelemetryCfg { window_secs: 1.0, ..TelemetryCfg::on() }
    }

    fn attr(decode: f64, sync: f64, drain: f64, idle: f64) -> AttrSnapshot {
        AttrSnapshot {
            decode_busy: decode,
            prefill: 0.0,
            prefill_replay: 0.0,
            weight_sync: sync,
            draining: drain,
            idle_bubble: idle,
        }
    }

    // ---- verdict rules, one per arm ----

    #[test]
    fn verdict_sync_stall_when_sync_dominates() {
        let i = VerdictInputs { attr: attr(7.0, 2.0, 1.0, 0.0), ..Default::default() };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::SyncStall);
    }

    #[test]
    fn verdict_tail_bound_on_p99_blowup() {
        let i = VerdictInputs {
            attr: attr(10.0, 0.0, 0.0, 0.0),
            lat_p50: 1.0,
            lat_p99: 10.0,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::TailBound);
    }

    #[test]
    fn verdict_sync_stall_beats_tail_bound() {
        let i = VerdictInputs {
            attr: attr(5.0, 5.0, 0.0, 0.0),
            lat_p50: 1.0,
            lat_p99: 10.0,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::SyncStall);
    }

    #[test]
    fn verdict_rollout_bound_when_trainer_starves_and_fleet_busy() {
        let i = VerdictInputs {
            attr: attr(10.0, 0.0, 0.0, 0.5),
            train_wait_frac: 0.8,
            queue_depth: 12.0,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::RolloutBound);
    }

    #[test]
    fn verdict_queue_starved_when_nothing_anywhere() {
        let i = VerdictInputs {
            attr: attr(1.0, 0.0, 0.0, 9.0),
            train_wait_frac: 0.9,
            queue_depth: 0.0,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::QueueStarved);
    }

    #[test]
    fn verdict_train_bound_when_fleet_idles_over_full_buffer() {
        let i = VerdictInputs {
            attr: attr(2.0, 0.0, 0.0, 8.0),
            buffer_ready: 64.0,
            train_wait_frac: 0.0,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::TrainBound);
    }

    #[test]
    fn verdict_healthy_otherwise() {
        let i = VerdictInputs {
            attr: attr(9.0, 0.5, 0.0, 0.5),
            lat_p50: 1.0,
            lat_p99: 3.0,
            queue_depth: 2.0,
            train_wait_frac: 0.1,
            ..Default::default()
        };
        assert_eq!(verdict(&i, &cfg()), BottleneckVerdict::Healthy);
    }

    #[test]
    fn verdict_empty_window_is_healthy() {
        assert_eq!(verdict(&VerdictInputs::default(), &cfg()), BottleneckVerdict::Healthy);
    }

    // ---- watchdog hysteresis ----

    #[test]
    fn hysteresis_fires_once_and_clears_at_half() {
        let mut h = Hysteresis::default();
        // below threshold: quiet
        assert!(h.update(1.0, AlertKind::WasteBudget, 0.1, 0.2).is_none());
        // crosses: fires exactly once
        let a = h.update(2.0, AlertKind::WasteBudget, 0.5, 0.2).unwrap();
        assert!(a.firing);
        assert!(h.update(3.0, AlertKind::WasteBudget, 0.5, 0.2).is_none());
        // inside the hysteresis band: still firing, still quiet
        assert!(h.update(4.0, AlertKind::WasteBudget, 0.15, 0.2).is_none());
        // at/below half: clears exactly once
        let c = h.update(5.0, AlertKind::WasteBudget, 0.05, 0.2).unwrap();
        assert!(!c.firing);
        assert!(h.update(6.0, AlertKind::WasteBudget, 0.05, 0.2).is_none());
    }

    fn base_sig(now: f64) -> TelemetrySignals {
        TelemetrySignals { now, ..Default::default() }
    }

    #[test]
    fn waste_watchdog_fire_and_clear_through_plane() {
        let mut p = TelemetryPlane::new(cfg());
        assert!(p.tick(&base_sig(0.0)).is_none()); // baseline
        // window 1: 80% waste -> fires
        let mut s = base_sig(1.0);
        s.wasted_tokens = 800;
        s.produced_tokens = 200;
        let w = p.tick(&s).unwrap();
        assert!(w.alerts.iter().any(|a| a.kind == AlertKind::WasteBudget && a.firing));
        assert_eq!(p.alerts_active(), 1);
        // window 2: clean flow -> clears
        let mut s2 = s.clone();
        s2.now = 2.0;
        s2.produced_tokens += 1000;
        let w2 = p.tick(&s2).unwrap();
        assert!(w2.alerts.iter().any(|a| a.kind == AlertKind::WasteBudget && !a.firing));
        assert_eq!(p.alerts_active(), 0);
    }

    #[test]
    fn stall_watchdog_tracks_open_decode_age() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut s = base_sig(1.0);
        s.oldest_open_decode_secs = 100.0;
        let w = p.tick(&s).unwrap();
        assert!(w.alerts.iter().any(|a| a.kind == AlertKind::StalledEpisode && a.firing));
        let mut s2 = base_sig(2.0);
        s2.oldest_open_decode_secs = 0.0;
        let w2 = p.tick(&s2).unwrap();
        assert!(w2.alerts.iter().any(|a| a.kind == AlertKind::StalledEpisode && !a.firing));
    }

    #[test]
    fn version_gap_watchdog_fire_and_clear() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut s = base_sig(1.0);
        s.version_gap = 20.0;
        let w = p.tick(&s).unwrap();
        assert!(w.alerts.iter().any(|a| a.kind == AlertKind::VersionGapBudget && a.firing));
        let mut s2 = base_sig(2.0);
        s2.version_gap = 1.0;
        let w2 = p.tick(&s2).unwrap();
        assert!(w2.alerts.iter().any(|a| a.kind == AlertKind::VersionGapBudget && !a.firing));
    }

    #[test]
    fn throughput_regression_needs_warmup_then_fires_on_drop() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut completed = 0u64;
        // five steady windows at 100 eps/s: no alarm (incl. warmup)
        for k in 1..=5 {
            completed += 100;
            let mut s = base_sig(k as f64);
            s.completed = completed;
            let w = p.tick(&s).unwrap();
            assert!(
                !w.alerts.iter().any(|a| a.kind == AlertKind::ThroughputRegression),
                "steady state must not alarm"
            );
        }
        // collapse to ~zero: fires
        let mut s = base_sig(6.0);
        s.completed = completed;
        let w = p.tick(&s).unwrap();
        assert!(w.alerts.iter().any(|a| a.kind == AlertKind::ThroughputRegression && a.firing));
    }

    // ---- plane windowing ----

    #[test]
    fn disabled_plane_never_produces() {
        let mut p = TelemetryPlane::new(TelemetryCfg::disabled());
        assert!(!p.due(1e9));
        for k in 0..10 {
            assert!(p.tick(&base_sig(k as f64 * 10.0)).is_none());
        }
        assert!(p.windows().is_empty());
        assert!(p.step_status().is_none());
    }

    #[test]
    fn windows_tile_time_contiguously() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        // sub-window ticks close nothing
        assert!(p.tick(&base_sig(0.4)).is_none());
        for k in 1..=5 {
            p.tick(&base_sig(k as f64 * 1.5));
        }
        let ws = p.windows();
        assert_eq!(ws.len(), 5);
        assert_eq!(ws[0].t0, 0.0);
        for i in 1..ws.len() {
            assert_eq!(ws[i].t0, ws[i - 1].t1, "windows must tile without gap or overlap");
        }
    }

    #[test]
    fn flush_closes_partial_window_so_timeline_tiles_the_run() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        p.tick(&base_sig(1.0)); // one full window
        let sig = base_sig(1.4); // 0.4s remainder: under the window gate
        assert!(p.tick(&sig).is_none(), "tick must refuse a short window");
        let w = p.flush(&sig).expect("flush closes the partial remainder");
        assert_eq!(w.t0, 1.0);
        assert_eq!(w.t1, 1.4);
        assert!(p.flush(&sig).is_none(), "zero-width flush is a no-op");
        let ws = p.windows();
        assert_eq!(ws.len(), 2);
        assert_eq!(ws[0].t1, ws[1].t0);
    }

    #[test]
    fn flush_window_carries_gap_signal_and_covers_run_end() {
        // the end-of-run flush must (a) stamp t1 at the exact run end
        // so the timeline tiles the whole run, and (b) carry the
        // caller's staleness signal + watchdog state into the final
        // window — the trailing partial window is what verdicts.jsonl
        // and the governor see last
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut hot = base_sig(1.0);
        hot.version_gap = 20.0; // over gap_budget 8 -> watchdog fires
        let w = p.tick(&hot).unwrap();
        assert!(w.gap_firing && w.version_gap == 20.0);
        let mut tail = base_sig(1.7); // run ends mid-window
        tail.version_gap = 6.0; // inside hysteresis band: stays firing
        let w = p.flush(&tail).expect("flush closes the remainder");
        assert_eq!(w.t1, 1.7, "last window's t1 must cover the run end");
        assert_eq!(w.version_gap, 6.0);
        assert!(w.gap_firing, "watchdog state must survive into the flush window");
        assert_eq!(p.windows().last().unwrap().t1, 1.7);
        let last_line = p.timeline_jsonl().lines().last().unwrap().to_string();
        assert!(last_line.contains("\"version_gap\":6.000000"));
        assert!(last_line.contains("\"gap_firing\":true"));
    }

    #[test]
    fn flush_before_baseline_or_disabled_is_a_no_op() {
        let mut p = TelemetryPlane::new(cfg());
        assert!(p.flush(&base_sig(5.0)).is_none(), "no baseline yet");
        let mut off = TelemetryPlane::new(TelemetryCfg::disabled());
        off.tick(&base_sig(0.0));
        assert!(off.flush(&base_sig(9.0)).is_none());
    }

    #[test]
    fn attr_deltas_telescope_to_cumulative_total() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut cum = 0.0;
        for k in 1..=4 {
            cum += 2.5;
            let mut s = base_sig(k as f64 * 2.0);
            s.attr = attr(cum, 0.0, 0.0, 0.0);
            p.tick(&s);
        }
        let sum: f64 = p.windows().iter().map(|w| w.attr.total()).sum();
        assert!((sum - cum).abs() < 1e-9, "window attr must tile the cumulative attr: {sum} vs {cum}");
    }

    #[test]
    fn timeline_jsonl_one_line_per_window() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        p.tick(&base_sig(1.0));
        p.tick(&base_sig(2.0));
        let out = p.timeline_jsonl();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        for l in lines {
            assert!(l.starts_with('{') && l.ends_with('}'));
            assert!(l.contains("\"verdict\":\"Healthy\""));
        }
    }

    // ---- critical path ----

    fn ev(seq: u64, t: f64, name: &'static str, phase: EventPhase) -> TraceEvent {
        TraceEvent {
            seq,
            t,
            name,
            phase,
            req: 7,
            replica: None,
            generation: 0,
            version: 0,
            detail: String::new(),
        }
    }

    #[test]
    fn fold_episode_decomposes_queue_route_decode() {
        let evs = vec![
            ev(0, 1.0, "queue", EventPhase::Begin),
            ev(1, 3.0, "queue", EventPhase::End),
            ev(2, 4.0, "decode", EventPhase::Begin),
            ev(3, 9.0, "decode", EventPhase::End),
        ];
        let s = fold_episode(&evs);
        assert!((s[0] - 2.0).abs() < 1e-12, "queue");
        assert!((s[1] - 1.0).abs() < 1e-12, "route");
        assert!((s[3] - 5.0).abs() < 1e-12, "decode");
        assert_eq!(s[2], 0.0);
        assert_eq!(s[4], 0.0);
    }

    #[test]
    fn fold_episode_handles_env_score_buffer_spans() {
        let evs = vec![
            ev(0, 0.0, "decode", EventPhase::Begin),
            ev(1, 2.0, "env", EventPhase::Begin),
            ev(2, 3.5, "env", EventPhase::End),
            ev(3, 5.0, "score", EventPhase::Begin),
            ev(4, 5.25, "score", EventPhase::End),
            ev(5, 5.5, "buffer", EventPhase::Begin),
            ev(6, 5.75, "buffer", EventPhase::End),
            ev(7, 6.0, "decode", EventPhase::End),
        ];
        let s = fold_episode(&evs);
        assert!((s[3] - 6.0).abs() < 1e-12, "decode span");
        assert!((s[4] - 1.5).abs() < 1e-12, "env");
        assert!((s[5] - 0.25).abs() < 1e-12, "score");
        assert!((s[6] - 0.25).abs() < 1e-12, "buffer");
        assert_eq!(s[1], 0.0, "no queue: route measured from first event = 0");
    }

    #[test]
    fn critical_path_window_percentiles() {
        let mut cp = CriticalPath::new();
        for k in 1..=100u64 {
            let evs = vec![
                ev(2 * k, 0.0, "decode", EventPhase::Begin),
                ev(2 * k + 1, k as f64 * 0.01, "decode", EventPhase::End),
            ];
            cp.observe_episode(&evs);
        }
        let stats = cp.stage_stats();
        let decode = stats.iter().find(|s| s.stage == "decode").unwrap();
        assert_eq!(decode.n, 100);
        assert!(decode.p50 >= 0.4 && decode.p50 <= 0.7, "p50 {}", decode.p50);
        assert!(decode.p99 >= 0.85, "p99 {}", decode.p99);
    }

    #[test]
    fn plane_folds_terminal_episodes_into_window_stages() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut evs = Vec::new();
        for r in 0..3u64 {
            evs.push(TraceEvent { req: r, ..ev(4 * r, 0.1, "queue", EventPhase::Begin) });
            evs.push(TraceEvent { req: r, ..ev(4 * r + 1, 0.2, "queue", EventPhase::End) });
            evs.push(TraceEvent { req: r, ..ev(4 * r + 2, 0.3, "decode", EventPhase::Begin) });
            evs.push(TraceEvent { req: r, ..ev(4 * r + 3, 0.9, "decode", EventPhase::End) });
        }
        p.observe_events(&evs);
        let w = p.tick(&base_sig(1.0)).unwrap();
        let decode = w.stages.iter().find(|s| s.stage == "decode").unwrap();
        assert_eq!(decode.n, 3);
        // watermark: re-observing the same slice is a no-op
        p.observe_events(&evs);
        let w2 = p.tick(&base_sig(2.0)).unwrap();
        assert!(w2.stages.is_empty(), "stages reset per window and events fold once");
    }

    // ---- publish / registry ----

    #[test]
    fn publish_bumps_registry_and_trace() {
        let mut p = TelemetryPlane::new(cfg());
        p.tick(&base_sig(0.0));
        let mut s = base_sig(1.0);
        s.wasted_tokens = 100; // 100% waste -> alarm
        let w = p.tick(&s).unwrap();
        let reg = MetricsRegistry::new();
        let rec = FlightRecorder::new(64);
        publish(&w, &rec, &reg);
        let snap = reg.snapshot();
        assert!(snap.counters.iter().any(|(n, v)| n == "telemetry.windows" && *v == 1));
        assert!(snap
            .counters
            .iter()
            .any(|(n, v)| n == "telemetry.alert.waste_budget" && *v == 1));
        let evs = rec.events();
        assert!(evs.iter().any(|e| e.name == "telemetry_verdict"));
        assert!(evs.iter().any(|e| e.name == "telemetry_alert"));
    }

    #[test]
    fn recorder_gauges_surface_dropped_and_occupancy() {
        let rec = FlightRecorder::new(2);
        for k in 0..5 {
            rec.emit("x", EventPhase::Instant, k, None, 0, 0, String::new());
        }
        let reg = MetricsRegistry::new();
        publish_recorder_gauges(&rec, &reg);
        let snap = reg.snapshot();
        assert!(snap.gauges.iter().any(|(n, v)| n == "trace.dropped" && *v == 3.0));
        assert!(snap.gauges.iter().any(|(n, v)| n == "trace.ring_occupancy.0" && *v == 2.0));
    }

    // ---- redundancy hint ----

    #[test]
    fn redundancy_hint_behaves() {
        assert_eq!(redundancy_hint(1.0, 0.0), 1.0);
        assert_eq!(redundancy_hint(1.5, 0.0), 1.5);
        assert!((redundancy_hint(1.0, 0.5) - 2.0).abs() < 1e-12);
        assert!(redundancy_hint(1.0, 0.3) > redundancy_hint(1.0, 0.1));
        assert_eq!(redundancy_hint(1.0, 0.99), 3.0, "capped");
        assert_eq!(redundancy_hint(2.5, 0.1), 2.5, "never below base");
    }

    #[test]
    fn cfg_validation() {
        assert!(TelemetryCfg::disabled().validate().is_ok());
        assert!(TelemetryCfg::on().validate().is_ok());
        let mut c = TelemetryCfg::on();
        c.window_secs = 0.0;
        assert!(c.validate().is_err());
        let mut c = TelemetryCfg::on();
        c.waste_budget = 1.5;
        assert!(c.validate().is_err());
        let mut c = TelemetryCfg::on();
        c.tail_ratio = 0.5;
        assert!(c.validate().is_err());
        let mut c = TelemetryCfg::on();
        c.gap_budget = 0.0;
        assert!(c.validate().is_err());
    }
}
