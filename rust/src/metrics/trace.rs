//! Flight recorder: lock-light per-request lifecycle tracing and
//! replica time-attribution, shared by the real fleet
//! (`coordinator/fleet.rs`, wall clock) and the virtual-time sim
//! (`sim/fleet.rs`, virtual clock).
//!
//! Two instruments live here:
//!
//!   * [`FlightRecorder`] — bounded per-replica ring buffers of
//!     structured [`TraceEvent`]s covering the request lifecycle
//!     (submit → queue-wait → route → prefill → decode →
//!     {park / salvage / re-dispatch / abort} → done), exportable as
//!     JSONL or Chrome `trace_event` JSON (open the file in
//!     `chrome://tracing` or <https://ui.perfetto.dev>). Timestamps are
//!     plain `f64` seconds so the real pool records wall time since the
//!     recorder's epoch and the sim records virtual time through the
//!     same API. The off switch is a single relaxed atomic load —
//!     `record` returns before touching any lock or allocation, so a
//!     disabled recorder costs one predictable branch
//!     (`benches/perf_hotpath.rs` measures both states).
//!
//!   * [`Attribution`] — six atomic accumulators classifying every
//!     wall-second of a replica loop's life into
//!     {decode-busy, prefill, prefill-replay, weight-sync pause,
//!     draining, idle-bubble}. Proxy loops drive it through
//!     [`AttrStopwatch`]; the sim computes the same categories from its
//!     virtual-time integrals. Per-step deltas surface in `StepLog`
//!     and per-replica totals in `PoolReport` — the paper's resource
//!     bubbles, attributed instead of aggregated.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::util::json::Json;

/// Tracing knobs, wired through `RolloutSystemCfg` / YAML
/// (`trace: {enabled, ring_capacity, export_path}`) / CLI.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceCfg {
    /// master switch; off = the recorder is a single branch per call
    pub enabled: bool,
    /// events retained per ring (one ring per replica slot + one
    /// pool-level ring); wraparound keeps the newest
    pub ring_capacity: usize,
    /// directory to write `trace.json` (Chrome), `trace.jsonl`, and
    /// metrics snapshots into at shutdown; `None` = in-memory only
    pub export_path: Option<PathBuf>,
}

impl TraceCfg {
    pub fn disabled() -> Self {
        TraceCfg { enabled: false, ring_capacity: 4096, export_path: None }
    }
}

impl Default for TraceCfg {
    fn default() -> Self {
        Self::disabled()
    }
}

/// Chrome `trace_event` phase of an event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventPhase {
    /// span open (`ph: "B"`)
    Begin,
    /// span close (`ph: "E"`)
    End,
    /// point event (`ph: "i"`)
    Instant,
}

impl EventPhase {
    fn chrome(self) -> &'static str {
        match self {
            EventPhase::Begin => "B",
            EventPhase::End => "E",
            EventPhase::Instant => "i",
        }
    }
}

/// One structured lifecycle event. `replica: None` marks pool-level
/// events (submit, queue); `Some(slot)` events carry the slot's
/// `generation` so a reused slot's occupants stay distinguishable.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// recorder-wide emission order (total order across rings)
    pub seq: u64,
    /// seconds — wall time since the recorder epoch, or virtual time
    pub t: f64,
    pub name: &'static str,
    pub phase: EventPhase,
    /// pool-level request id
    pub req: u64,
    pub replica: Option<usize>,
    /// replica slot generation (0 for pool-level events)
    pub generation: u64,
    /// weight version in force when the event fired
    pub version: u64,
    /// freeform payload (routing policy, token counts, …)
    pub detail: String,
}

/// Bounded event ring: wraparound overwrites the oldest entry.
struct Ring {
    buf: Vec<TraceEvent>,
    /// index of the oldest entry once the ring is full
    head: usize,
    cap: usize,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { buf: Vec::with_capacity(cap.min(1024)), head: 0, cap }
    }

    /// Returns true when an old event was overwritten.
    fn push(&mut self, ev: TraceEvent) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
            false
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            true
        }
    }

    /// Oldest-first snapshot.
    fn ordered(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

/// The recorder. Ring selection is per replica slot (index `slot + 1`;
/// ring 0 holds pool-level events), each behind its own mutex so
/// collectors on different replicas never contend; the outer `RwLock`
/// is only write-locked when a new slot appears.
pub struct FlightRecorder {
    enabled: AtomicBool,
    cap: usize,
    epoch: Instant,
    rings: RwLock<Vec<Arc<Mutex<Ring>>>>,
    seq: AtomicU64,
    dropped: AtomicU64,
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FlightRecorder")
            .field("enabled", &self.is_enabled())
            .field("ring_capacity", &self.cap)
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl FlightRecorder {
    pub fn new(ring_capacity: usize) -> Self {
        assert!(ring_capacity > 0, "ring_capacity must be positive");
        FlightRecorder {
            enabled: AtomicBool::new(true),
            cap: ring_capacity,
            epoch: Instant::now(),
            rings: RwLock::new(Vec::new()),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// A permanently-off recorder: every `record` is one branch.
    pub fn disabled() -> Self {
        let r = Self::new(1);
        r.enabled.store(false, Ordering::Relaxed);
        r
    }

    pub fn from_cfg(cfg: &TraceCfg) -> Arc<Self> {
        Arc::new(if cfg.enabled { Self::new(cfg.ring_capacity) } else { Self::disabled() })
    }

    /// The hot-path gate: call sites that would allocate a `detail`
    /// string should check this first.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Wall seconds since the recorder epoch (the real pool's clock;
    /// the sim passes its virtual `now` instead).
    pub fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Record at the current wall clock.
    #[inline]
    pub fn emit(
        &self,
        name: &'static str,
        phase: EventPhase,
        req: u64,
        replica: Option<usize>,
        generation: u64,
        version: u64,
        detail: String,
    ) {
        if !self.is_enabled() {
            return;
        }
        let t = self.now();
        self.push(name, phase, req, replica, generation, version, t, detail);
    }

    /// Record at an explicit timestamp (virtual-time callers).
    #[inline]
    pub fn emit_at(
        &self,
        name: &'static str,
        phase: EventPhase,
        req: u64,
        replica: Option<usize>,
        generation: u64,
        version: u64,
        t: f64,
        detail: String,
    ) {
        if !self.is_enabled() {
            return;
        }
        self.push(name, phase, req, replica, generation, version, t, detail);
    }

    #[allow(clippy::too_many_arguments)]
    fn push(
        &self,
        name: &'static str,
        phase: EventPhase,
        req: u64,
        replica: Option<usize>,
        generation: u64,
        version: u64,
        t: f64,
        detail: String,
    ) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = TraceEvent { seq, t, name, phase, req, replica, generation, version, detail };
        let idx = replica.map(|r| r + 1).unwrap_or(0);
        let ring = self.ring(idx);
        let overwrote = ring.lock().unwrap().push(ev);
        if overwrote {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn ring(&self, idx: usize) -> Arc<Mutex<Ring>> {
        {
            let rings = self.rings.read().unwrap();
            if let Some(r) = rings.get(idx) {
                return r.clone();
            }
        }
        let mut rings = self.rings.write().unwrap();
        while rings.len() <= idx {
            rings.push(Arc::new(Mutex::new(Ring::new(self.cap))));
        }
        rings[idx].clone()
    }

    /// Events currently retained per ring (index 0 = pool-level ring,
    /// `slot + 1` per replica slot). With `dropped()`, the telemetry
    /// plane publishes these as `trace.ring_occupancy.<i>` gauges so
    /// silent wraparound loss is visible while the run is live.
    pub fn ring_occupancy(&self) -> Vec<usize> {
        self.rings
            .read()
            .unwrap()
            .iter()
            .map(|r| r.lock().unwrap().buf.len())
            .collect()
    }

    /// Spans named `name` with a Begin but no matching End yet, as
    /// `(req, begin_t)` ordered oldest-first. Drives the telemetry
    /// plane's stalled-episode watchdog (an open `decode` span whose
    /// age exceeds the stall timeout is a hung generation). A Begin
    /// evicted by ring wraparound makes its span invisible here —
    /// acceptable for a watchdog that only needs the *oldest* strays.
    pub fn open_spans(&self, name: &str) -> Vec<(u64, f64)> {
        let mut open: std::collections::HashMap<u64, f64> = std::collections::HashMap::new();
        for e in self.events() {
            if e.name != name {
                continue;
            }
            match e.phase {
                EventPhase::Begin => {
                    open.entry(e.req).or_insert(e.t);
                }
                EventPhase::End => {
                    open.remove(&e.req);
                }
                EventPhase::Instant => {}
            }
        }
        let mut out: Vec<(u64, f64)> = open.into_iter().collect();
        out.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
        out
    }

    /// Age of the oldest still-open `name` span at `now` (0 when none
    /// are open) — the stalled-episode watchdog's input signal.
    pub fn oldest_open_span_age(&self, name: &str, now: f64) -> f64 {
        self.open_spans(name).first().map(|&(_, t)| (now - t).max(0.0)).unwrap_or(0.0)
    }

    /// Snapshot of every ring, in global emission order.
    pub fn events(&self) -> Vec<TraceEvent> {
        let rings: Vec<Arc<Mutex<Ring>>> = self.rings.read().unwrap().clone();
        let mut out: Vec<TraceEvent> = Vec::new();
        for r in rings {
            out.extend(r.lock().unwrap().ordered());
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// One JSON object per line.
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for e in self.events() {
            out.push_str(&format!(
                "{{\"seq\":{},\"t\":{:.6},\"name\":{},\"ph\":\"{}\",\"req\":{},\"replica\":{},\
                 \"generation\":{},\"version\":{},\"detail\":{}}}\n",
                e.seq,
                e.t,
                Json::Str(e.name.to_string()),
                e.phase.chrome(),
                e.req,
                e.replica.map(|r| r as i64).unwrap_or(-1),
                e.generation,
                e.version,
                Json::Str(e.detail.clone()),
            ));
        }
        out
    }

    /// Chrome `trace_event` JSON (`{"traceEvents": [...]}`). `pid` is
    /// the replica slot + 1 (0 = pool level), `tid` the request id,
    /// `ts` microseconds.
    pub fn export_chrome_trace(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        for (i, e) in self.events().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let scope = if e.phase == EventPhase::Instant { ",\"s\":\"t\"" } else { "" };
            out.push_str(&format!(
                "{{\"name\":{},\"cat\":\"fleet\",\"ph\":\"{}\"{scope},\"ts\":{:.3},\"pid\":{},\
                 \"tid\":{},\"args\":{{\"generation\":{},\"version\":{},\"detail\":{}}}}}",
                Json::Str(e.name.to_string()),
                e.phase.chrome(),
                e.t * 1e6,
                e.replica.map(|r| r + 1).unwrap_or(0),
                e.req,
                e.generation,
                e.version,
                Json::Str(e.detail.clone()),
            ));
        }
        out.push_str("]}");
        out
    }

    /// Write `trace.json` (Chrome) and `trace.jsonl` into `dir`.
    pub fn export_to_dir(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("trace.json"), self.export_chrome_trace())?;
        std::fs::write(dir.join("trace.jsonl"), self.export_jsonl())?;
        Ok(())
    }
}

/// Well-formedness check over a request's span events: every `Begin`
/// closes with a matching `End` (innermost first) and nothing dangles.
/// Shared by the recorder's own tests and the fleet/sim suites.
pub fn check_span_nesting(events: &[TraceEvent]) -> Result<(), String> {
    use std::collections::HashMap;
    let mut open: HashMap<u64, Vec<&'static str>> = HashMap::new();
    let mut sorted: Vec<&TraceEvent> = events.iter().collect();
    sorted.sort_by_key(|e| e.seq);
    for e in sorted {
        match e.phase {
            EventPhase::Begin => open.entry(e.req).or_default().push(e.name),
            EventPhase::End => {
                let stack = open.entry(e.req).or_default();
                match stack.pop() {
                    Some(top) if top == e.name => {}
                    Some(top) => {
                        return Err(format!(
                            "req {}: End({}) closes open span {top:?} (interleaved overlap)",
                            e.req, e.name
                        ));
                    }
                    None => {
                        return Err(format!("req {}: End({}) without a Begin", e.req, e.name));
                    }
                }
            }
            EventPhase::Instant => {}
        }
    }
    for (req, stack) in &open {
        if !stack.is_empty() {
            return Err(format!("req {req}: spans left open: {stack:?}"));
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Replica time-attribution
// ---------------------------------------------------------------------------

/// Where a replica-second went. Every instant of a proxy loop's life
/// lands in exactly one category; `Draining` is pool-side time between
/// a slot leaving service and its retirement being finalized (counted
/// in addition to the serving-time categories).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttrCategory {
    /// decode steps + sampling on admitted work
    DecodeBusy = 0,
    /// admission of fresh prompts into decode rows
    Prefill = 1,
    /// admission that replays a salvaged prefix (the KV rebuild bill)
    PrefillReplay = 2,
    /// weight rebuild on UPDATE_WEIGHTS, or suspended waiting out a
    /// broadcast sync
    WeightSync = 3,
    /// draining toward retirement (pool-side, after the serve clock
    /// closed)
    Draining = 4,
    /// nothing to decode — the paper's resource bubble
    IdleBubble = 5,
}

impl AttrCategory {
    pub const ALL: [AttrCategory; 6] = [
        AttrCategory::DecodeBusy,
        AttrCategory::Prefill,
        AttrCategory::PrefillReplay,
        AttrCategory::WeightSync,
        AttrCategory::Draining,
        AttrCategory::IdleBubble,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            AttrCategory::DecodeBusy => "decode_busy",
            AttrCategory::Prefill => "prefill",
            AttrCategory::PrefillReplay => "prefill_replay",
            AttrCategory::WeightSync => "weight_sync",
            AttrCategory::Draining => "draining",
            AttrCategory::IdleBubble => "idle_bubble",
        }
    }
}

/// Lock-free accumulator (microseconds per category), shared between a
/// proxy loop and the pool that reports on it.
#[derive(Debug, Default)]
pub struct Attribution {
    micros: [AtomicU64; 6],
}

impl Attribution {
    pub fn add(&self, cat: AttrCategory, secs: f64) {
        if secs > 0.0 {
            self.micros[cat as usize].fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> AttrSnapshot {
        let s = |c: AttrCategory| self.micros[c as usize].load(Ordering::Relaxed) as f64 / 1e6;
        AttrSnapshot {
            decode_busy: s(AttrCategory::DecodeBusy),
            prefill: s(AttrCategory::Prefill),
            prefill_replay: s(AttrCategory::PrefillReplay),
            weight_sync: s(AttrCategory::WeightSync),
            draining: s(AttrCategory::Draining),
            idle_bubble: s(AttrCategory::IdleBubble),
        }
    }
}

/// A point-in-time (or per-step delta) reading of an [`Attribution`],
/// in seconds per category.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AttrSnapshot {
    pub decode_busy: f64,
    pub prefill: f64,
    pub prefill_replay: f64,
    pub weight_sync: f64,
    pub draining: f64,
    pub idle_bubble: f64,
}

impl AttrSnapshot {
    /// Seconds attributed while serving (everything but `draining`) —
    /// the quantity that sums to `serving_replicas × wall_secs`.
    pub fn serving_total(&self) -> f64 {
        self.decode_busy + self.prefill + self.prefill_replay + self.weight_sync + self.idle_bubble
    }

    /// All attributed seconds including the drain tail.
    pub fn total(&self) -> f64 {
        self.serving_total() + self.draining
    }

    pub fn merge(&mut self, o: &AttrSnapshot) {
        self.decode_busy += o.decode_busy;
        self.prefill += o.prefill;
        self.prefill_replay += o.prefill_replay;
        self.weight_sync += o.weight_sync;
        self.draining += o.draining;
        self.idle_bubble += o.idle_bubble;
    }

    /// Per-step delta against an earlier reading (clamped at zero so a
    /// replica retiring mid-step cannot go negative).
    pub fn delta(&self, earlier: &AttrSnapshot) -> AttrSnapshot {
        let d = |a: f64, b: f64| (a - b).max(0.0);
        AttrSnapshot {
            decode_busy: d(self.decode_busy, earlier.decode_busy),
            prefill: d(self.prefill, earlier.prefill),
            prefill_replay: d(self.prefill_replay, earlier.prefill_replay),
            weight_sync: d(self.weight_sync, earlier.weight_sync),
            draining: d(self.draining, earlier.draining),
            idle_bubble: d(self.idle_bubble, earlier.idle_bubble),
        }
    }

    /// Fraction of serving time spent decoding.
    pub fn busy_frac(&self) -> f64 {
        let t = self.serving_total();
        if t <= 0.0 {
            0.0
        } else {
            (self.decode_busy + self.prefill + self.prefill_replay) / t
        }
    }

    /// Fraction of serving time lost to idle bubbles.
    pub fn bubble_frac(&self) -> f64 {
        let t = self.serving_total();
        if t <= 0.0 { 0.0 } else { self.idle_bubble / t }
    }

    /// The attribution column the fleet tables print:
    /// `busy/sync/idle` percent of serving time.
    pub fn format_compact(&self) -> String {
        let t = self.serving_total();
        if t <= 0.0 {
            return "-".into();
        }
        format!(
            "{:.0}/{:.0}/{:.0}%",
            100.0 * self.busy_frac(),
            100.0 * self.weight_sync / t,
            100.0 * self.bubble_frac(),
        )
    }
}

/// Segment timer for event loops: every `lap(cat)` attributes the time
/// since the previous lap to `cat`, so the loop's whole life is
/// covered with no gaps and no double counting.
pub struct AttrStopwatch {
    attr: Arc<Attribution>,
    last: Instant,
}

impl AttrStopwatch {
    pub fn new(attr: Arc<Attribution>) -> Self {
        AttrStopwatch { attr, last: Instant::now() }
    }

    pub fn lap(&mut self, cat: AttrCategory) {
        let now = Instant::now();
        self.attr.add(cat, (now - self.last).as_secs_f64());
        self.last = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(rec: &FlightRecorder, name: &'static str, phase: EventPhase, req: u64) {
        rec.emit(name, phase, req, Some(0), 0, 0, String::new());
    }

    #[test]
    fn ring_wraparound_keeps_newest_events() {
        let rec = FlightRecorder::new(4);
        for i in 0..10u64 {
            ev(&rec, "decode", EventPhase::Instant, i);
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 4, "ring holds exactly its capacity");
        let reqs: Vec<u64> = evs.iter().map(|e| e.req).collect();
        assert_eq!(reqs, vec![6, 7, 8, 9], "newest events survive the wrap");
        assert_eq!(rec.dropped(), 6, "each overwrite is counted");
    }

    #[test]
    fn rings_are_per_replica() {
        let rec = FlightRecorder::new(2);
        // 3 events on replica 0 would wrap a shared ring of 2; with
        // per-replica rings both replicas keep their own newest 2
        for r in [0usize, 1, 0, 1, 0, 1] {
            rec.emit("decode", EventPhase::Instant, r as u64, Some(r), 0, 0, String::new());
        }
        rec.emit("submit", EventPhase::Instant, 9, None, 0, 0, String::new());
        let evs = rec.events();
        assert_eq!(evs.iter().filter(|e| e.replica == Some(0)).count(), 2);
        assert_eq!(evs.iter().filter(|e| e.replica == Some(1)).count(), 2);
        assert_eq!(evs.iter().filter(|e| e.replica.is_none()).count(), 1);
        // global order is preserved across rings
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "{seqs:?}");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let rec = FlightRecorder::disabled();
        ev(&rec, "decode", EventPhase::Instant, 1);
        assert!(rec.events().is_empty());
        assert_eq!(rec.dropped(), 0);
        rec.set_enabled(true);
        ev(&rec, "decode", EventPhase::Instant, 2);
        assert_eq!(rec.events().len(), 1);
    }

    #[test]
    fn span_nesting_checker_accepts_well_formed() {
        let rec = FlightRecorder::new(64);
        for req in 0..3u64 {
            ev(&rec, "queue", EventPhase::Begin, req);
            ev(&rec, "queue", EventPhase::End, req);
            ev(&rec, "decode", EventPhase::Begin, req);
            ev(&rec, "prefill", EventPhase::Begin, req);
            ev(&rec, "prefill", EventPhase::End, req);
            ev(&rec, "done", EventPhase::Instant, req);
            ev(&rec, "decode", EventPhase::End, req);
        }
        check_span_nesting(&rec.events()).unwrap();
    }

    #[test]
    fn span_nesting_checker_rejects_malformed() {
        let rec = FlightRecorder::new(64);
        ev(&rec, "decode", EventPhase::Begin, 1);
        assert!(
            check_span_nesting(&rec.events()).is_err(),
            "a dangling Begin must be rejected"
        );
        ev(&rec, "decode", EventPhase::End, 1);
        check_span_nesting(&rec.events()).unwrap();
        // interleaved overlap on one request id
        ev(&rec, "a", EventPhase::Begin, 2);
        ev(&rec, "b", EventPhase::Begin, 2);
        ev(&rec, "a", EventPhase::End, 2);
        assert!(check_span_nesting(&rec.events()).is_err(), "interleaved spans must be rejected");
        // an End with no Begin
        let rec = FlightRecorder::new(8);
        ev(&rec, "x", EventPhase::End, 3);
        assert!(check_span_nesting(&rec.events()).is_err());
    }

    #[test]
    fn chrome_trace_round_trips_through_json_parser() {
        let rec = FlightRecorder::new(64);
        rec.emit("submit", EventPhase::Instant, 7, None, 0, 0, "policy=\"queue\"".into());
        rec.emit("decode", EventPhase::Begin, 7, Some(2), 3, 11, String::new());
        rec.emit("decode", EventPhase::End, 7, Some(2), 3, 11, "tokens=5".into());
        let text = rec.export_chrome_trace();
        let j = Json::parse(&text).expect("chrome trace must be valid JSON");
        let evs = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
        assert_eq!(evs.len(), 3);
        let first = &evs[0];
        assert_eq!(first.get("name").and_then(Json::as_str), Some("submit"));
        assert_eq!(first.get("ph").and_then(Json::as_str), Some("i"));
        assert_eq!(first.get("pid").and_then(Json::as_f64), Some(0.0), "pool-level pid 0");
        assert_eq!(first.get("tid").and_then(Json::as_f64), Some(7.0));
        // the escaped detail survives the round trip
        assert_eq!(
            first.get("args").and_then(|a| a.get("detail")).and_then(Json::as_str),
            Some("policy=\"queue\"")
        );
        let span = &evs[1];
        assert_eq!(span.get("pid").and_then(Json::as_f64), Some(3.0), "replica 2 -> pid 3");
        assert_eq!(
            span.get("args").and_then(|a| a.get("version")).and_then(Json::as_f64),
            Some(11.0)
        );

        // JSONL: every line parses on its own
        let jsonl = rec.export_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            Json::parse(line).expect("each JSONL line is a JSON object");
        }
    }

    #[test]
    fn virtual_timestamps_pass_through() {
        let rec = FlightRecorder::new(8);
        rec.emit_at("decode", EventPhase::Begin, 1, Some(0), 0, 0, 123.5, String::new());
        let evs = rec.events();
        assert_eq!(evs[0].t, 123.5);
    }

    #[test]
    fn attribution_accumulates_and_deltas() {
        let attr = Attribution::default();
        attr.add(AttrCategory::DecodeBusy, 2.0);
        attr.add(AttrCategory::IdleBubble, 1.0);
        attr.add(AttrCategory::WeightSync, 0.5);
        let a = attr.snapshot();
        assert!((a.serving_total() - 3.5).abs() < 1e-6, "{a:?}");
        attr.add(AttrCategory::DecodeBusy, 1.0);
        attr.add(AttrCategory::Draining, 0.25);
        let b = attr.snapshot();
        let d = b.delta(&a);
        assert!((d.decode_busy - 1.0).abs() < 1e-6);
        assert!((d.draining - 0.25).abs() < 1e-6);
        assert!((d.idle_bubble).abs() < 1e-6);
        assert!((b.total() - 4.75).abs() < 1e-6);
        // negative-duration guard
        attr.add(AttrCategory::Prefill, -5.0);
        assert_eq!(attr.snapshot().prefill, 0.0);
        // merge sums categories
        let mut m = a;
        m.merge(&d);
        assert!((m.total() - b.total()).abs() < 1e-6);
        assert!(b.busy_frac() > 0.0 && b.bubble_frac() > 0.0);
        assert!(!b.format_compact().is_empty());
    }

    #[test]
    fn stopwatch_covers_every_segment() {
        let attr = Arc::new(Attribution::default());
        let t0 = Instant::now();
        let mut sw = AttrStopwatch::new(attr.clone());
        std::thread::sleep(std::time::Duration::from_millis(20));
        sw.lap(AttrCategory::DecodeBusy);
        std::thread::sleep(std::time::Duration::from_millis(10));
        sw.lap(AttrCategory::IdleBubble);
        let wall = t0.elapsed().as_secs_f64();
        let s = attr.snapshot();
        assert!(s.decode_busy >= 0.015, "{s:?}");
        assert!(s.idle_bubble >= 0.005, "{s:?}");
        // laps partition the wall time: no double counting
        assert!(s.serving_total() <= wall + 1e-3, "{s:?} vs wall {wall}");
    }
}
