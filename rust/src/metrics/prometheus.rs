//! Prometheus text exposition (format version 0.0.4) for the
//! [`MetricsRegistry`].
//!
//! Render [`MetricsSnapshot`]s — counters, gauges, and log-bucketed
//! histograms (exposed as summaries with fixed quantiles) — into the
//! plain-text scrape format and write it to a file (textfile-collector
//! style: point `node_exporter --collector.textfile.directory` or any
//! scraper at the output). No HTTP server: the repo has no network
//! dependency to serve from, and the file is the trivially-correct
//! transport for both the real controller and CI's format-lint step.
//!
//! Naming: every series is prefixed `roll_`, dots and dashes map to
//! underscores (`pool.kv_hits` → `roll_pool_kv_hits_total`), counters
//! get the conventional `_total` suffix, and histograms expose
//! `_sum`/`_count` plus `quantile` labels.

use std::path::Path;

use crate::metrics::registry::{MetricsRegistry, MetricsSnapshot};

const PREFIX: &str = "roll_";
const QUANTILES: [f64; 4] = [0.5, 0.9, 0.99, 1.0];

/// Map an internal metric name onto the Prometheus grammar
/// `[a-zA-Z_:][a-zA-Z0-9_:]*` (dots/dashes/anything else → `_`).
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(PREFIX.len() + name.len());
    out.push_str(PREFIX);
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphanumeric() || c == '_' || c == ':';
        if ok && !(i == 0 && c.is_ascii_digit()) {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn finite(v: f64) -> f64 {
    if v.is_finite() { v } else { 0.0 }
}

/// Render a snapshot as Prometheus text exposition.
pub fn render(snap: &MetricsSnapshot) -> String {
    let mut out = String::new();
    for (name, v) in &snap.counters {
        let n = format!("{}_total", sanitize(name));
        out.push_str(&format!("# HELP {n} counter `{name}`\n"));
        out.push_str(&format!("# TYPE {n} counter\n"));
        out.push_str(&format!("{n} {v}\n"));
    }
    for (name, v) in &snap.gauges {
        let n = sanitize(name);
        out.push_str(&format!("# HELP {n} gauge `{name}`\n"));
        out.push_str(&format!("# TYPE {n} gauge\n"));
        out.push_str(&format!("{n} {}\n", finite(*v)));
    }
    for (name, h) in &snap.hists {
        let n = sanitize(name);
        out.push_str(&format!("# HELP {n} histogram `{name}` (log-bucketed summary)\n"));
        out.push_str(&format!("# TYPE {n} summary\n"));
        for q in QUANTILES {
            out.push_str(&format!("{n}{{quantile=\"{q}\"}} {}\n", finite(h.percentile(q * 100.0))));
        }
        out.push_str(&format!("{n}_sum {}\n", finite(h.mean() * h.count() as f64)));
        out.push_str(&format!("{n}_count {}\n", h.count()));
    }
    out
}

/// Snapshot (without reset) and write the exposition to `path`.
pub fn write_to_file(registry: &MetricsRegistry, path: &Path) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, render(&registry.snapshot()))
}

/// Structural lint of an exposition document — the same checks CI's
/// format-lint step applies: every `# TYPE`/`# HELP` line is
/// well-formed, every sample line parses as `name[{labels}] value`
/// with a legal metric name and a float value, and every sample's
/// base name was declared by a preceding `# TYPE`.
pub fn lint(text: &str) -> Result<(), String> {
    fn name_ok(n: &str) -> bool {
        !n.is_empty()
            && !n.starts_with(|c: char| c.is_ascii_digit())
            && n.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    }
    let mut typed: Vec<String> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let human = lineno + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let kw = parts.next().unwrap_or("");
            let name = parts.next().unwrap_or("");
            match kw {
                "HELP" => {
                    if !name_ok(name) {
                        return Err(format!("line {human}: bad HELP metric name {name:?}"));
                    }
                }
                "TYPE" => {
                    let kind = parts.next().unwrap_or("");
                    if !name_ok(name) {
                        return Err(format!("line {human}: bad TYPE metric name {name:?}"));
                    }
                    if !matches!(kind, "counter" | "gauge" | "summary" | "histogram" | "untyped") {
                        return Err(format!("line {human}: bad TYPE kind {kind:?}"));
                    }
                    typed.push(name.to_string());
                }
                _ => return Err(format!("line {human}: unknown comment keyword {kw:?}")),
            }
            continue;
        }
        if line.starts_with('#') {
            return Err(format!("line {human}: comments must be `# HELP` or `# TYPE`"));
        }
        let (series, value) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return Err(format!("line {human}: sample missing value")),
        };
        if value.parse::<f64>().is_err() {
            return Err(format!("line {human}: value {value:?} is not a float"));
        }
        let name = match series.split_once('{') {
            Some((n, labels)) => {
                if !labels.ends_with('}') {
                    return Err(format!("line {human}: unterminated label set"));
                }
                n
            }
            None => series,
        };
        if !name_ok(name) {
            return Err(format!("line {human}: bad sample metric name {name:?}"));
        }
        let declared = typed.iter().any(|t| {
            name == t
                || name
                    .strip_prefix(t.as_str())
                    .is_some_and(|s| s == "_sum" || s == "_count" || s == "_total" || s == "_bucket")
        });
        if !declared {
            return Err(format!("line {human}: sample {name:?} has no preceding # TYPE"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sanitize_maps_dots_and_digits() {
        assert_eq!(sanitize("pool.kv_hits"), "roll_pool_kv_hits");
        assert_eq!(sanitize("trace.ring_occupancy.3"), "roll_trace_ring_occupancy_3");
        assert_eq!(sanitize("weird-name!x"), "roll_weird_name_x");
    }

    #[test]
    fn render_passes_own_lint() {
        let reg = MetricsRegistry::new();
        reg.counter("pool.completed").add(42);
        reg.gauge("telemetry.waste_rate").set(0.25);
        let h = reg.histogram("pool.completion_latency", 1e-3, 1.25);
        for k in 1..=50 {
            h.record(k as f64 * 0.01);
        }
        let text = render(&reg.snapshot());
        lint(&text).expect("rendered exposition must lint clean");
        assert!(text.contains("roll_pool_completed_total 42"));
        assert!(text.contains("# TYPE roll_pool_completed_total counter"));
        assert!(text.contains("# TYPE roll_telemetry_waste_rate gauge"));
        assert!(text.contains("roll_pool_completion_latency{quantile=\"0.5\"}"));
        assert!(text.contains("roll_pool_completion_latency_count 50"));
    }

    #[test]
    fn empty_registry_renders_empty_and_lints() {
        let reg = MetricsRegistry::new();
        let text = render(&reg.snapshot());
        assert!(text.is_empty());
        lint(&text).unwrap();
    }

    #[test]
    fn lint_rejects_malformed_documents() {
        assert!(lint("no_type_decl 1\n").is_err());
        assert!(lint("# TYPE x bogus\nx 1\n").is_err());
        assert!(lint("# TYPE x gauge\nx notafloat\n").is_err());
        assert!(lint("# TYPE 9bad gauge\n").is_err());
        assert!(lint("# TYPE x gauge\nx{quantile=\"0.5\" 1\n").is_err());
        assert!(lint("# TYPE x gauge\nx 1\n").is_ok());
        assert!(lint("# HELP x doc words here\n# TYPE x summary\nx{quantile=\"0.5\"} 2\nx_sum 3\nx_count 1\n").is_ok());
    }

    #[test]
    fn write_to_file_roundtrip() {
        let reg = MetricsRegistry::new();
        reg.counter("pool.completed").inc();
        let dir = std::env::temp_dir().join("roll_prom_test");
        let path = dir.join("metrics.prom");
        write_to_file(&reg, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        lint(&text).unwrap();
        assert!(text.contains("roll_pool_completed_total 1"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
