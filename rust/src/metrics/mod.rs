//! Metrics substrate: log-bucketed histograms, utilization ledgers,
//! and table/CSV emitters used by the bench harness, plus the
//! flight-recorder tracing layer ([`trace`]), the central named
//! metrics registry ([`registry`]), the live telemetry plane
//! ([`telemetry`]: windowed bottleneck verdicts, anomaly watchdogs,
//! episode critical-path analysis), and Prometheus text exposition
//! ([`prometheus`]).

pub mod prometheus;
pub mod registry;
pub mod telemetry;
pub mod trace;

use std::collections::HashMap;
use std::fmt::Write as _;

/// Log-bucketed latency/size histogram (HDR-lite, std-only).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// bucket i covers [min * growth^i, min * growth^(i+1))
    min: f64,
    growth: f64,
    counts: Vec<u64>,
    total: u64,
    sum: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new(1e-6, 1.07)
    }
}

impl Histogram {
    pub fn new(min: f64, growth: f64) -> Self {
        assert!(min > 0.0 && growth > 1.0);
        Histogram { min, growth, counts: vec![0; 512], total: 0, sum: 0.0, max: 0.0 }
    }

    fn bucket(&self, v: f64) -> usize {
        if v <= self.min {
            return 0;
        }
        let i = ((v / self.min).ln() / self.growth.ln()).floor() as usize;
        i.min(self.counts.len() - 1)
    }

    pub fn record(&mut self, v: f64) {
        let b = self.bucket(v);
        self.counts[b] += 1;
        self.total += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.sum / self.total as f64 }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Fold another histogram into this one. Both must share the same
    /// bucket layout (min/growth) — the fleet report uses this to
    /// aggregate per-replica histograms across retired slot occupants.
    pub fn merge(&mut self, other: &Histogram) {
        assert!(
            (self.min - other.min).abs() < 1e-12
                && (self.growth - other.growth).abs() < 1e-12
                && self.counts.len() == other.counts.len(),
            "histogram bucket layouts differ: cannot merge"
        );
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    /// Clear all recorded samples, keeping the bucket layout. Turns a
    /// lifetime histogram into a windowed one: record, read, reset —
    /// the autoscaler samples per-interval queue-depth percentiles this
    /// way instead of lifetime ones.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.total = 0;
        self.sum = 0.0;
        self.max = 0.0;
    }

    /// Approximate percentile (bucket upper edge).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * self.total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return self.min * self.growth.powi(i as i32 + 1);
            }
        }
        self.max
    }
}

/// Busy/total accounting per worker pool — the resource-utilization and
/// bubble metrics the paper reports.
#[derive(Clone, Debug, Default)]
pub struct UtilizationLedger {
    pub busy: f64,
    pub span: f64,
    pub workers: usize,
}

impl UtilizationLedger {
    pub fn new(workers: usize) -> Self {
        UtilizationLedger { busy: 0.0, span: 0.0, workers }
    }

    pub fn add_busy(&mut self, dt: f64) {
        self.busy += dt;
    }

    pub fn close(&mut self, makespan: f64) {
        self.span = makespan;
    }

    /// Fraction of worker-time spent busy.
    pub fn utilization(&self) -> f64 {
        let cap = self.span * self.workers as f64;
        if cap <= 0.0 { 0.0 } else { (self.busy / cap).min(1.0) }
    }

    /// Idle worker-seconds (the paper's "resource bubbles").
    pub fn bubble_time(&self) -> f64 {
        (self.span * self.workers as f64 - self.busy).max(0.0)
    }
}

/// Named scalar metrics with insertion-ordered emit: the CSV header
/// lists keys in the order they were first set, so columns line up
/// with the writer's narrative rather than alphabetically.
#[derive(Clone, Debug, Default)]
pub struct Scalars {
    vals: Vec<(String, f64)>,
    index: HashMap<String, usize>,
}

impl Scalars {
    pub fn set(&mut self, k: &str, v: f64) {
        match self.index.get(k) {
            Some(&i) => self.vals[i].1 = v,
            None => {
                self.index.insert(k.to_string(), self.vals.len());
                self.vals.push((k.to_string(), v));
            }
        }
    }

    pub fn add(&mut self, k: &str, v: f64) {
        match self.index.get(k) {
            Some(&i) => self.vals[i].1 += v,
            None => self.set(k, v),
        }
    }

    pub fn get(&self, k: &str) -> Option<f64> {
        self.index.get(k).map(|&i| self.vals[i].1)
    }

    pub fn to_csv_row(&self) -> (String, String) {
        let header = self.vals.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>().join(",");
        let row = self.vals.iter().map(|(_, v)| format!("{v:.6}")).collect::<Vec<_>>().join(",");
        (header, row)
    }
}

/// Markdown table emitter for bench reports (mirrors paper tables).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(line, " {c:w$} |");
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<1$}|", "", w + 2);
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format seconds as the paper's "X.XXh" convention.
pub fn hours(secs: f64) -> String {
    format!("{:.2}h", secs / 3600.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let mut h = Histogram::new(0.001, 1.05);
        for i in 1..=1000 {
            h.record(i as f64 / 100.0); // 0.01 .. 10.0
        }
        assert_eq!(h.count(), 1000);
        assert!((h.mean() - 5.005).abs() < 0.01);
        let p50 = h.percentile(50.0);
        assert!(p50 > 4.0 && p50 < 6.0, "{p50}");
        let p99 = h.percentile(99.0);
        assert!(p99 > 9.0 && p99 < 11.0, "{p99}");
    }

    #[test]
    fn histogram_merge_matches_single_recording() {
        let mut a = Histogram::new(0.001, 1.05);
        let mut b = Histogram::new(0.001, 1.05);
        let mut whole = Histogram::new(0.001, 1.05);
        for i in 1..=500 {
            a.record(i as f64 / 100.0);
            whole.record(i as f64 / 100.0);
        }
        for i in 501..=1000 {
            b.record(i as f64 / 100.0);
            whole.record(i as f64 / 100.0);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert!((a.mean() - whole.mean()).abs() < 1e-9);
        assert!((a.max() - whole.max()).abs() < 1e-12);
        for p in [50.0, 90.0, 99.0] {
            assert!((a.percentile(p) - whole.percentile(p)).abs() < 1e-9, "p{p}");
        }
        // merging an empty histogram is a no-op
        let before = a.count();
        a.merge(&Histogram::new(0.001, 1.05));
        assert_eq!(a.count(), before);
    }

    #[test]
    #[should_panic(expected = "bucket layouts differ")]
    fn histogram_merge_rejects_mismatched_layouts() {
        let mut a = Histogram::new(0.001, 1.05);
        a.merge(&Histogram::new(1.0, 1.25));
    }

    #[test]
    fn histogram_reset_windows_recordings() {
        let mut h = Histogram::new(1.0, 1.25);
        h.record(10.0);
        h.record(20.0);
        assert_eq!(h.count(), 2);
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        // the layout survives: recording works again after the reset
        h.record(5.0);
        assert_eq!(h.count(), 1);
        assert!((h.mean() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_ledger() {
        let mut u = UtilizationLedger::new(4);
        u.add_busy(10.0);
        u.close(5.0); // 4 workers x 5s = 20 worker-seconds
        assert!((u.utilization() - 0.5).abs() < 1e-12);
        assert!((u.bubble_time() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn scalars_emit_insertion_order() {
        let mut s = Scalars::default();
        s.set("zulu", 1.0);
        s.set("alpha", 2.0);
        s.add("mike", 3.0);
        s.set("alpha", 4.0); // overwrite must not move the column
        s.add("zulu", 0.5);
        let (header, row) = s.to_csv_row();
        assert_eq!(header, "zulu,alpha,mike", "first-set order, not alphabetical");
        assert_eq!(row, "1.500000,4.000000,3.000000");
        assert_eq!(s.get("alpha"), Some(4.0));
        assert_eq!(s.get("missing"), None);
    }

    #[test]
    fn table_markdown() {
        let mut t = Table::new(&["k", "v"]);
        t.row(&["a".into(), "1".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | 1 |"));
        assert!(md.lines().count() == 3);
    }

    #[test]
    fn hours_format() {
        assert_eq!(hours(36792.0), "10.22h");
    }
}
