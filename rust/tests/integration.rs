//! Full-stack integration: LLMProxy + EnvManagers + SampleBuffer +
//! AsyncController against the real PJRT engine (tiny artifacts).
//! Skipped when `make artifacts` has not run.

use std::path::PathBuf;

use roll_flash::config::PgVariant;
use roll_flash::coordinator::{
    run_training, ControllerCfg, LlmProxy, RolloutSystem, RolloutSystemCfg,
};
use roll_flash::env::alfworld::AlfworldEnv;
use roll_flash::env::math::MathEnv;
use roll_flash::env::vocab;
use roll_flash::runtime::ModelRuntime;
use roll_flash::workload::EnvLatency;

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/tiny");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn proxy_generates_and_respects_commands() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    let proxy = LlmProxy::spawn(dir, weights.clone(), vocab::EOS, 7);

    // several concurrent requests (continuous batching)
    let mut rxs = Vec::new();
    for i in 0..10 {
        let prompt = MathEnv::prompt_for(i % 10, (i + 3) % 10);
        rxs.push(proxy.generate(prompt, 4).1);
    }
    for rx in rxs {
        let res = rx.recv().expect("generation completes");
        assert!(!res.tokens.is_empty() && res.tokens.len() <= 4);
        assert_eq!(res.tokens.len(), res.logps.len());
        assert!(res.logps.iter().all(|&l| l <= 0.0 && l.is_finite()));
        assert_eq!(res.version, 0);
    }

    // weight update bumps the reported version
    proxy.update_weights(weights, 3);
    let (_, rx) = proxy.generate(MathEnv::prompt_for(1, 2), 4);
    assert_eq!(rx.recv().unwrap().version, 3);

    // abort: the reply channel never fires
    proxy.suspend(); // hold decoding so the abort lands first
    let (id, rx) = proxy.generate(MathEnv::prompt_for(2, 2), 4);
    proxy.abort(id);
    proxy.resume();
    assert!(rx.recv_timeout(std::time::Duration::from_millis(400)).is_err());

    let report = proxy.shutdown().unwrap();
    assert!(report.completed >= 11);
    assert!(report.tokens_generated > 0);
}

#[test]
fn fleet_collects_complete_groups() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    let cfg = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: 4,
        env_group_size: 4,
        consume_groups: 4,
        consume_group_size: 4,
        alpha: 1.0,
        seed: 3,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
    };
    let system = RolloutSystem::start(&cfg, weights, |_, _| MathEnv::new()).unwrap();
    let samples = system.buffer.get_batch(4).expect("batch");
    assert_eq!(samples.len(), 16);
    // group completeness: every group key appears exactly group_size times
    let mut counts = std::collections::BTreeMap::new();
    for s in &samples {
        *counts.entry(s.group).or_insert(0usize) += 1;
        assert_eq!(s.prompt.len(), 8);
        assert!(!s.response.is_empty());
        assert_eq!(s.response.len(), s.behavior_logps.len());
        assert_eq!(s.init_version, 0);
    }
    assert!(counts.values().all(|&c| c == 4), "{counts:?}");
    let report = system.shutdown().unwrap();
    assert!(report.buffer.produced >= 16);
    assert!(report.proxy.completed as usize >= 16);
}

#[test]
fn sync_training_loop_runs_on_math_env() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    let mut st = rt.train_state(&weights).unwrap();
    // tiny: train_batch = 16 => 4 groups x 4 = 16 sequences per step
    let cfg = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: 4,
        env_group_size: 4,
        consume_groups: 4,
        consume_group_size: 4,
        alpha: 0.0,
        seed: 5,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
    };
    let system = RolloutSystem::start(&cfg, weights, |_, _| MathEnv::new()).unwrap();
    let ctl = ControllerCfg {
        variant: PgVariant::Ppo,
        steps: 3,
        lr: 1e-3,
        n_groups: 4,
        group_size: 4,
        sync_mode: true,
    };
    let logs = run_training(&rt, &mut st, &system.proxy, &system.buffer, &ctl).unwrap();
    assert_eq!(logs.len(), 3);
    for l in &logs {
        assert!(l.loss.is_finite());
        assert!(l.entropy > 0.0);
        assert!(l.reward_mean >= 0.0 && l.reward_mean <= 1.0);
        // on-policy-ish: ratios near 1 (same policy generated the data)
        assert!(l.mean_ratio > 0.8 && l.mean_ratio < 1.2, "ratio {}", l.mean_ratio);
    }
    let report = system.shutdown().unwrap();
    // sync mode (alpha = 0): strictly on-policy consumption — any
    // sample straddling an update is reclaimed, never trained on
    assert_eq!(report.buffer.max_version_gap, 0, "sync must be on-policy");
}

#[test]
fn async_training_overlaps_and_bounds_staleness() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    let mut st = rt.train_state(&weights).unwrap();
    let alpha = 2.0;
    let cfg = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: 4,
        env_group_size: 4,
        consume_groups: 4,
        consume_group_size: 4,
        alpha,
        seed: 11,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
    };
    let system = RolloutSystem::start(&cfg, weights, |_, _| MathEnv::new()).unwrap();
    let ctl = ControllerCfg {
        variant: PgVariant::Tis,
        steps: 5,
        lr: 1e-3,
        n_groups: 4,
        group_size: 4,
        sync_mode: false,
    };
    let logs = run_training(&rt, &mut st, &system.proxy, &system.buffer, &ctl).unwrap();
    assert_eq!(logs.len(), 5);
    let report = system.shutdown().unwrap();
    // per-sample freshness (Section 4.3): consumed gap <= alpha, exactly
    assert!(
        (report.buffer.max_version_gap as f64) <= alpha,
        "gap {} exceeds alpha {}",
        report.buffer.max_version_gap,
        alpha
    );
    assert!(report.buffer.consumed >= 5 * 16);
}

#[test]
fn multiturn_env_manager_interleaves_obs_and_actions() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    let cfg = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: 2,
        env_group_size: 2,
        consume_groups: 2,
        consume_group_size: 2,
        alpha: 0.0,
        seed: 9,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
    };
    let system = RolloutSystem::start(&cfg, weights, |_, _| {
        AlfworldEnv::new(3, EnvLatency::gaussian(0.0, 0.0))
    })
    .unwrap();
    let samples = system.buffer.get_batch(2).expect("batch");
    assert_eq!(samples.len(), 4);
    for s in &samples {
        assert_eq!(s.response.len(), s.response_mask.len());
        assert_eq!(s.response.len(), s.behavior_logps.len());
        // at least one trainable action token
        assert!(s.response_mask.iter().any(|&m| m > 0.0));
        // obs tokens (mask 0) have no behavior logp
        for (m, lp) in s.response_mask.iter().zip(&s.behavior_logps) {
            if *m == 0.0 {
                assert_eq!(*lp, 0.0);
            } else {
                assert!(*lp <= 0.0);
            }
        }
        assert!(s.total_len() <= rt.manifest.max_seq);
    }
    system.shutdown().unwrap();
}

#[test]
fn redundant_groups_produce_surplus_without_blocking() {
    let Some(dir) = artifacts() else { return };
    let rt = ModelRuntime::load(&dir).unwrap();
    let weights = rt.load_init_params().unwrap();
    // fleet 3 groups x 5 members; quota 2 groups x 4
    let cfg = RolloutSystemCfg {
        artifacts_dir: dir,
        num_env_groups: 3,
        env_group_size: 5,
        consume_groups: 2,
        consume_group_size: 4,
        alpha: 1.0,
        seed: 13,
        latency_scale: 0.0,
        hang_timeout: f64::INFINITY,
    };
    let system = RolloutSystem::start(&cfg, weights, |_, _| MathEnv::new()).unwrap();
    let samples = system.buffer.get_batch(2).expect("batch");
    assert_eq!(samples.len(), 8);
    let report = system.shutdown().unwrap();
    // the 5th member of each completed group is surplus
    assert!(report.buffer.surplus > 0 || report.buffer.produced >= 8);
}
